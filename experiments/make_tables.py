"""Regenerate the EXPERIMENTS.md roofline tables from dryrun.json."""
import json
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.3g}"


def table(mesh: str) -> str:
    data = json.loads((HERE / "dryrun.json").read_text())
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
           "| peak GiB/chip | useful | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(data):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        v = data[key]
        if v["status"] == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                       f"skipped: {v['reason'][:60]} |")
            continue
        if v["status"] != "ok":
            out.append(f"| {arch} | {shape} | — | — | — | ERROR | — | — | "
                       f"{v.get('error','')[:50]} |")
            continue
        r = v["roofline"]
        out.append(
            f"| {arch} | {shape} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
            f"**{r['bottleneck']}** | "
            f"{v['memory']['peak_estimate_per_chip']/2**30:.2f} | "
            f"{v['useful_flops_ratio']:.3f} | |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(table(mesh))
