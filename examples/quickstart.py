"""Quickstart: the paper's result in 60 seconds, end to end.

1. Simulate the paper's Fig. 3 experiment: blocked Jacobi under
   dynamic scheduling with and without locality queues.
2. Train a reduced LM from the assigned-architecture zoo for a few steps.
3. Serve it through the locality-queue request router.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (NEHALEM_EP, SMALL_GRID, OpenMPLocalityQueues,
                        OpenMPTasking, StaticWorksharing, place, simulate)
from repro.data.pipeline import make_batch_iterator
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def part1_locality_queues():
    print("=" * 64)
    print("1. The paper's experiment: ccNUMA locality under tasking")
    print("=" * 64)
    topo = NEHALEM_EP
    ft = simulate(SMALL_GRID, topo, StaticWorksharing(),
                  place("static", SMALL_GRID, topo))
    task = simulate(SMALL_GRID, topo, OpenMPTasking("ijk"),
                    place("static", SMALL_GRID, topo), seed=0)
    lq = simulate(SMALL_GRID, topo, OpenMPLocalityQueues("kji"),
                  place("static1", SMALL_GRID, topo), seed=0)
    print(f"static first-touch (best case):   {ft.mlups:7.0f} MLUPs")
    print(f"plain OpenMP tasking (worst mix): {task.mlups:7.0f} MLUPs "
          f"(local access: {task.local_fraction:.0%})")
    print(f"locality queues (paper's fix):    {lq.mlups:7.0f} MLUPs "
          f"(local access: {lq.local_fraction:.0%})")
    print(f"-> locality queues recover {lq.mlups/ft.mlups:.1%} of optimal\n")


def part2_train():
    print("=" * 64)
    print("2. Train a reduced qwen2-0.5b on the synthetic corpus")
    print("=" * 64)
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=64)
    trainer = Trainer(model, make_batch_iterator(cfg.vocab_size, 32, 8),
                      LoopConfig(total_steps=20, checkpoint_every=1000,
                                 log_every=5),
                      AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20))
    out = trainer.run()
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}\n")
    return cfg, model, out


def part3_serve(cfg, model, params):
    print("=" * 64)
    print("3. Serve it through the locality-queue request router")
    print("=" * 64)
    engine = ServingEngine(model, params, num_replicas=2, max_seq=64,
                           policy="locality")
    rng = np.random.default_rng(0)
    for i in range(6):
        toks = rng.integers(0, cfg.vocab_size, size=8)
        engine.submit(Request(uid=i, tokens=toks, max_new=4,
                              home_replica=i % 2))
    done = engine.run_until_drained()
    for r in done[:3]:
        print(f"  request {r.uid}: generated {r.out_tokens}")
    s = engine.stats
    print(f"  locality fraction: {s.locality_fraction:.0%}, "
          f"steals: {s.stolen}")


if __name__ == "__main__":
    part1_locality_queues()
    cfg, model, out = part2_train()
    part3_serve(cfg, model, out["params"])
    print("\nDone. Next: examples/stencil_locality.py, "
          "examples/train_100m.py, python -m repro.launch.dryrun")
