"""A policy is a JSON file: load → build → run → record → replay, no code.

    PYTHONPATH=src python examples/spec_policies.py [spec.json]

The whole point of ``repro.spec``: a scheduling experiment is named by a
serializable ``RuntimeSpec``, so trying a new policy is editing a JSON
file, not wiring constructors.  This example

  1. loads a checked-in policy file (default: the full control plane,
     ``specs/controlled_replay.json``),
  2. builds the declared system (executor + control loop) and drives a
     seeded hot-skew arrival stream through it while recording,
  3. writes the trace — whose v2 header embeds the policy — to JSONL,
  4. reads it back and replays it with ``trace.replay(t)`` and *no
     executor argument*: the recorded configuration is reconstructed from
     the header alone and reproduces the recorded stats bit-for-bit,
  5. derives a variant policy in three lines and prints its JSON, ready to
     be checked in as a new named experiment.
"""
import dataclasses
import os
import sys
import tempfile

from repro import spec, trace

NUM_STEPS = 32


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "specs/controlled_replay.json"
    policy = spec.load(path)
    print(f"policy file: {path}")
    print(f"  steal_order={policy.steal_order} governor={policy.governor.kind}"
          f" breaker={policy.governor.breaker is not None}"
          f" router={policy.router.kind} batch={policy.batch.kind}")

    # build + drive: the declared system, a recorder attached on top
    # (unless the policy itself declares trace recording)
    built = policy.build()
    rec = built.recorder
    if rec is None:
        rec = trace.TraceRecorder()
        rec.attach(built.executor)
    wl = trace.hot_skew(
        trace.poisson(rate=policy.num_domains, steps=NUM_STEPS,
                      num_domains=policy.num_domains, seed=11),
        hot_domain=0, p_hot=0.8, seed=11)
    trace.drive(built.executor, wl)
    t = rec.finish()
    s = built.executor.stats
    print(f"ran {wl.name}: executed={s.executed} "
          f"local={s.local_fraction:.0%} steal={s.steal_fraction:.0%}")
    if built.control is not None:
        print(f"controller: {built.control.snapshot()}")

    # the trace file fully names the system that produced it
    tpath = os.path.join(tempfile.mkdtemp(prefix="repro-spec-"),
                         "policy-run.trace.jsonl")
    trace.TraceWriter(tpath).write(t)
    t2 = trace.TraceReader(tpath).read()
    assert t2.spec_dict is not None, "v2 header should embed the spec"
    res = trace.replay(t2, assert_match=True)      # no executor argument
    print(f"replayed from {tpath} header alone: bit-identical "
          f"(executed={res.stats['executed']:.0f})")

    # deriving a new experiment is a value edit, not a constructor change
    variant = dataclasses.replace(
        policy,
        router=dataclasses.replace(policy.router, spill="measured"),
        governor=dataclasses.replace(policy.governor, kind="adaptive"))
    print("\na derived policy (router prices spill from measurements):")
    print(variant.to_json())
    print("spec policies smoke OK")


if __name__ == "__main__":
    main()
