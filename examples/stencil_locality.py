"""The paper's toy model as a real distributed JAX application.

Runs the blocked Jacobi solver on an 8-device mesh under the two block→device
schedules (locality/contiguous vs scattered/round-robin), verifies both give
identical physics, and compares their compiled collective traffic — the
TPU-tier version of the paper's local-vs-nonlocal access measurement.

    PYTHONPATH=src python examples/stencil_locality.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import build_assignment
from repro.kernels.jacobi.ref import jacobi_sweep_ref
from repro.roofline.hlo_cost import analyze_text
from repro.stencil.jacobi import (JacobiGridConfig, make_contiguous_sweep,
                                  make_scattered_sweep, reassemble_scattered,
                                  run_runtime_sweep, scatter_lattice)

N_DEV = 8


def main():
    mesh = jax.make_mesh((N_DEV,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = JacobiGridConfig(ni=160, nj=48, nk=64)
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.standard_normal((cfg.ni, cfg.nj, cfg.nk)), jnp.float32)
    c = jnp.float32(1 / 6)

    # the schedule builder chooses contiguous slabs given block homes
    homes = np.repeat(np.arange(N_DEV), 160 // 10 // N_DEV)
    assign = build_assignment(homes, np.ones(len(homes)), N_DEV)
    print(f"schedule: locality={assign.locality_fraction:.0%} "
          f"imbalance={assign.imbalance:.1%} moved={assign.moved}")

    ref = jacobi_sweep_ref(f)
    with jax.set_mesh(mesh):
        fs = jax.device_put(f, NamedSharding(mesh, P("data", None, None)))
        contig = jax.jit(make_contiguous_sweep(cfg))
        out = contig(fs, c)
        err_c = float(jnp.max(jnp.abs(out - ref)))
        coll_c = sum(analyze_text(
            contig.lower(fs, c).compile().as_text()).coll.values())

        bpd = 2
        scat = jax.jit(make_scattered_sweep(cfg, blocks_per_dev=bpd))
        fs2 = jax.device_put(scatter_lattice(f, N_DEV, bpd),
                             NamedSharding(mesh, P("data", None, None)))
        out2 = reassemble_scattered(scat(fs2, c), N_DEV, bpd)
        err_s = float(jnp.max(jnp.abs(out2 - ref)))
        coll_s = sum(analyze_text(
            scat.lower(fs2, c).compile().as_text()).coll.values())

    # the same sweep as *online* runtime tasks: slabs homed contiguously on
    # 4 domains, scheduled by the paper's locality queues (repro.runtime)
    out_rt, rt = run_runtime_sweep(np.asarray(f), di=10, num_domains=4,
                                   workers_per_domain=2)
    err_r = float(np.max(np.abs(out_rt - np.asarray(ref))))

    print(f"contiguous (locality) : err={err_c:.1e} "
          f"collective={coll_c/1024:.0f} KiB/dev")
    print(f"scattered (oblivious) : err={err_s:.1e} "
          f"collective={coll_s/1024:.0f} KiB/dev")
    print(f"runtime    (online)   : err={err_r:.1e} "
          f"local={rt.local_fraction:.0%} steals={rt.stolen}")
    print(f"-> locality schedule moves {coll_s/max(coll_c,1):.0f}x fewer "
          f"bytes across domains for the same answer")


if __name__ == "__main__":
    main()
