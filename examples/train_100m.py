"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps on the synthetic corpus, with checkpointing and resume.

This is the deliverable-(b) "real" driver: full config system, data
pipeline, AdamW, async checkpoints.  On this CPU container it uses a
~100M-parameter narrowed qwen2 (same code path as the full configs); on a
TPU slice, drop --narrow to use the real qwen2-0.5b.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import make_batch_iterator
from repro.models.model import build_model
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def narrow_100m(cfg):
    """qwen2-0.5b narrowed to ~100M params (CPU-trainable)."""
    return dataclasses.replace(
        cfg, name="qwen2-100m", num_layers=6, d_model=512, num_heads=8,
        num_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32768,
        microbatches=1, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--narrow", action="store_true", default=True)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b")
    if args.narrow:
        cfg = narrow_100m(cfg)
    model = build_model(cfg, max_pos=args.seq)
    n_params = cfg.num_params()
    print(f"training {cfg.name}: ~{n_params/1e6:.0f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    trainer = Trainer(
        model, make_batch_iterator(cfg.vocab_size, args.seq, args.batch),
        LoopConfig(total_steps=args.steps, checkpoint_every=100,
                   checkpoint_dir=args.ckpt, log_every=20),
        AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps),
    )
    out = trainer.run()
    losses = out["losses"]
    k = max(len(losses) // 10, 1)
    print(f"loss: first-{k} mean {sum(losses[:k])/k:.4f} -> "
          f"last-{k} mean {sum(losses[-k:])/k:.4f}")


if __name__ == "__main__":
    main()
