"""Self-tuning serving: the control plane driving the serving engine.

    PYTHONPATH=src python examples/control_serving.py

Serves one stream of skewed requests through the ``ServingEngine`` twice —
uncontrolled (home routing, greedy stealing, one request per grab) and
controlled (cost-aware routing, adaptive continuous batching, storm
circuit-breaker) — and checks the contract that makes online control safe
to turn on: decoded tokens are bit-identical, only the scheduling
statistics move.

Both arms are declarative ``repro.spec`` policies: the controlled arm is
the registry entry ``controlled_serving`` and the uncontrolled arm is the
same spec with the control plane edited out — no constructor wiring.
Finally the controlled router's behaviour is recorded as a trace and
replayed *from the header spec alone* (``trace.replay(t)``, no factory),
asserting the replayed scheduler statistics are bit-identical to the
recorded ones.
"""
import dataclasses

import jax
import numpy as np

from repro import spec, trace
from repro.configs import get_config, reduce_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine

NUM_REPLICAS = 2
N_REQUESTS = 10


def make_requests(cfg, seed=0):
    # skewed session affinity: most requests' KV caches live on replica 0
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 14)))
        home = 0 if rng.random() < 0.8 else int(rng.integers(NUM_REPLICAS))
        reqs.append(Request(uid=i, tokens=toks, max_new=4, home_replica=home))
    return reqs


def serve(model, params, cfg, policy_spec, *, rec=None):
    eng = ServingEngine(model, params, spec=policy_spec, trace=rec)
    for r in make_requests(cfg):
        eng.submit(r)
    done = eng.run_until_drained()
    return eng, {r.uid: tuple(r.out_tokens) for r in done}


def main():
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=96)
    params = model.init_params(jax.random.key(0))

    ctl_spec = spec.named("controlled_serving")
    # the uncontrolled arm = the same declared system minus the control
    # plane: greedy stealing, default routing, single-request grabs.
    base_spec = dataclasses.replace(
        ctl_spec, governor=spec.GovernorSpec(kind="greedy"),
        router=spec.RouterSpec(kind="none"), batch=spec.BatchSpec())

    base_eng, base_out = serve(model, params, cfg, base_spec)
    print(f"uncontrolled: served={base_eng.stats.served} "
          f"local={base_eng.stats.locality_fraction:.0%} "
          f"stolen={base_eng.stats.stolen} "
          f"prefill_tokens={base_eng.stats.prefill_tokens}")

    rec = trace.TraceRecorder()
    ctl_eng, ctl_out = serve(model, params, cfg, ctl_spec, rec=rec)
    loop = ctl_eng.control
    print(f"controlled:   served={ctl_eng.stats.served} "
          f"local={ctl_eng.stats.locality_fraction:.0%} "
          f"stolen={ctl_eng.stats.stolen} "
          f"prefill_tokens={ctl_eng.stats.prefill_tokens}")
    print(f"controller:   {loop.snapshot()}")

    assert ctl_out == base_out, "control plane changed decoded tokens!"
    print("decoded tokens bit-identical under control: OK")
    assert ctl_eng.stats.prefill_tokens <= base_eng.stats.prefill_tokens, \
        "control plane should never re-prefill more than greedy stealing"

    # the controlled router's schedule replays deterministically from the
    # header-embedded spec alone — no factory, no rebuilt control loop
    # (scheduling only: payloads are opaque, the model does not re-run)
    t = rec.finish()
    res = trace.replay(t, assert_match=True)
    print(f"replayed controlled schedule from header spec: bit-identical "
          f"(executed={res.stats['executed']:.0f})")
    print(trace.render_timeline(t.events, num_workers=NUM_REPLICAS, width=2))
    print("\ncontrol serving smoke OK")


if __name__ == "__main__":
    main()
