"""Self-tuning serving: the control plane driving the serving engine.

    PYTHONPATH=src python examples/control_serving.py

Serves one stream of skewed requests through the ``ServingEngine`` twice —
uncontrolled (home routing, greedy stealing, one request per grab) and
controlled (``repro.control.ControlLoop``: cost-aware routing, adaptive
continuous batching, storm circuit-breaker) — and checks the contract that
makes online control safe to turn on: decoded tokens are bit-identical,
only the scheduling statistics move.  Finally records the controlled
router's behaviour as a trace and replays it to show controlled runs stay
deterministically replayable.
"""
import jax
import numpy as np

from repro import trace
from repro.configs import get_config, reduce_config
from repro.control import BatchGovernor, ControlLoop, CostRouter, StormBreaker
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine

NUM_REPLICAS = 2
N_REQUESTS = 10


def make_requests(cfg, seed=0):
    # skewed session affinity: most requests' KV caches live on replica 0
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_REQUESTS):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 14)))
        home = 0 if rng.random() < 0.8 else int(rng.integers(NUM_REPLICAS))
        reqs.append(Request(uid=i, tokens=toks, max_new=4, home_replica=home))
    return reqs


def serve(model, params, cfg, *, control=None, batch=1, rec=None):
    eng = ServingEngine(model, params, num_replicas=NUM_REPLICAS, max_seq=64,
                        policy="locality", batch=batch, control=control,
                        trace=rec)
    for r in make_requests(cfg):
        eng.submit(r)
    done = eng.run_until_drained()
    return eng, {r.uid: tuple(r.out_tokens) for r in done}


def main():
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=96)
    params = model.init_params(jax.random.key(0))

    base_eng, base_out = serve(model, params, cfg)
    print(f"uncontrolled: served={base_eng.stats.served} "
          f"local={base_eng.stats.locality_fraction:.0%} "
          f"stolen={base_eng.stats.stolen} "
          f"prefill_tokens={base_eng.stats.prefill_tokens}")

    loop = ControlLoop(
        router=CostRouter(spill_penalty=8.0),
        batcher=BatchGovernor(target_service=24.0, batch_cap=4),
        breaker=StormBreaker(width=2, cooldown=2, min_executed=2))
    rec = trace.TraceRecorder()
    ctl_eng, ctl_out = serve(model, params, cfg, control=loop, rec=rec)
    print(f"controlled:   served={ctl_eng.stats.served} "
          f"local={ctl_eng.stats.locality_fraction:.0%} "
          f"stolen={ctl_eng.stats.stolen} "
          f"prefill_tokens={ctl_eng.stats.prefill_tokens}")
    print(f"controller:   {loop.snapshot()}")

    assert ctl_out == base_out, "control plane changed decoded tokens!"
    print("decoded tokens bit-identical under control: OK")
    assert ctl_eng.stats.prefill_tokens <= base_eng.stats.prefill_tokens, \
        "control plane should never re-prefill more than greedy stealing"

    # the controlled router's schedule replays deterministically (scheduling
    # only: payloads are opaque, the model does not re-run)
    from repro.runtime import GreedySteal
    t = rec.finish()
    res = trace.replay(t, lambda tr: ControlLoop(
        router=CostRouter(spill_penalty=8.0),
        batcher=BatchGovernor(target_service=24.0, batch_cap=4),
        breaker=StormBreaker(width=2, cooldown=2, min_executed=2)).attach(
            trace.executor_from_meta(
                tr, governor=GreedySteal(),
                steal_penalty=lambda task, w: task.cost)))
    print(f"replayed controlled schedule: executed={res.stats['executed']:.0f}"
          f" (recorded {t.stats['executed']:.0f})")
    print(trace.render_timeline(t.events, num_workers=NUM_REPLICAS, width=2))
    print("\ncontrol serving smoke OK")


if __name__ == "__main__":
    main()
