"""Record → analyze → replay a runtime stencil sweep (the `make trace` smoke).

    PYTHONPATH=src python examples/trace_stencil.py

Runs one online Jacobi sweep through the locality runtime with a
``repro.trace.TraceRecorder`` attached, writes the trace to JSONL, renders
the per-worker steal timeline with storm detection, replays the recorded
submission trace and checks the scheduler statistics reproduce exactly,
and finally seeds a ``MeasuredPenalty`` governor from the measured service
times — the whole trace loop on a problem small enough for CI.

Every executor here is built from a declarative ``repro.spec.RuntimeSpec``,
so the recorded trace headers embed the full policy (schema v2) and both
replays run from the trace file alone — no factories.
"""
import os
import tempfile

import numpy as np

from repro import spec, trace
from repro.kernels.jacobi.ref import jacobi_sweep_ref
from repro.stencil.jacobi import run_runtime_sweep

NUM_DOMAINS = 4


def main():
    rng = np.random.default_rng(0)
    f = rng.standard_normal((80, 12, 16)).astype(np.float32)

    # -- record: one online sweep, slab tasks homed contiguously ------------
    sweep_spec = spec.RuntimeSpec(num_domains=NUM_DOMAINS)
    rec = trace.TraceRecorder()
    out, stats = run_runtime_sweep(f, di=5, spec=sweep_spec, trace=rec)
    assert np.array_equal(out, np.asarray(jacobi_sweep_ref(f))), "physics!"
    t = rec.finish()
    print(f"recorded: {t.n_tasks} slab tasks, {t.total_steps} rounds, "
          f"local={stats.local_fraction:.0%} steal={stats.steal_fraction:.0%}")

    path = os.path.join(tempfile.mkdtemp(prefix="repro-trace-"),
                        "stencil.trace.jsonl")
    trace.TraceWriter(path).write(t)
    t = trace.TraceReader(path).read()
    print(f"trace file: {path} ({os.path.getsize(path)} bytes, "
          f"schema v{trace.SCHEMA_VERSION})")

    # -- analyze: windowed storm detection + per-worker timeline ------------
    print()
    print(trace.render_timeline(t.events, num_workers=NUM_DOMAINS, width=2))
    storms = trace.detect_steal_storms(t.events, width=2)
    bursts = trace.detect_inline_bursts(t.events, width=2)
    print(f"\nsteal-storm windows: {[w.start for w in storms]}  "
          f"inline bursts: {[w.start for w in bursts]}")

    # -- replay: same arrivals, identical stats -----------------------------
    res = trace.replay(t, assert_match=True)
    print(f"replay: stats reproduce recorded run exactly "
          f"(executed={res.stats['executed']:.0f}, "
          f"local_fraction={res.stats['local_fraction']:.3f})")

    # -- storm demo: the contiguous sweep is storm-free by construction, so
    # drive a hot-domain-skewed arrival stream through the runtime to show
    # the detectors firing and the measured θ reacting to real steals.
    wl = trace.hot_skew(trace.poisson(rate=NUM_DOMAINS, steps=24,
                                      num_domains=NUM_DOMAINS, seed=1),
                        hot_domain=0, p_hot=0.85, seed=1)
    storm_spec = spec.RuntimeSpec(
        num_domains=NUM_DOMAINS,
        penalty=spec.PenaltySpec(kind="cost_factor", value=4.0),
        trace=spec.TraceSpec(record=True))
    built = storm_spec.build()
    ex = built.executor
    trace.drive(ex, wl)
    t2 = built.recorder.finish()
    print(f"\nskewed workload {wl.name}: {t2.n_tasks} tasks, "
          f"steal={ex.stats.steal_fraction:.0%}")
    print(trace.render_timeline(t2.events, num_workers=NUM_DOMAINS, width=4))
    storms = trace.detect_steal_storms(t2.events, width=4)
    print(f"steal-storm windows: {[w.start for w in storms]}")
    assert storms, "hot-skew stream should provoke a steal storm"
    trace.replay(t2, assert_match=True)      # rebuilt from the header spec

    # -- feedback: measured service times -> adaptive θ ---------------------
    gov = trace.MeasuredPenalty.from_trace(t2)
    print(f"measured feedback: local_cost≈{gov.local_cost_estimate:.2f}, "
          f"penalty≈{gov.penalty_estimate:.2f} -> θ={gov.threshold} "
          f"(from {gov.observed_local} local / {gov.observed_steals} "
          f"stolen observations)")
    print("\ntrace smoke OK")


if __name__ == "__main__":
    main()
