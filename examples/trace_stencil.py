"""Record → analyze → replay a runtime stencil sweep (the `make trace` smoke).

    PYTHONPATH=src python examples/trace_stencil.py

Runs one online Jacobi sweep through the locality runtime with a
``repro.trace.TraceRecorder`` attached, writes the trace to JSONL, renders
the per-worker steal timeline with storm detection, replays the recorded
submission trace and checks the scheduler statistics reproduce exactly,
and finally seeds a ``MeasuredPenalty`` governor from the measured service
times — the whole trace loop on a problem small enough for CI.
"""
import os
import tempfile

import numpy as np

from repro import trace
from repro.kernels.jacobi.ref import jacobi_sweep_ref
from repro.stencil.jacobi import run_runtime_sweep

NUM_DOMAINS = 4


def main():
    rng = np.random.default_rng(0)
    f = rng.standard_normal((80, 12, 16)).astype(np.float32)

    # -- record: one online sweep, slab tasks homed contiguously ------------
    rec = trace.TraceRecorder()
    out, stats = run_runtime_sweep(f, di=5, num_domains=NUM_DOMAINS,
                                   workers_per_domain=1, trace=rec)
    assert np.array_equal(out, np.asarray(jacobi_sweep_ref(f))), "physics!"
    t = rec.finish()
    print(f"recorded: {t.n_tasks} slab tasks, {t.total_steps} rounds, "
          f"local={stats.local_fraction:.0%} steal={stats.steal_fraction:.0%}")

    path = os.path.join(tempfile.mkdtemp(prefix="repro-trace-"),
                        "stencil.trace.jsonl")
    trace.TraceWriter(path).write(t)
    t = trace.TraceReader(path).read()
    print(f"trace file: {path} ({os.path.getsize(path)} bytes, "
          f"schema v{trace.SCHEMA_VERSION})")

    # -- analyze: windowed storm detection + per-worker timeline ------------
    print()
    print(trace.render_timeline(t.events, num_workers=NUM_DOMAINS, width=2))
    storms = trace.detect_steal_storms(t.events, width=2)
    bursts = trace.detect_inline_bursts(t.events, width=2)
    print(f"\nsteal-storm windows: {[w.start for w in storms]}  "
          f"inline bursts: {[w.start for w in bursts]}")

    # -- replay: same arrivals, identical stats -----------------------------
    res = trace.replay(t, assert_match=True)
    print(f"replay: stats reproduce recorded run exactly "
          f"(executed={res.stats['executed']:.0f}, "
          f"local_fraction={res.stats['local_fraction']:.3f})")

    # -- storm demo: the contiguous sweep is storm-free by construction, so
    # drive a hot-domain-skewed arrival stream through the runtime to show
    # the detectors firing and the measured θ reacting to real steals.
    from repro.runtime import Executor

    wl = trace.hot_skew(trace.poisson(rate=NUM_DOMAINS, steps=24,
                                      num_domains=NUM_DOMAINS, seed=1),
                        hot_domain=0, p_hot=0.85, seed=1)
    rec2 = trace.TraceRecorder()
    ex = rec2.attach(Executor(NUM_DOMAINS,
                              steal_penalty=lambda task, w: 4.0 * task.cost))
    trace.drive(ex, wl)
    t2 = rec2.finish()
    print(f"\nskewed workload {wl.name}: {t2.n_tasks} tasks, "
          f"steal={ex.stats.steal_fraction:.0%}")
    print(trace.render_timeline(t2.events, num_workers=NUM_DOMAINS, width=4))
    storms = trace.detect_steal_storms(t2.events, width=4)
    print(f"steal-storm windows: {[w.start for w in storms]}")
    assert storms, "hot-skew stream should provoke a steal storm"
    trace.replay(t2, lambda tr: trace.executor_from_meta(
        tr, steal_penalty=lambda task, w: 4.0 * task.cost), assert_match=True)

    # -- feedback: measured service times -> adaptive θ ---------------------
    gov = trace.MeasuredPenalty.from_trace(t2)
    print(f"measured feedback: local_cost≈{gov.local_cost_estimate:.2f}, "
          f"penalty≈{gov.penalty_estimate:.2f} -> θ={gov.threshold} "
          f"(from {gov.observed_local} local / {gov.observed_steals} "
          f"stolen observations)")
    print("\ntrace smoke OK")


if __name__ == "__main__":
    main()
