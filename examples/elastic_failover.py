"""Elastic failover scenario: lose devices mid-run, re-mesh, resume.

Storyline (all real code paths, CPU-runnable):
  1. train with checkpointing on the full "fleet";
  2. a pod row "fails" → plan_elastic_mesh computes the largest healthy
     rectangular mesh (model axis preserved, degraded data rows dropped);
  3. the locality schedule (data-pipeline shard ownership) is rebuilt for
     the survivor domains — tasks homed on dead domains are re-placed by
     the balance rule, everything else keeps locality;
  4. training resumes from the latest checkpoint and continues — losses
     continue from where they left off.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import numpy as np

from repro.configs import get_config, reduce_config
from repro.data.pipeline import make_batch_iterator
from repro.distributed.fault import (DeviceSet, StragglerMonitor,
                                     plan_elastic_mesh, rebuild_schedule)
from repro.models.model import build_model
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def main():
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=64)
    ckpt_dir = "/tmp/repro_elastic_ckpt"
    import shutil
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    def make_trainer(steps):
        return Trainer(model, make_batch_iterator(cfg.vocab_size, 32, 8, seed=7),
                       LoopConfig(total_steps=steps, checkpoint_every=10,
                                  checkpoint_dir=ckpt_dir, log_every=10),
                       AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=40))

    print("=== phase 1: healthy fleet, steps 0-20 ===")
    out1 = make_trainer(20).run(seed=0)

    print("\n=== failure injected: chip (pod 0, data row 3, model 7) dies ===")
    fleet = DeviceSet(pods=2, data=16, model=16,
                      failed=frozenset({(0, 3, 7)}))
    plan = plan_elastic_mesh(fleet)
    print(f"re-mesh plan: {plan['mesh_shape']} "
          f"(lost {plan['lost_fraction']:.1%} of the fleet; "
          f"dropped rows: every pod trimmed to {plan['mesh_shape'][1]} rows)")

    # rebuild the data-pipeline locality schedule for the survivor count
    n_old = 2 * 16
    n_new = plan["mesh_shape"][0] * plan["mesh_shape"][1]
    homes = np.arange(64) % n_old
    sched = rebuild_schedule(homes, np.ones(64), n_old, n_new)
    print(f"data-shard schedule rebuilt: locality={sched.locality_fraction:.0%} "
          f"imbalance={sched.imbalance:.1%} moved={sched.moved}")

    print("\n=== phase 2: resume on the degraded fleet, steps 20-40 ===")
    out2 = make_trainer(40).run(seed=0)    # restores step-20 checkpoint

    l1 = out1["losses"]
    l2 = out2["losses"]
    print(f"\nloss at failure: {l1[-1]:.4f}; first post-resume losses: "
          f"{[round(x, 4) for x in l2[:3]]}")
    assert l2[0] < l1[0], "resumed run should continue, not restart"
    mon = StragglerMonitor(num_domains=4)
    for _ in range(6):
        report = mon.update([1.0, 1.0, 1.02, 1.55])
    print(f"straggler monitor post-failure: domains {report['stragglers']} "
          f"flagged, shedding {report['shed_fraction']}")
    print("\nelastic failover complete: re-mesh + schedule rebuild + resume.")


if __name__ == "__main__":
    main()
