"""Observe a run end to end: spans -> metrics -> a Perfetto timeline.

    PYTHONPATH=src python examples/obs_timeline.py [--out out.perfetto-trace]

One observed, profiled run over the two-socket topology, then the whole
``repro.obs`` surface on its recorded trace:

  1. build a hierarchical (2x2-socket) policy with ``ObsSpec(enabled=True,
     profile=True)`` — the executor carries the hot-path timers and the
     trace header (schema v4) names the observation;
  2. drive a hot-skew workload (domain 0 overloaded, so the run steals —
     including cross-socket steals the timeline draws as flow arrows);
  3. ``observe()`` the trace: per-task span trees, registry counters and
     log-bucket histograms, exact nearest-rank p50/p95/p99;
  4. print the self-profiled scheduler overhead (ns per decision for
     submit-route / steal-scan / batch-grab / event-append);
  5. ``export_chrome_trace`` -> a ``.perfetto-trace`` JSON: open it at
     https://ui.perfetto.dev (or chrome://tracing) — one process track per
     locality domain, one thread lane per worker, queue-depth counters,
     and steal arrows from victim queue to thief execution slice.

The export is pure post-processing of the recorded trace: running this
example twice produces byte-identical timelines (only the profiler's wall
timings differ — they are measurements, not schedule inputs).  ``--out``
picks the destination; the default lands in ``artifacts/`` (gitignored)
so example runs don't litter the checkout.
"""
import argparse
import os

from repro import obs, spec, trace

DEFAULT_OUT = os.path.join("artifacts", "obs_timeline.perfetto-trace")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"timeline destination (default: {DEFAULT_OUT})")
    out = ap.parse_args().out
    if os.path.dirname(out):
        os.makedirs(os.path.dirname(out), exist_ok=True)

    s = spec.RuntimeSpec(
        num_domains=4,
        topology=spec.TopologySpec(kind="grouped", groups=(2, 2),
                                   near=1.0, far=10.0),
        penalty=spec.PenaltySpec(kind="constant", value=4.0),
        batch=spec.BatchSpec(kind="fixed", size=2),
        trace=spec.TraceSpec(record=True),
        obs=spec.ObsSpec(enabled=True, profile=True))
    built = s.build()

    wl = trace.lognormal_costs(
        trace.hot_skew(trace.poisson(rate=4, steps=32, num_domains=4,
                                     seed=7),
                       hot_domain=0, p_hot=0.8, seed=7),
        median=2.0, sigma=0.75, seed=7)
    trace.drive(built.executor, wl)
    t = built.recorder.finish()

    rep = built.obs.report(t)
    m = rep.registry.snapshot()
    print(f"observed {m['tasks_observed']}/{m['tasks_submitted']} tasks "
          f"({m['tasks_unobserved']} outside the event window); "
          f"{m['steals']} steals, {m['remote_steals']} cross-socket")
    for metric in ("wait", "sojourn", "service"):
        p = rep.percentiles[metric]
        print(f"  {metric:8s} p50={p['p50']:g} p95={p['p95']:g} "
              f"p99={p['p99']:g}  (exact nearest-rank, steps)")

    print("self-profiled scheduler overhead (ns/decision):")
    for path, ns in rep.profile["ns_per_call"].items():
        print(f"  {path:13s} {ns:8.0f}  ({rep.profile['calls'][path]} calls)")

    # one task's span tree, for flavor: the deepest sojourn
    worst = max(rep.spans, key=lambda sp: sp.duration)
    print(f"slowest task #{worst.attrs['uid']} "
          f"(home={worst.attrs['home']}, sojourn={worst.duration:g}):")
    for c in worst.children:
        print(f"  {c.name:7s} [{c.start:g} .. {c.end:g}] {dict(c.attrs)}")

    obs.export_chrome_trace(t, out)
    print(f"wrote {out} — open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
