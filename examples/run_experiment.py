"""An experiment is a JSON file: policy + workload + seeds, run end to end.

    PYTHONPATH=src python examples/run_experiment.py [experiment.json|name]

``repro.spec.ExperimentSpec`` completes what ``spec_policies.py`` started:
where a policy file names *how* to schedule, an experiment file also names
*what* arrives (the workload block) and *how the run is conducted*
(repeats, drain budget).  This example

  1. loads a checked-in experiment (default: the registry's
     ``replay_hot_skew`` — the trace-replay benchmark's hot-skew cell),
  2. runs it: the declared policy is built and the declared workload is
     driven through it while recording,
  3. replays the recorded trace from its header alone and asserts the
     stats reproduce bit-identically (the conformance gate every
     ``specs/experiments/*.json`` file passes in CI),
  4. checkpoints the governor's learned θ state into a new spec
     (``GovernorStateSpec``) — declarative mid-run restore, no trace
     re-read — and prints the derived experiment JSON ready to check in.
"""
import dataclasses
import os
import sys

from repro import spec, trace


def main():
    arg = sys.argv[1] if len(sys.argv) > 1 else "replay_hot_skew"
    if os.path.exists(arg):
        exp = spec.load_experiment(arg)
        print(f"experiment file: {arg}")
    else:
        exp = spec.experiment(arg)
        print(f"registry experiment: {arg}")
    wl = exp.workload
    print(f"  workload: kind={wl.kind} steps={wl.steps} seed={wl.seed}"
          f" skew={wl.skew is not None} heavy_tail={wl.costs is not None}")
    print(f"  policy: governor={exp.policy.governor.kind}"
          f" router={exp.policy.router.kind} seed={exp.policy.seed}")

    result = exp.run()
    run = result.primary
    s = run.executor.stats
    print(f"ran {result.workload.name}: executed={s.executed} "
          f"local={s.local_fraction:.0%} steal={s.steal_fraction:.0%} "
          f"penalty={s.steal_penalty:.0f}")

    # the trace names the whole experiment; its header alone replays it
    t = trace.loads_lines(trace.dumps_lines(run.trace))
    assert spec.ExperimentSpec.from_dict(t.experiment_dict) == exp
    replayed = trace.replay(t, assert_match=True)
    print(f"header-only replay: bit-identical stats "
          f"({replayed.matches_recorded})")

    # derive a measured-governor variant seeded from this run's trace —
    # the learned state is spec data, so the variant is pure JSON
    seeded = trace.MeasuredPenalty.from_trace(t)
    variant = dataclasses.replace(
        exp, policy=dataclasses.replace(
            exp.policy,
            governor=spec.GovernorSpec(
                kind="measured",
                state=spec.GovernorStateSpec.from_governor(seeded))))
    assert spec.ExperimentSpec.from_json(variant.to_json()) == variant
    theta = variant.policy.governor.state
    print(f"derived measured-θ variant (penalty≈{theta.penalty_estimate:.2f}"
          f" / local cost≈{theta.task_cost:.2f}); as JSON:")
    print("\n".join(variant.to_json().splitlines()[:8]) + "\n  ...")


if __name__ == "__main__":
    main()
