"""Serving scenario: the locality-queue request router vs naive policies.

Multi-turn chat sessions have KV/prefix-cache affinity to the replica that
served their first turn; the paper's router (local queue first, steal when
idle) minimizes cache-miss re-prefills while keeping replicas busy.

    PYTHONPATH=src python examples/serve_router.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine


def workload(cfg, n=18, replicas=3, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 20)))
        # 70% are follow-up turns with an existing cache home; skew the homes
        # (replica 0 is hot) so stealing has something to balance
        if rng.random() < 0.7:
            home = int(rng.choice([0, 0, 1, 2]))
        else:
            home = -1
        reqs.append(Request(uid=i, tokens=toks, max_new=6, home_replica=home))
    return reqs


def main():
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=96)
    params = model.init_params(jax.random.key(0))

    print(f"{'policy':14s} {'local%':>7s} {'steals':>7s} {'prefill_toks':>13s}")
    baseline = None
    for policy in ("single_queue", "round_robin", "locality"):
        eng = ServingEngine(model, params, num_replicas=3, max_seq=64,
                            policy=policy)
        for r in workload(cfg):
            eng.submit(r)
        done = eng.run_until_drained()
        s = eng.stats
        if baseline is None:
            baseline = {r.uid: tuple(r.out_tokens) for r in done}
        else:
            assert baseline == {r.uid: tuple(r.out_tokens) for r in done}, \
                "routing must not change results"
        print(f"{policy:14s} {s.locality_fraction:7.0%} {s.stolen:7d} "
              f"{s.prefill_tokens:13d}")
    print("\nidentical outputs under every policy; locality routing "
          "maximizes cache hits (local%), stealing keeps replicas busy.")


if __name__ == "__main__":
    main()
