"""Paper Fig. 3: all scheduling-policy columns on the three test beds.

Columns reproduced (labels as in the paper):
  refs       : static worksharing with serial / round-robin / first-touch
  omp_task   : plain tasking {s, s-1} x {ijk, kji}
  omp_lq     : locality queues {s, s-1} x {ijk, kji}
  tbb        : parallel_for {p, n-p} x {a, n-a}
  tbb_lq     : TBB locality queues {p, n-p}

Emits CSV: system,column,label,median_mlups,q25,q75,local_frac,steal_frac
"""
from __future__ import annotations

import numpy as np

from repro.core import (SMALL_GRID, PAPER_GRID, TESTBED, OpenMPLocalityQueues,
                        OpenMPTasking, StaticWorksharing, TBBLocalityQueues,
                        TBBParallelFor, place, run_samples, summarize,
                        tbb_first_touch)


def run(grid=SMALL_GRID, samples: int = 5, seed0: int = 0):
    rows = []
    for name, topo in TESTBED.items():
        # reference lines
        for pl, label in [("serial", "ref_serial"),
                          ("round_robin", "ref_round_robin"),
                          ("static", "ref_first_touch")]:
            homes = place(pl, grid, topo)
            s = summarize(run_samples(grid, topo, StaticWorksharing, homes,
                                      n_samples=max(samples // 2, 2),
                                      seed0=seed0))
            rows.append((name, "refs", label, s))
        # OpenMP tasking / locality queues
        for col, mk in [("omp_task", OpenMPTasking),
                        ("omp_lq", OpenMPLocalityQueues)]:
            for init, init_lbl in [("static", "s"), ("static1", "s-1")]:
                for order in ("ijk", "kji"):
                    homes = place(init, grid, topo)
                    s = summarize(run_samples(
                        grid, topo, lambda m=mk, o=order: m(submit_order=o),
                        homes, n_samples=samples, seed0=seed0))
                    rows.append((name, col, f"{init_lbl}/{order}", s))
        # TBB
        for pinned, p_lbl in [(True, "p"), (False, "n-p")]:
            for aff, a_lbl in [(True, "a"), (False, "n-a")]:
                def mk_tbb(a=aff, s0=seed0):
                    return None
                # fresh dynamic first-touch per sample set
                rng = np.random.default_rng(seed0 + 17)
                homes, threads = tbb_first_touch(grid, topo, rng)
                s = summarize(run_samples(
                    grid, topo,
                    lambda a=aff, t=threads: TBBParallelFor(affinity=a, replay=t),
                    homes, n_samples=samples, pinned=pinned, seed0=seed0))
                rows.append((name, "tbb", f"{p_lbl}/{a_lbl}", s))
            rng = np.random.default_rng(seed0 + 17)
            homes, _ = tbb_first_touch(grid, topo, rng)
            s = summarize(run_samples(grid, topo, TBBLocalityQueues, homes,
                                      n_samples=samples, pinned=pinned,
                                      seed0=seed0))
            rows.append((name, "tbb_lq", p_lbl, s))
    return rows


def main(grid=SMALL_GRID, samples: int = 5) -> list[str]:
    lines = ["system,column,label,median_mlups,q25,q75,local_frac,steal_frac"]
    for name, col, label, s in run(grid, samples):
        lines.append(f"{name},{col},{label},{s['median_mlups']:.0f},"
                     f"{s['q25']:.0f},{s['q75']:.0f},"
                     f"{s['local_fraction']:.3f},{s['steal_fraction']:.3f}")
    return lines


if __name__ == "__main__":
    import sys
    full = "--full" in sys.argv
    for line in main(grid=PAPER_GRID if full else SMALL_GRID,
                     samples=15 if full else 5):
        print(line)
