"""Control-plane A/B: controlled vs uncontrolled policies on recorded traces.

    PYTHONPATH=src python -m benchmarks.control_plane [--fast]

Each scenario is executed once (greedy baseline, heavy-tailed costs) while
``repro.trace`` records the submission stream; the *same* arrival sequence
is then replayed twice with ``reroute=True`` (routing re-decided — the
submit side is the treatment here, unlike ``benchmarks.trace_replay``):

  uncontrolled — the recorded configuration: home routing, greedy cyclic
                 stealing, single-task grabs.
  controlled   — the full ``repro.control`` plane: ``CostRouter``
                 (least-backlog submit + spill), ``BatchGovernor``
                 (adaptive batch grabs), ``StormBreaker`` (windowed steal
                 circuit-breaker) over a ``cost_weighted`` steal scan.

Throughput is tasks per *makespan* round (the last execution event's step —
the forced trailing rounds of a replay are idle by construction and carry
no information about the policy).  Storm windows are counted by the same
``detect_steal_storms`` detector the breaker runs online.  Per-task
counterfactuals (``compare_replays``) report how many individual tasks the
control plane helped vs hurt, not just the aggregates.

The acceptance gate is asserted inline: on every scenario the controlled
arm must achieve >= the uncontrolled throughput with <= its steal-storm
window count (and strictly fewer storms somewhere overall).

CSV: scenario,arm,tasks,makespan,throughput,local_frac,steal_frac,
steal_penalty,storm_windows,mean_wait,mean_sojourn,improved,regressed

``main(json_path=...)`` (default ``BENCH_control.json`` as a script) also
writes the machine-readable summary + controller state per scenario.

Both arms are declarative ``repro.spec`` policies and every scenario's
workload is the declarative block of the ``control_*`` named experiments
(``repro.spec.control_workloads``): this module is a thin driver that owns
no workload construction.  The recorded baseline embeds its spec in the
trace header (the determinism gate is a bare ``replay(trace,
assert_match=True)`` — the acceptance criterion that a v2 trace alone
reconstructs the recorded system), and the controlled arm is the registry
policy ``controlled_replay``.  ``main(spec=...)`` substitutes any spec as
the controlled arm (``benchmarks.run --spec/--policy``; ``gates=False``
then skips the controlled-must-win assertions, since an arbitrary policy
makes no such promise).
"""
from __future__ import annotations

import dataclasses
import json
import sys

NUM_DOMAINS = 4
STEAL_PENALTY = 6.0      # fixed nonlocal cost per stolen task
COST_MEDIAN = 2.0        # lognormal service-cost median
COST_SIGMA = 0.75
STORM_WIDTH = 8
SCENARIOS = ("bursty", "diurnal", "hot_skew")


def _experiments(steps: int, seed: int):
    """scenario -> recording experiment: the ``control_*`` workload block
    under the shared ``replay_baseline`` recording policy (the same
    baseline ``benchmarks.trace_replay`` records under)."""
    from repro import spec as rspec

    base = dataclasses.replace(rspec.named("replay_baseline"), seed=seed)
    assert (base.num_domains == NUM_DOMAINS
            and base.penalty.value == STEAL_PENALTY), \
        "benchmark constants drifted from the replay_baseline registry policy"
    workloads = rspec.control_workloads(steps=steps, seed=seed)
    assert tuple(workloads) == SCENARIOS and all(
        wl.costs.median == COST_MEDIAN and wl.costs.sigma == COST_SIGMA
        for wl in workloads.values()), \
        "benchmark constants drifted from the control_* experiments"
    return {name: rspec.ExperimentSpec(policy=base, workload=wl)
            for name, wl in workloads.items()}


def _controlled_factory(spec):
    """Replay factory for the controlled arm: build ``spec`` fresh and keep
    its control loop reachable for the benchmark's snapshot."""
    def factory(trace):
        built = spec.build()
        built.executor._control_loop = built.control
        return built.executor
    return factory


def _measure(result):
    from repro.trace import detect_steal_storms

    ex = result.executor
    s = ex.stats
    execs = [e for e in ex.events if e.kind in ("run", "steal", "inline")]
    makespan = max(e.step for e in execs) if execs else ex.step_count
    times = result.task_times().values()
    return {
        "tasks": s.executed,
        "makespan": makespan,
        "throughput": round(s.executed / max(makespan, 1), 4),
        "local_fraction": round(s.local_fraction, 4),
        "steal_fraction": round(s.steal_fraction, 4),
        "steal_penalty": s.steal_penalty,
        "storm_windows": len(detect_steal_storms(ex.events,
                                                 width=STORM_WIDTH)),
        "mean_wait": round(sum(t.wait for t in times) / max(len(times), 1), 4),
        "mean_sojourn": round(sum(t.sojourn for t in times)
                              / max(len(times), 1), 4),
    }


def main(steps: int = 48, seed: int = 0,
         json_path: str | None = None, spec=None,
         gates: bool = True) -> list[str]:
    from repro import spec as rspec
    from repro.trace import compare_replays, replay

    controlled = (spec if spec is not None
                  else rspec.named("controlled_replay"))
    controlled = dataclasses.replace(controlled, seed=seed)
    lines = ["scenario,arm,tasks,makespan,throughput,local_frac,steal_frac,"
             "steal_penalty,storm_windows,mean_wait,mean_sojourn,"
             "improved,regressed"]
    results: dict[str, dict] = {}
    storms_reduced = 0
    for scen, exp in _experiments(steps, seed).items():
        trace = exp.run().primary.trace

        # determinism gate first — and the spec acceptance criterion: the
        # v2 header alone (no executor argument, no factory) reconstructs
        # the recorded system and reproduces its stats bit-for-bit.
        replay(trace, assert_match=True)

        un = replay(trace, reroute=True)
        co = replay(trace, _controlled_factory(controlled), reroute=True)
        delta = compare_replays(un, co)

        u, c = _measure(un), _measure(co)
        if gates:
            assert c["throughput"] >= u["throughput"], (scen, u, c)
            assert c["storm_windows"] <= u["storm_windows"], (scen, u, c)
        storms_reduced += u["storm_windows"] - c["storm_windows"]
        assert u["tasks"] == c["tasks"] == trace.n_tasks

        for arm, m, imp, reg in (("uncontrolled", u, "", ""),
                                 ("controlled", c, delta.improved,
                                  delta.regressed)):
            lines.append(
                f"{scen},{arm},{m['tasks']},{m['makespan']},"
                f"{m['throughput']},{m['local_fraction']},"
                f"{m['steal_fraction']},{m['steal_penalty']:.0f},"
                f"{m['storm_windows']},{m['mean_wait']},{m['mean_sojourn']},"
                f"{imp},{reg}")
        results[scen] = {
            "uncontrolled": u, "controlled": c,
            # a --spec policy may declare no control plane at all
            "controller": (co.executor._control_loop.snapshot()
                           if co.executor._control_loop is not None else {}),
            "tasks_improved": delta.improved,
            "tasks_regressed": delta.regressed,
        }
    if gates:
        assert storms_reduced > 0, \
            "control plane never reduced a storm window"
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "control_plane", "steps": steps,
                       "seed": seed, "steal_penalty": STEAL_PENALTY,
                       "results": results}, fh, indent=2)
            fh.write("\n")
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(steps=24 if fast else 48,
                   json_path="BENCH_control.json"):
        print(ln)
