"""Governor A/B on identical replayed traces: record once, replay per policy.

    PYTHONPATH=src python -m benchmarks.trace_replay [--fast]

The methodological upgrade over ``benchmarks.runtime_throughput``: instead
of re-generating "the same" workload per policy, each scenario is executed
*once* (greedy baseline) while ``repro.trace`` records the submission
stream; every governor is then replayed against that recorded trace, so
all policies see the bit-identical arrival sequence — the controlled A/B
the paper's Fig. 3 comparison wants.

Per scenario the benchmark also:
  * asserts the baseline replay reproduces the recorded ``RuntimeStats``
    exactly (deterministic-replay acceptance check), and
  * seeds a ``MeasuredPenalty`` governor from the recorded run/steal
    service times and reports the θ it derives (vs the static-hint
    adaptive governor) — the measured-feedback acceptance check.

Scenarios (``repro.trace.workloads.standard_scenarios``): poisson steady
traffic, bursty MMPP storms, a diurnal ramp, and hot-domain skew — each
with heavy-tailed ``lognormal_costs`` (median 2, the long-prefill shape)
and a *fixed* per-steal penalty (a fixed-prefix re-prefill).  That split is
what makes measurement matter: the static-hint adaptive governor prices θ
in unit-cost tasks (θ = penalty / 1), while ``MeasuredPenalty`` learns the
real ~2.6 mean local cost and lands on a correspondingly lower θ — same
penalty, different (correct) depth threshold.

The recorded baseline and every replay arm are built from
``repro.spec.RuntimeSpec`` values (the baseline spec rides in the trace
header, so the determinism gate is a bare ``replay(trace,
assert_match=True)`` — no hand-written factory).  ``main(spec=...)``
replaces the governor grid with one externally supplied spec
(``benchmarks.run --spec/--policy``).

CSV: scenario,governor,tasks,local_frac,steal_frac,steal_penalty,idle_polls,steps,theta
"""
from __future__ import annotations

import dataclasses
import sys

NUM_DOMAINS = 4
STEAL_PENALTY = 6.0      # fixed nonlocal cost per stolen task
COST_MEDIAN = 2.0        # lognormal service-cost median (sigma below)
COST_SIGMA = 0.75


def _base_spec(seed: int):
    """The greedy-baseline recording configuration: the single registry
    definition (``replay_baseline``) both replay benchmarks record under,
    re-seeded (recorded into the trace header, so replay needs no factory)."""
    from repro import spec

    base = dataclasses.replace(spec.named("replay_baseline"), seed=seed)
    assert (base.num_domains == NUM_DOMAINS
            and base.penalty.value == STEAL_PENALTY), \
        "benchmark constants drifted from the replay_baseline registry policy"
    return base


def _record_baseline(workload, seed: int):
    from repro.trace import drive

    built = _base_spec(seed).build()
    drive(built.executor, workload)
    return built.recorder.finish()


def _arms(trace, seed: int):
    """Replay arm -> spec.  Three arms are pure spec edits of the baseline;
    the measured arm overrides the governor with an *instance* seeded from
    the recorded service times (``MeasuredPenalty.from_trace`` state is
    data-derived, not configuration)."""
    from repro.spec import GovernorSpec, TraceSpec
    from repro.trace import MeasuredPenalty

    base = dataclasses.replace(_base_spec(seed), trace=TraceSpec())

    def gov(**kw):
        return dataclasses.replace(base, governor=GovernorSpec(**kw))

    return {
        "static": (gov(kind="none"), None),
        "greedy": (base, None),
        "adaptive": (gov(kind="adaptive", penalty_hint=STEAL_PENALTY), None),
        "measured": (base, MeasuredPenalty.from_trace(trace)),
    }


def _scenarios(steps: int, seed: int):
    from repro.trace import lognormal_costs, standard_scenarios

    return {name: lognormal_costs(wl, median=COST_MEDIAN, sigma=COST_SIGMA,
                                  seed=seed + i)
            for i, (name, wl) in enumerate(
                standard_scenarios(NUM_DOMAINS, steps, seed).items())}


def main(steps: int = 48, seed: int = 0, spec=None) -> list[str]:
    from repro.trace import replay

    lines = ["scenario,governor,tasks,local_frac,steal_frac,steal_penalty,"
             "idle_polls,steps,theta"]
    for scen, workload in _scenarios(steps, seed).items():
        trace = _record_baseline(workload, seed)

        # determinism gate: the header-embedded spec must reproduce the
        # recorded stats bit-for-bit before any A/B is meaningful.
        base = replay(trace, assert_match=True)
        again = replay(trace)
        assert base.stats == again.stats, f"replay nondeterministic on {scen}"

        if spec is not None:
            arms = {"spec": (dataclasses.replace(spec, seed=seed), None)}
        else:
            arms = _arms(trace, seed)
        for name, (arm_spec, gov_override) in arms.items():
            res = replay(trace, lambda tr: arm_spec.build(
                governor=gov_override).executor)
            s = res.executor.stats
            assert s.executed == trace.n_tasks, (scen, name, s.executed)
            gov = res.executor.governor
            gov = getattr(gov, "inner", None) or gov
            theta = getattr(gov, "threshold", "")
            lines.append(
                f"{scen},{name},{s.executed},{s.local_fraction:.3f},"
                f"{s.steal_fraction:.3f},{s.steal_penalty:.0f},"
                f"{s.idle_polls},{res.executor.step_count},{theta}")
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(steps=24 if fast else 48):
        print(ln)
