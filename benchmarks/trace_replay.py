"""Governor A/B on identical replayed traces: record once, replay per policy.

    PYTHONPATH=src python -m benchmarks.trace_replay [--fast]

The methodological upgrade over ``benchmarks.runtime_throughput``: instead
of re-generating "the same" workload per policy, each scenario is executed
*once* (greedy baseline) while ``repro.trace`` records the submission
stream; every governor is then replayed against that recorded trace, so
all policies see the bit-identical arrival sequence — the controlled A/B
the paper's Fig. 3 comparison wants.

Per scenario the benchmark also:
  * asserts the baseline replay reproduces the recorded ``RuntimeStats``
    exactly (deterministic-replay acceptance check), and
  * seeds a ``MeasuredPenalty`` governor from the recorded run/steal
    service times and reports the θ it derives (vs the static-hint
    adaptive governor) — the measured-feedback acceptance check.

Scenarios (``repro.trace.workloads.standard_scenarios``): poisson steady
traffic, bursty MMPP storms, a diurnal ramp, and hot-domain skew — each
with heavy-tailed ``lognormal_costs`` (median 2, the long-prefill shape)
and a *fixed* per-steal penalty (a fixed-prefix re-prefill).  That split is
what makes measurement matter: the static-hint adaptive governor prices θ
in unit-cost tasks (θ = penalty / 1), while ``MeasuredPenalty`` learns the
real ~2.6 mean local cost and lands on a correspondingly lower θ — same
penalty, different (correct) depth threshold.

CSV: scenario,governor,tasks,local_frac,steal_frac,steal_penalty,idle_polls,steps,theta
"""
from __future__ import annotations

import sys

NUM_DOMAINS = 4
STEAL_PENALTY = 6.0      # fixed nonlocal cost per stolen task
COST_MEDIAN = 2.0        # lognormal service-cost median (sigma below)
COST_SIGMA = 0.75


def _steal_penalty(task, worker) -> float:
    return STEAL_PENALTY


def _record_baseline(workload, seed: int):
    from repro.runtime import Executor
    from repro.trace import TraceRecorder, drive

    rec = TraceRecorder()
    ex = rec.attach(Executor(NUM_DOMAINS, steal_order="cyclic",
                             steal_penalty=_steal_penalty, seed=seed))
    drive(ex, workload)
    return rec.finish()


def _governors(trace):
    from repro.runtime import AdaptiveSteal, GreedySteal, NoSteal
    from repro.trace import MeasuredPenalty

    return {
        "static": NoSteal(),
        "greedy": GreedySteal(),
        "adaptive": AdaptiveSteal(penalty_hint=STEAL_PENALTY),
        "measured": MeasuredPenalty.from_trace(trace),
    }


def _scenarios(steps: int, seed: int):
    from repro.trace import lognormal_costs, standard_scenarios

    return {name: lognormal_costs(wl, median=COST_MEDIAN, sigma=COST_SIGMA,
                                  seed=seed + i)
            for i, (name, wl) in enumerate(
                standard_scenarios(NUM_DOMAINS, steps, seed).items())}


def main(steps: int = 48, seed: int = 0) -> list[str]:
    from repro.trace import executor_from_meta, replay

    lines = ["scenario,governor,tasks,local_frac,steal_frac,steal_penalty,"
             "idle_polls,steps,theta"]
    for scen, workload in _scenarios(steps, seed).items():
        trace = _record_baseline(workload, seed)

        # determinism gate: a policy-equivalent replay must reproduce the
        # recorded stats bit-for-bit before any A/B is meaningful.
        base = replay(trace, lambda tr: executor_from_meta(
            tr, steal_penalty=_steal_penalty), assert_match=True)
        again = replay(trace, lambda tr: executor_from_meta(
            tr, steal_penalty=_steal_penalty))
        assert base.stats == again.stats, f"replay nondeterministic on {scen}"

        for name, gov in _governors(trace).items():
            res = replay(trace, lambda tr: executor_from_meta(
                tr, governor=gov, steal_penalty=_steal_penalty))
            s = res.executor.stats
            assert s.executed == trace.n_tasks, (scen, name, s.executed)
            theta = getattr(gov, "threshold", "")
            lines.append(
                f"{scen},{name},{s.executed},{s.local_fraction:.3f},"
                f"{s.steal_fraction:.3f},{s.steal_penalty:.0f},"
                f"{s.idle_polls},{res.executor.step_count},{theta}")
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(steps=24 if fast else 48):
        print(ln)
