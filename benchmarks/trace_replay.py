"""Governor A/B on identical replayed traces: record once, replay per policy.

    PYTHONPATH=src python -m benchmarks.trace_replay [--fast]

The methodological upgrade over ``benchmarks.runtime_throughput``: instead
of re-generating "the same" workload per policy, each scenario is executed
*once* (greedy baseline) while ``repro.trace`` records the submission
stream; every governor is then replayed against that recorded trace, so
all policies see the bit-identical arrival sequence — the controlled A/B
the paper's Fig. 3 comparison wants.

Per scenario the benchmark also:
  * asserts the baseline replay reproduces the recorded ``RuntimeStats``
    exactly (deterministic-replay acceptance check), and
  * seeds a ``MeasuredPenalty`` governor from the recorded run/steal
    service times and reports the θ it derives (vs the static-hint
    adaptive governor) — the measured-feedback acceptance check.

Scenarios (``repro.trace.workloads.standard_scenarios``): poisson steady
traffic, bursty MMPP storms, a diurnal ramp, and hot-domain skew — each
with heavy-tailed ``lognormal_costs`` (median 2, the long-prefill shape)
and a *fixed* per-steal penalty (a fixed-prefix re-prefill).  That split is
what makes measurement matter: the static-hint adaptive governor prices θ
in unit-cost tasks (θ = penalty / 1), while ``MeasuredPenalty`` learns the
real ~2.6 mean local cost and lands on a correspondingly lower θ — same
penalty, different (correct) depth threshold.

Both the scenarios and the recorded baseline are the ``replay_*`` named
experiments (``repro.spec.replay_experiments``): this module is a thin
driver that runs each experiment to record its trace, then replays the
governor grid against it.  Every replay arm — including the measured one,
whose learned θ inputs ride in a declarative ``GovernorStateSpec``
snapshot — is a pure spec edit of the experiment's policy (the baseline
spec rides in the trace header, so the determinism gate is a bare
``replay(trace, assert_match=True)`` — no hand-written factory).
``main(spec=...)`` replaces the governor grid with one externally supplied
spec (``benchmarks.run --spec/--policy``).

CSV: scenario,governor,tasks,local_frac,steal_frac,steal_penalty,idle_polls,steps,theta
"""
from __future__ import annotations

import dataclasses
import sys

NUM_DOMAINS = 4
STEAL_PENALTY = 6.0      # fixed nonlocal cost per stolen task
COST_MEDIAN = 2.0        # lognormal service-cost median (sigma below)
COST_SIGMA = 0.75


def _experiments(steps: int, seed: int):
    """scenario -> the ``replay_*`` named experiment, re-parameterized
    (workload + recording policy in one declarative block)."""
    from repro.spec import replay_experiments

    exps = replay_experiments(steps=steps, seed=seed)
    for exp in exps.values():
        assert (exp.policy.num_domains == NUM_DOMAINS
                and exp.policy.penalty.value == STEAL_PENALTY
                and exp.workload.costs.median == COST_MEDIAN
                and exp.workload.costs.sigma == COST_SIGMA), \
            "benchmark constants drifted from the replay_* experiments"
    return exps


def _arms(trace, base):
    """Replay arm -> spec: pure edits of the experiment's policy.  The
    measured arm seeds its governor from the recorded service times
    (``MeasuredPenalty.from_trace``), snapshotted into a declarative
    ``GovernorStateSpec`` — data-derived state, serialized as spec."""
    from repro.spec import GovernorSpec, GovernorStateSpec, TraceSpec
    from repro.trace import MeasuredPenalty

    base = dataclasses.replace(base, trace=TraceSpec())

    def gov(**kw):
        return dataclasses.replace(base, governor=GovernorSpec(**kw))

    measured = GovernorStateSpec.from_governor(
        MeasuredPenalty.from_trace(trace))
    return {
        "static": gov(kind="none"),
        "greedy": base,
        "adaptive": gov(kind="adaptive", penalty_hint=STEAL_PENALTY),
        "measured": gov(kind="measured", state=measured),
    }


def main(steps: int = 48, seed: int = 0, spec=None) -> list[str]:
    from repro.trace import replay

    lines = ["scenario,governor,tasks,local_frac,steal_frac,steal_penalty,"
             "idle_polls,steps,theta"]
    for scen, exp in _experiments(steps, seed).items():
        trace = exp.run().primary.trace

        # determinism gate: the header-embedded spec must reproduce the
        # recorded stats bit-for-bit before any A/B is meaningful.
        base = replay(trace, assert_match=True)
        again = replay(trace)
        assert base.stats == again.stats, f"replay nondeterministic on {scen}"

        if spec is not None:
            arms = {"spec": dataclasses.replace(spec, seed=seed)}
        else:
            arms = _arms(trace, exp.policy)
        for name, arm_spec in arms.items():
            res = replay(trace, lambda tr: arm_spec.build().executor)
            s = res.executor.stats
            assert s.executed == trace.n_tasks, (scen, name, s.executed)
            gov = res.executor.governor
            gov = getattr(gov, "inner", None) or gov
            theta = getattr(gov, "threshold", "")
            lines.append(
                f"{scen},{name},{s.executed},{s.local_fraction:.3f},"
                f"{s.steal_fraction:.3f},{s.steal_penalty:.0f},"
                f"{s.idle_polls},{res.executor.step_count},{theta}")
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(steps=24 if fast else 48):
        print(ln)
