"""Online runtime throughput: scheduling policies x arrival scenarios.

    PYTHONPATH=src python -m benchmarks.runtime_throughput [--fast]

Drives the ``repro.runtime`` executor with three arrival patterns and four
policies and reports the locality/balance trade-off each policy strikes —
the online analogue of the paper's Fig. 3 policy comparison:

  policies
    static    — route to home, never steal (OpenMP ``schedule(static)``:
                pure locality, imbalance shows up as idle polls / steps)
    tasking   — round-robin routing, greedy stealing (plain OpenMP tasking:
                balanced, locality accidental ≈ 1/num_domains)
    locality  — route to home, greedy cyclic stealing (the paper's §2.2
                locality queues: balance over locality)
    adaptive  — route to home, depth-thresholded stealing tracking the steal
                penalty (``runtime.AdaptiveSteal``, beyond the paper)

  scenarios (task homes + arrival cadence; identical streams per policy)
    uniform   — homes uniform over domains, steady arrivals
    bursty    — large synchronized bursts separated by idle rounds
    skewed    — 80% of tasks homed on domain 0 (one hot replica/socket)

Each stolen task pays an abstract nonlocal penalty (STEAL_PENALTY cost
units ≈ a prefix re-prefill); ``steps`` is the number of scheduling rounds
until drained (the discrete makespan proxy).

CSV: scenario,policy,tasks,local_frac,steal_frac,steal_penalty,idle_polls,steps

Alongside the CSV, ``main(json_path=...)`` (default ``BENCH_runtime.json``
when run as a script) writes a machine-readable ``scenario -> policy ->
{throughput, local_fraction, steal_penalty, ...}`` summary so the perf
trajectory is comparable across PRs (``throughput`` = tasks per scheduling
round, the discrete makespan-normalized rate).

Every policy is a named ``repro.spec.RuntimeSpec`` from the registry
(static → ``static_local``, tasking → ``tasking_round_robin``, locality →
``paper_cyclic``, adaptive → ``adaptive_theta``); ``main(spec=...)``
replaces the whole grid with one externally supplied spec — the
``benchmarks.run --spec/--policy`` path.
"""
from __future__ import annotations

import dataclasses
import json
import sys

import numpy as np

NUM_DOMAINS = 4
STEAL_PENALTY = 4.0           # cost units per stolen task (local cost = 1)


def _scenarios(n_tasks: int, seed: int):
    """name -> list of per-round arrival batches, each a list of home tags
    (an empty batch is an idle round)."""
    rng = np.random.default_rng(seed)

    def uniform():
        homes = rng.integers(0, NUM_DOMAINS, n_tasks)
        return [list(homes[i:i + 8]) for i in range(0, n_tasks, 8)]

    def bursty():
        homes = rng.integers(0, NUM_DOMAINS, n_tasks)
        waves = []
        for i in range(0, n_tasks, 64):
            waves.append(list(homes[i:i + 64]))
            waves.extend([[]] * 6)           # idle rounds between bursts
        return waves

    def skewed():
        hot = rng.random(n_tasks) < 0.8
        homes = np.where(hot, 0, rng.integers(0, NUM_DOMAINS, n_tasks))
        return [list(homes[i:i + 8]) for i in range(0, n_tasks, 8)]

    return {"uniform": uniform(), "bursty": bursty(), "skewed": skewed()}


def _policies():
    from repro import spec

    # benchmark arm -> registry policy (all declarative; no constructors)
    return {
        "static": spec.named("static_local"),
        "tasking": spec.named("tasking_round_robin"),
        "locality": spec.named("paper_cyclic"),
        "adaptive": spec.named("adaptive_theta"),
    }


def _drive(waves, policy_spec, seed: int):
    ex = dataclasses.replace(policy_spec, seed=seed,
                             record_events=False).build().executor
    for batch in waves:
        for home in batch:
            ex.submit(ex.make_task(home=int(home)))
        ex.step()
    ex.run_until_drained()
    return ex


def to_json(lines: list[str]) -> dict:
    """CSV summary lines -> ``scenario -> policy -> metrics`` dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for ln in lines[1:]:
        scen, pol, tasks, local, steal, pen, idle, steps = ln.split(",")
        out.setdefault(scen, {})[pol] = {
            "tasks": int(tasks),
            "steps": int(steps),
            "throughput": round(int(tasks) / max(int(steps), 1), 4),
            "local_fraction": float(local),
            "steal_fraction": float(steal),
            "steal_penalty": float(pen),
            "idle_polls": int(idle),
        }
    return out


def main(n_tasks: int = 400, seed: int = 0,
         json_path: str | None = None, spec=None) -> list[str]:
    policies = {"spec": spec} if spec is not None else _policies()
    lines = ["scenario,policy,tasks,local_frac,steal_frac,steal_penalty,"
             "idle_polls,steps"]
    for scen_name, waves in _scenarios(n_tasks, seed).items():
        for pol_name, policy_spec in policies.items():
            ex = _drive(waves, policy_spec, seed)
            s = ex.stats
            assert s.executed == n_tasks, (scen_name, pol_name, s.executed)
            lines.append(
                f"{scen_name},{pol_name},{s.executed},"
                f"{s.local_fraction:.3f},{s.steal_fraction:.3f},"
                f"{s.steal_penalty:.0f},{s.idle_polls},{ex.step_count}")
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "runtime_throughput", "n_tasks": n_tasks,
                       "seed": seed, "results": to_json(lines)}, fh, indent=2)
            fh.write("\n")
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(n_tasks=160 if fast else 400,
                   json_path="BENCH_runtime.json"):
        print(ln)
