"""Online runtime throughput: scheduling policies x arrival scenarios.

    PYTHONPATH=src python -m benchmarks.runtime_throughput [--fast]

Drives the ``repro.runtime`` executor with three arrival patterns and four
policies and reports the locality/balance trade-off each policy strikes —
the online analogue of the paper's Fig. 3 policy comparison:

  policies
    static    — route to home, never steal (OpenMP ``schedule(static)``:
                pure locality, imbalance shows up as idle polls / steps)
    tasking   — round-robin routing, greedy stealing (plain OpenMP tasking:
                balanced, locality accidental ≈ 1/num_domains)
    locality  — route to home, greedy cyclic stealing (the paper's §2.2
                locality queues: balance over locality)
    adaptive  — route to home, depth-thresholded stealing tracking the steal
                penalty (``runtime.AdaptiveSteal``, beyond the paper)

  scenarios (task homes + arrival cadence; identical streams per policy)
    uniform   — homes uniform over domains, steady arrivals
    bursty    — large synchronized bursts separated by idle rounds
    skewed    — 80% of tasks homed on domain 0 (one hot replica/socket)

Each stolen task pays an abstract nonlocal penalty (STEAL_PENALTY cost
units ≈ a prefix re-prefill); ``steps`` is the number of scheduling rounds
until drained (the discrete makespan proxy).

CSV: scenario,policy,tasks,local_frac,steal_frac,steal_penalty,idle_polls,steps

Alongside the CSV, ``main(json_path=...)`` (default ``BENCH_runtime.json``
when run as a script) writes a machine-readable ``scenario -> policy ->
{throughput, local_fraction, steal_penalty, ...}`` summary so the perf
trajectory is comparable across PRs (``throughput`` = tasks per scheduling
round, the discrete makespan-normalized rate).

Every policy is a named ``repro.spec.RuntimeSpec`` from the registry
(static → ``static_local``, tasking → ``tasking_round_robin``, locality →
``paper_cyclic``, adaptive → ``adaptive_theta``) and every scenario is a
declarative ``repro.spec.WorkloadSpec`` (``spec.runtime_workloads`` — the
workload block of the ``runtime_*`` named experiments), so this module is
a thin driver: it owns no workload construction, only the policy × workload
grid.  ``main(spec=...)`` replaces the whole grid with one externally
supplied spec — the ``benchmarks.run --spec/--policy`` path.
"""
from __future__ import annotations

import dataclasses
import json
import sys

NUM_DOMAINS = 4
STEAL_PENALTY = 4.0           # cost units per stolen task (local cost = 1)


def _scenarios(n_tasks: int, seed: int):
    """name -> built ``trace.workloads.Workload`` (the declared arrival
    streams of the ``runtime_*`` experiment registry)."""
    from repro.spec import runtime_workloads

    return {name: wl.build() for name, wl in runtime_workloads(
        n_tasks=n_tasks, num_domains=NUM_DOMAINS, seed=seed).items()}


def _policies():
    from repro import spec

    # benchmark arm -> registry policy (all declarative; no constructors)
    return {
        "static": spec.named("static_local"),
        "tasking": spec.named("tasking_round_robin"),
        "locality": spec.named("paper_cyclic"),
        "adaptive": spec.named("adaptive_theta"),
    }


def _drive(workload, policy_spec, seed: int):
    from repro.trace import drive

    ex = dataclasses.replace(policy_spec, seed=seed,
                             record_events=False).build().executor
    return drive(ex, workload)


def to_json(lines: list[str]) -> dict:
    """CSV summary lines -> ``scenario -> policy -> metrics`` dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for ln in lines[1:]:
        scen, pol, tasks, local, steal, pen, idle, steps = ln.split(",")
        out.setdefault(scen, {})[pol] = {
            "tasks": int(tasks),
            "steps": int(steps),
            "throughput": round(int(tasks) / max(int(steps), 1), 4),
            "local_fraction": float(local),
            "steal_fraction": float(steal),
            "steal_penalty": float(pen),
            "idle_polls": int(idle),
        }
    return out


def main(n_tasks: int = 400, seed: int = 0,
         json_path: str | None = None, spec=None) -> list[str]:
    policies = {"spec": spec} if spec is not None else _policies()
    lines = ["scenario,policy,tasks,local_frac,steal_frac,steal_penalty,"
             "idle_polls,steps"]
    for scen_name, workload in _scenarios(n_tasks, seed).items():
        for pol_name, policy_spec in policies.items():
            ex = _drive(workload, policy_spec, seed)
            s = ex.stats
            assert s.executed == n_tasks, (scen_name, pol_name, s.executed)
            lines.append(
                f"{scen_name},{pol_name},{s.executed},"
                f"{s.local_fraction:.3f},{s.steal_fraction:.3f},"
                f"{s.steal_penalty:.0f},{s.idle_polls},{ex.step_count}")
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "runtime_throughput", "n_tasks": n_tasks,
                       "seed": seed, "results": to_json(lines)}, fh, indent=2)
            fh.write("\n")
    return lines


if __name__ == "__main__":
    # the --fast smoke must not overwrite the committed full-grid
    # BENCH_runtime.json artifact with small-run numbers
    fast = "--fast" in sys.argv
    for ln in main(n_tasks=160 if fast else 400,
                   json_path=None if fast else "BENCH_runtime.json"):
        print(ln)
