"""BENCH regression sentinel: fresh runs vs the committed baselines.

    PYTHONPATH=src python -m benchmarks.sentinel [--only a,b] [--full]

The BENCH_*.json artifacts were write-only — refreshed by whoever last ran
the benchmarks, drifting silently otherwise.  This gate (``make sentinel``)
gives them teeth: it re-runs each benchmark *at the committed baseline's
own declared parameters*, compares every numeric metric under a per-metric
tolerance policy, writes a markdown report (``BENCH_sentinel.md``), appends
a summary entry to the ``BENCH_trajectory.json`` history, and exits nonzero
on any regression — the before/after scoreboard ROADMAP item 2's hot-path
rewrite is graded by.

Tolerance policy (``metric_policy``) — the load-bearing design choice:

  * **deterministic metrics** (control/topology/experiments: every count,
    fraction, penalty, percentile; overhead: the ``stats_identical`` gate)
    come off the seeded step-clock simulator, so they are bit-reproducible
    across machines.  Tolerance: *exact* — any delta is drift and fails.
    This is what makes the sentinel schedule-passive: it asserts the
    schedule, it never perturbs it.
  * **wall-clock metrics** (overhead: ``ns_per_decision.*``) are machine-
    dependent.  They gate *lower-is-better* with a deliberately loose 3x
    ratio — wide enough that a shared CI box never flakes, tight enough to
    catch an accidental O(n) slip in a hot path.  Pure environment
    readouts (``wall_*``, ``tasks_per_s``, ``overhead_frac`` — already
    gated inside the benchmark itself, ``repeats_used``,
    ``profile_total_ns``) are reported but never gated here, as are the
    ``speedup_*`` ratios of the fast-vs-slow block (two wall readouts in a
    ratio; equivalence and the speedup floor already gate inside the
    benchmark).
  * metrics present in the baseline but missing fresh fail (a deleted
    measurement is a regression of the record); new fresh metrics are
    reported as ``new`` and pass (the next baseline refresh adopts them).

Fresh runs write to a temp directory — the committed BENCH baselines are
never clobbered by the sentinel (refreshing a baseline stays an explicit
``make bench-*`` + commit).  ``--only`` restricts the bench set;
``--full`` runs the overhead bench's full task ladder instead of the fast
CI ladder (rows are compared on the (n_tasks, num_domains) intersection
either way).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
import time
from typing import Any, Optional

BASELINES = {
    "control": "BENCH_control.json",
    "topology": "BENCH_topology.json",
    "overhead": "BENCH_overhead.json",
    "experiments": "BENCH_experiments.json",
}
REPORT_PATH = "BENCH_sentinel.md"
TRAJECTORY_PATH = "BENCH_trajectory.json"

EXACT_EPS = 1e-9          # float equality slack for deterministic metrics
WALL_RATIO_TOL = 2.0      # lower-better wall metrics may grow up to 3x

# wall-clock environment readouts: reported, never gated
_UNGATED = ("wall_off_s", "wall_on_s", "tasks_per_s", "overhead_frac",
            "profile_total_ns", "repeats_used")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One compared metric: baseline vs fresh under its policy."""

    bench: str
    metric: str
    baseline: Optional[float]
    fresh: Optional[float]
    direction: str            # "equal" | "lower" | "info"
    status: str               # "ok" | "regression" | "improvement"
                              # | "new" | "missing" | "info"

    @property
    def failed(self) -> bool:
        return self.status in ("regression", "missing")


def flatten(obj: Any, prefix: str = "") -> dict[str, float]:
    """Numeric leaves of a nested JSON value as dotted paths (lists index
    as ``[i]``; booleans and the embedded ``experiment`` spec blocks are
    config, not measurements, and are skipped)."""
    out: dict[str, float] = {}
    if isinstance(obj, bool):
        return out
    if isinstance(obj, (int, float)):
        out[prefix] = float(obj)
        return out
    if isinstance(obj, dict):
        for k in sorted(obj):
            if k == "experiment":
                continue
            out.update(flatten(obj[k], f"{prefix}.{k}" if prefix else k))
        return out
    if isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}[{i}]"))
    return out


def metric_policy(bench: str, path: str) -> str:
    """``"equal"`` (deterministic — exact), ``"lower"`` (wall, loose
    lower-is-better), or ``"info"`` (reported, never gated)."""
    leaf = path.rsplit(".", 1)[-1].split("[")[0]
    if leaf in _UNGATED or leaf.startswith("speedup_"):
        return "info"
    if bench == "overhead" and ".ns_per_decision." in f".{path}":
        return "lower"
    return "equal"


def _compare_one(bench: str, path: str, base: Optional[float],
                 fresh: Optional[float]) -> Finding:
    direction = metric_policy(bench, path)
    if base is None:
        return Finding(bench, path, None, fresh, direction, "new")
    if fresh is None:
        return Finding(bench, path, base, None, direction,
                       "info" if direction == "info" else "missing")
    if direction == "info":
        return Finding(bench, path, base, fresh, direction, "info")
    if direction == "lower":
        if fresh > base * (1.0 + WALL_RATIO_TOL):
            status = "regression"
        elif fresh < base:
            status = "improvement"
        else:
            status = "ok"
        return Finding(bench, path, base, fresh, direction, status)
    # exact: any drift beyond float-formatting noise fails
    ok = abs(fresh - base) <= EXACT_EPS * max(1.0, abs(base), abs(fresh))
    return Finding(bench, path, base, fresh, direction,
                   "ok" if ok else "regression")


def compare(baseline: dict, fresh: dict, bench: str) -> list[Finding]:
    """Per-metric findings over the union of flattened numeric paths."""
    fb, ff = flatten(baseline), flatten(fresh)
    return [_compare_one(bench, path, fb.get(path), ff.get(path))
            for path in sorted(set(fb) | set(ff))]


# -- fresh runs (at the baseline's own declared parameters) -------------------

def _run_control(base: dict, out: str) -> None:
    from benchmarks import control_plane
    control_plane.main(steps=base.get("steps", 48), seed=base.get("seed", 0),
                       json_path=out)


def _run_topology(base: dict, out: str) -> None:
    from benchmarks import topology_locality
    topology_locality.main(steps=base.get("steps", 48),
                           seed=base.get("seed", 0), json_path=out)


def _overhead_rows(base: dict, out: str, full: bool) -> None:
    from benchmarks import scheduler_overhead as so
    if full:
        scales, domains, fvs = so.TASK_SCALES, so.DOMAIN_SCALES, so.FVS_SCALES
    else:
        scales, domains, fvs = (so.FAST_TASK_SCALES, so.FAST_DOMAIN_SCALES,
                                so.FAST_FVS_SCALES)
    so.main(task_scales=scales, domain_scales=domains, fvs_scales=fvs,
            repeats=base.get("repeats", 5), json_path=out)


def _run_experiments(base: dict, out: str) -> None:
    from benchmarks.run import _cli_experiments, run_experiments
    experiments, _ = _cli_experiments(["--experiment", "all"])
    run_experiments(experiments, json_path=out)


def _intersect_overhead(base: dict, fresh: dict) -> tuple[dict, dict]:
    """Restrict both overhead results to the shared (n_tasks, num_domains)
    rows, re-keyed by configuration so row order can't misalign the diff
    (the fast CI ladder runs a subset of the committed full ladder)."""
    def rows(d, key):
        return {f"{r['n_tasks']}x{r['num_domains']}": r
                for r in d.get(key, [])}
    strip = ("results", "fast_vs_slow")
    nb = {k: v for k, v in base.items() if k not in strip}
    nf = {k: v for k, v in fresh.items() if k not in strip}
    for key, dest in (("results", "rows"), ("fast_vs_slow", "fvs")):
        rb, rf = rows(base, key), rows(fresh, key)
        shared = sorted(set(rb) & set(rf))
        nb[dest] = {k: rb[k] for k in shared}
        nf[dest] = {k: rf[k] for k in shared}
    return nb, nf


# -- report + trajectory ------------------------------------------------------

def render_report(all_findings: dict[str, list[Finding]],
                  skipped: dict[str, str]) -> str:
    """The markdown regression report (``BENCH_sentinel.md``): verdict,
    per-bench summary, every non-ok finding in full."""
    from repro.obs.report import markdown_table

    failed = [f for fs in all_findings.values() for f in fs if f.failed]
    lines = ["# BENCH regression sentinel", "",
             ("**FAIL** — regression against committed baselines."
              if failed else
              "**PASS** — no regression against committed baselines."), "",
             markdown_table(
                 ["bench", "metrics", "ok", "regressions", "improvements",
                  "new", "info"],
                 [[b, len(fs),
                   sum(1 for f in fs if f.status == "ok"),
                   sum(1 for f in fs if f.failed),
                   sum(1 for f in fs if f.status == "improvement"),
                   sum(1 for f in fs if f.status == "new"),
                   sum(1 for f in fs if f.status == "info")]
                  for b, fs in sorted(all_findings.items())])]
    for bench, reason in sorted(skipped.items()):
        lines.append(f"\n(skipped `{bench}`: {reason})")
    notable = [f for fs in all_findings.values() for f in fs
               if f.status not in ("ok", "info")]
    if notable:
        lines += ["", "## Non-ok findings", "",
                  markdown_table(
                      ["bench", "metric", "baseline", "fresh", "policy",
                       "status"],
                      [[f.bench, f.metric,
                        "-" if f.baseline is None else f"{f.baseline:g}",
                        "-" if f.fresh is None else f"{f.fresh:g}",
                        f.direction, f.status] for f in notable])]
    return "\n".join(lines) + "\n"


def append_trajectory(all_findings: dict[str, list[Finding]],
                      path: str = TRAJECTORY_PATH) -> dict:
    """Append this run's summary to the BENCH history file (created on
    first run) and return the entry."""
    entry = {
        "when": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "ok": not any(f.failed for fs in all_findings.values() for f in fs),
        "benches": {b: {"metrics": len(fs),
                        "regressions": sum(1 for f in fs if f.failed),
                        "improvements": sum(1 for f in fs
                                            if f.status == "improvement")}
                    for b, fs in sorted(all_findings.items())},
    }
    history = {"bench": "sentinel_trajectory", "entries": []}
    if os.path.exists(path):
        with open(path, "r", encoding="utf-8") as fh:
            history = json.load(fh)
    history["entries"].append(entry)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(history, fh, indent=2)
        fh.write("\n")
    return entry


RUNNERS = {
    "control": _run_control,
    "topology": _run_topology,
    "experiments": _run_experiments,
}

# traces committed to the repo: the model checker must hold on all of them
MODEL_FIXTURES = ("tests/data/v1_trace_fixture.jsonl",
                  "tests/data/v1_segments")
# registry policies whose fresh probe traces the model checker re-verifies
# every sentinel run (flat v2, hierarchical v3, obs-profiled v4 headers —
# one per schema generation still being written)
MODEL_POLICIES = ("replay_baseline", "topology_two_level",
                  "topology_pods_adaptive")


def _model_findings() -> list[Finding]:
    """The ``model`` sentinel section: run ``repro.check``'s trace model
    checker over every committed trace fixture plus a fresh probe trace
    per schema-spanning registry policy.  Baseline is implicit and
    constant — zero violations — so any structurally illegal schedule is a
    regression (the second gate on ROADMAP item 2's hot-path rewrite,
    independent of stats equality)."""
    from repro.check import check_path, check_trace
    from repro.spec import registry
    from repro.spec.validate import probe_trace

    findings: list[Finding] = []

    def judge(label: str, result) -> None:
        n = float(len(result.violations))
        findings.append(Finding("model", f"{label}.violations", 0.0, n,
                                "equal", "ok" if result.ok else "regression"))
        for v in result.violations:
            print(f"# sentinel model: {v}", file=sys.stderr)

    for path in MODEL_FIXTURES:
        if not os.path.exists(path):
            continue
        judge(f"fixture.{os.path.basename(path)}", check_path(path))
    names = [n for n in MODEL_POLICIES if n in registry.policy_names()]
    for name in names:
        spec = registry.named(name)
        judge(f"policy.{name}", check_trace(probe_trace(spec), path=name))
    return findings


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    full = "--full" in argv
    only = None
    if "--only" in argv:
        only = set(argv[argv.index("--only") + 1].split(","))
        known = set(BASELINES) | {"model"}
        unknown = only - known
        if unknown:
            raise SystemExit(f"--only: unknown bench(es) {sorted(unknown)}; "
                             f"choose from {sorted(known)}")

    all_findings: dict[str, list[Finding]] = {}
    skipped: dict[str, str] = {}
    with tempfile.TemporaryDirectory(prefix="sentinel-") as tmp:
        for bench, baseline_path in BASELINES.items():
            if only is not None and bench not in only:
                continue
            if not os.path.exists(baseline_path):
                skipped[bench] = f"no committed baseline {baseline_path}"
                continue
            with open(baseline_path, "r", encoding="utf-8") as fh:
                base = json.load(fh)
            out = os.path.join(tmp, f"{bench}.json")
            print(f"# sentinel: re-running {bench} at baseline parameters "
                  f"({baseline_path})", flush=True)
            if bench == "overhead":
                _overhead_rows(base, out, full)
            else:
                RUNNERS[bench](base, out)
            with open(out, "r", encoding="utf-8") as fh:
                fresh = json.load(fh)
            if bench == "overhead":
                base, fresh = _intersect_overhead(base, fresh)
            all_findings[bench] = compare(base, fresh, bench)

    if only is None or "model" in only:
        print("# sentinel: model-checking committed fixtures + fresh "
              "policy probe traces", flush=True)
        all_findings["model"] = _model_findings()

    report = render_report(all_findings, skipped)
    with open(REPORT_PATH, "w", encoding="utf-8") as fh:
        fh.write(report)
    entry = append_trajectory(all_findings)
    print(report)
    print(f"# report: {REPORT_PATH}; trajectory: {TRAJECTORY_PATH} "
          f"({len(entry['benches'])} bench(es), ok={entry['ok']})")
    return 0 if entry["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
