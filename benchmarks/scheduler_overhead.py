"""Scheduler self-overhead: ns per scheduling decision at production scale.

    PYTHONPATH=src python -m benchmarks.scheduler_overhead [--fast]

ROADMAP item 2 asks what the *scheduler itself* costs as task and domain
count scale toward production (10⁵–10⁶ tasks; cf. Wang et al. on
fine-grained parallelism overheads).  This benchmark answers with the
``repro.obs`` self-profiling hooks — ``Executor(profiler=...)`` wraps the
four hot decision sites in ``perf_counter_ns`` timers:

  submit_route   choosing a queue per routed submission
  steal_scan     one dequeue attempt (local check + governed victim scan)
  batch_grab     draining batch-mates from the chosen queue
  event_append   appending one event to the ring-buffer log

and with an obs-on vs obs-off A/B: the same workload driven under
``ObsSpec(enabled=True)`` (a live ``Observation`` attached, **no**
profiler) and under ``ObsSpec()`` — observation is passive, so the wall
time delta must stay inside noise.  Gates (skipped under ``gates=False``):

  * obs-on and obs-off runs produce bit-identical ``RuntimeStats``
    (the obs layer's load-bearing invariant, asserted per configuration);
  * obs-on throughput within ``OVERHEAD_GATE`` (5%) of obs-off
    (min-of-``repeats`` wall time on both arms, the cyclic GC paused
    during timed regions, so collector pauses and scheduler jitter do
    not fail the gate).

The wall-time gate binds at *every* scale.  A single short run is noisier
than the few-percent delta the gate watches, so short configurations
don't get exempted — they get more repeats: rounds continue until each
arm has accumulated at least ``REPEAT_WALL_FLOOR_S`` of measured wall
time (capped at ``MAX_REPEATS``).  Each round runs both arms
back-to-back, with the arm *order alternating* round to round: a fixed
off-then-on order lets slow machine drift (thermal, allocator state)
masquerade as a one-sided obs cost — measured on a shared box, a fixed
order read a reproducible +11% on two provably identical arms (cProfile:
same call counts to the function), while off-vs-off read 0%.  The gated
``overhead_frac`` is the **median of per-round on/off ratios**: pairing
cancels drift, alternation cancels order bias, and the median shrugs off
background spikes landing in either arm of any single round (per-round
ratios jitter ±20% where the median holds within ±3%).  The JSON records
``repeats_used`` and the ``estimator`` name per row; ``wall_off_s``/
``wall_on_s``/``tasks_per_s`` always report the min-of-N floors.

The profiled arm is reported but not gated: the timers themselves cost a
few hundred ns per decision and that cost is exactly what this benchmark
exists to measure, not to hide.

The driven workload is synthetic and arrival-paced (``num_domains`` tasks
per scheduling round, 20% of them homed hot on domain 0 so the steal scan
has real work), under a fixed batch-4 grab so all four hot paths fire.

Fast vs slow (``fast_vs_slow`` in the JSON): the runtime keeps the
pre-rewrite O(domains) victim scan and object-per-event log alive as a
reference implementation (``Executor(fast=False)``).  For each configured
scale this block drives the identical workload through both arms,
**requires** bit-identical results — same ``RuntimeStats`` snapshot, same
whole-run event counts, and byte-identical event-window CSV — and reports
each arm's ns/decision plus the fast/slow speedup per hot path
(``speedup_*``; the committed artifact is where the ≥2x steal_scan /
event_append acceptance number lives, and ``FVS_SPEEDUP_FLOOR`` guards
against the fast path silently regressing toward the slow one).

CSV: n_tasks,num_domains,submit_route_ns,steal_scan_ns,batch_grab_ns,
event_append_ns,wall_off_s,wall_on_s,overhead_frac,tasks_per_s

``main(json_path=...)`` (default ``BENCH_overhead.json`` when run as a
script) writes the machine-readable summary: per configuration, ns/decision
and call counts for every hot path plus the obs-on/off wall-time delta.
``--fast`` runs a reduced ladder for CI (the committed artifact comes from
the full run).
"""
from __future__ import annotations

import gc
import json
import statistics
import sys
import time
import warnings

TASK_SCALES = (1_000, 10_000, 100_000, 1_000_000)
DOMAIN_SCALES = (4, 16)
FAST_TASK_SCALES = (1_000, 20_000)
FAST_DOMAIN_SCALES = (4,)
OVERHEAD_GATE = 0.05           # obs-on may cost at most 5% throughput
REPEAT_WALL_FLOOR_S = 1.0      # accumulated per-arm wall before gating
MAX_REPEATS = 256              # adaptive-repeat ceiling per arm
MILLION_REPEATS = 2            # repeat floor for the 10^6-task rows
BATCH_SIZE = 4                 # fixed batch so batch_grab fires
STEAL_PENALTY = 4.0
HOT_EVERY = 5                  # every 5th task homed on domain 0
DEPTH_STRIDE_HUGE = 64         # depth-sample stride for the 10^6-task rows

# fast-vs-slow equivalence + speedup scales, (n_tasks, num_domains)
FVS_SCALES = ((100_000, 4), (100_000, 16))
FAST_FVS_SCALES = ((20_000, 4),)
FVS_SPEEDUP_FLOOR = 1.5        # fast arm must beat slow by at least this
FVS_GATED_PATHS = ("steal_scan", "event_append")
FVS_GATE_MIN_TASKS = 100_000   # speedup floor binds at this scale and up


def _spec(num_domains: int, *, obs_enabled: bool, profile: bool):
    from repro import spec

    return spec.RuntimeSpec(
        num_domains=num_domains,
        steal_order="cyclic",
        penalty=spec.PenaltySpec(kind="constant", value=STEAL_PENALTY),
        batch=spec.BatchSpec(kind="fixed", size=BATCH_SIZE),
        obs=spec.ObsSpec(enabled=obs_enabled, profile=profile),
    )


def _drive(ex, n_tasks: int, num_domains: int, *,
           contended: bool = False) -> float:
    """Submit ``num_domains`` tasks per scheduling round (20% homed hot on
    domain 0), step between waves, drain; returns elapsed wall seconds.
    Takes a bare ``Executor`` (spec callers pass ``built.executor``).  The
    big scales overflow the event ring buffer by design — the one-shot
    warning is expected and muted here (storm analysis is not run).

    ``contended=True`` homes *every* task on domain 0: all other workers'
    local queues stay dry, so each of their grabs runs the victim-selection
    scan or the machine-wide-empty poll — the code the fast eligibility
    structures replace.  The default mix is local-pop dominated (every
    timed dequeue is a successful pop) and measures the other half of the
    hot path."""
    # GC hygiene: a collection pause landing inside one arm but not the
    # other would swamp the few-percent delta the gate watches.  The driven
    # structures are cycle-free (refcounting reclaims them), so the cyclic
    # collector is paused for the timed region.
    gc.collect()
    gc.disable()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            t0 = time.perf_counter()
            for i in range(n_tasks):
                home = (0 if contended or i % HOT_EVERY == 0
                        else i % num_domains)
                ex.submit(ex.make_task(home=home))
                if i % num_domains == num_domains - 1:
                    ex.step()
            ex.run_until_drained()
            return time.perf_counter() - t0
    finally:
        gc.enable()


def measure(n_tasks: int, num_domains: int,
            repeats: int = 5) -> dict:
    """One configuration: profiled ns/decision + obs-on/off wall A/B.

    ``repeats`` is the floor; short configurations repeat adaptively
    until each arm accumulates ``REPEAT_WALL_FLOOR_S`` of wall time
    (capped at ``MAX_REPEATS``) so every row participates in the overhead
    gate.  The gated fraction is the median of per-round paired ratios
    under alternating arm order (see module doc); the reported
    ``wall_*``/``tasks_per_s`` stay min-of-N floors.
    """
    # profiled arm: ns/decision per hot path (one run; the counters are
    # totals over millions of calls, repeat noise is already averaged out)
    built_prof = _spec(num_domains, obs_enabled=True, profile=True).build()
    _drive(built_prof.executor, n_tasks, num_domains)
    prof = built_prof.obs.profiler.snapshot()
    stats_prof = built_prof.executor.metrics.snapshot()

    wall_off = wall_on = float("inf")
    acc_off = acc_on = 0.0
    ratios = []
    stats = {True: None, False: None}
    repeats_used = 0
    while repeats_used < repeats or (
            min(acc_off, acc_on) < REPEAT_WALL_FLOOR_S
            and repeats_used < MAX_REPEATS):
        # alternate which arm runs first (round parity — deterministic)
        arms = (False, True) if repeats_used % 2 == 0 else (True, False)
        walls = {}
        for on in arms:
            built = _spec(num_domains, obs_enabled=on, profile=False).build()
            walls[on] = _drive(built.executor, n_tasks, num_domains)
            stats[on] = built.executor.metrics.snapshot()
        wall_off = min(wall_off, walls[False])
        wall_on = min(wall_on, walls[True])
        acc_off += walls[False]
        acc_on += walls[True]
        ratios.append(walls[True] / walls[False])
        repeats_used += 1

    stats_off, stats_on = stats[False], stats[True]
    if stats_on != stats_off or stats_prof != stats_off:
        raise SystemExit(
            f"obs perturbed the schedule at n_tasks={n_tasks}, "
            f"num_domains={num_domains}: off={stats_off} on={stats_on} "
            f"profiled={stats_prof}")
    return {
        "n_tasks": n_tasks,
        "num_domains": num_domains,
        "ns_per_decision": prof["ns_per_call"],
        "calls": prof["calls"],
        "profile_total_ns": sum(prof["ns"].values()),
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "overhead_frac": statistics.median(ratios) - 1.0,
        "tasks_per_s": n_tasks / wall_off,
        "stats_identical": True,
        "repeats_used": repeats_used,
        "estimator": "paired_median",
        "gated": True,
    }


def _fvs_executor(num_domains: int, *, fast: bool):
    from repro.obs import HotPathProfiler
    from repro.runtime import Executor

    prof = HotPathProfiler()
    ex = Executor(num_domains,
                  steal_order="cyclic",
                  steal_penalty=lambda t, w: STEAL_PENALTY,
                  batch=BATCH_SIZE,
                  profiler=prof,
                  fast=fast,
                  depth_sample_stride=DEPTH_STRIDE_HUGE)
    return ex, prof


def measure_fast_vs_slow(n_tasks: int, num_domains: int, *,
                         gates: bool = True) -> tuple[dict, list[str]]:
    """Fast-path vs reference-path A/B at one scale: equivalence + speedup.

    Drives the identical *contended* workload (every task homed on domain
    0 — see ``_drive``) through ``Executor(fast=True)`` and
    ``Executor(fast=False)`` (the pre-rewrite O(domains) victim scan and
    object-per-event ``ReferenceEventLog``), both profiled.  Contention
    makes every non-hot worker's grab a victim scan or an empty poll —
    the code the rewrite replaces — while the main ladder's mixed drive
    covers the local-pop path.  Equivalence is
    **mandatory** regardless of ``gates`` — identical ``RuntimeStats``,
    identical whole-run event counts, and byte-identical retained-window
    event CSV — because bit-identity is the fast path's contract, not a
    performance target.  The speedup floor (``FVS_SPEEDUP_FLOOR`` on
    ``FVS_GATED_PATHS`` at >= ``FVS_GATE_MIN_TASKS`` tasks) is soft
    anti-regression insurance; the headline ≥2x acceptance numbers live in
    the committed full-ladder artifact.
    """
    snaps = {}
    for fast in (True, False):
        ex, prof = _fvs_executor(num_domains, fast=fast)
        _drive(ex, n_tasks, num_domains, contended=True)
        snaps[fast] = {
            "stats": ex.metrics.snapshot(),
            "counts": ex.events.counts(),
            "csv": tuple(ex.events.to_csv_lines()),
            "events_retained": len(ex.events),
            "events_total": ex.events.total,
            "prof": prof.snapshot(),
        }
    f, s = snaps[True], snaps[False]
    for key, label in (("stats", "RuntimeStats"),
                       ("counts", "event counts"),
                       ("csv", "event CSV")):
        if f[key] != s[key]:
            raise SystemExit(
                f"fast/slow divergence at n_tasks={n_tasks}, "
                f"num_domains={num_domains}: {label} differ — "
                f"fast={f[key]!r:.200} slow={s[key]!r:.200}")
    ns_f, ns_s = f["prof"]["ns_per_call"], s["prof"]["ns_per_call"]
    row = {
        "n_tasks": n_tasks,
        "num_domains": num_domains,
        "ns_per_decision": {
            **{f"{p}_fast": ns_f[p] for p in sorted(ns_f)},
            **{f"{p}_slow": ns_s[p] for p in sorted(ns_s)},
        },
        "stats_identical": True,
        "events_identical": True,
        "events_compared": f["events_retained"],
        "events_total": f["events_total"],
    }
    failures = []
    for p in sorted(ns_f):
        if ns_f[p] > 0:
            speedup = ns_s[p] / ns_f[p]
            row[f"speedup_{p}"] = speedup
            if (gates and n_tasks >= FVS_GATE_MIN_TASKS
                    and p in FVS_GATED_PATHS
                    and speedup < FVS_SPEEDUP_FLOOR):
                failures.append(
                    f"n_tasks={n_tasks} num_domains={num_domains}: "
                    f"{p} fast/slow speedup {speedup:.2f}x "
                    f"< floor {FVS_SPEEDUP_FLOOR}x")
    return row, failures


def main(task_scales=TASK_SCALES, domain_scales=DOMAIN_SCALES,
         repeats: int = 5, json_path: str | None = None,
         gates: bool = True, fvs_scales=FVS_SCALES) -> list[str]:
    lines = ["n_tasks,num_domains,submit_route_ns,steal_scan_ns,"
             "batch_grab_ns,event_append_ns,wall_off_s,wall_on_s,"
             "overhead_frac,tasks_per_s"]
    rows = []
    failures = []
    for num_domains in domain_scales:
        for n_tasks in task_scales:
            # the 10^6-task rows run multi-second walls per arm; the
            # min-of-N estimator is already tight there, so cap repeats
            row = measure(n_tasks, num_domains,
                          repeats=(min(repeats, MILLION_REPEATS)
                                   if n_tasks >= 1_000_000 else repeats))
            rows.append(row)
            ns = row["ns_per_decision"]
            lines.append(
                f"{n_tasks},{num_domains},{ns['submit_route']:.0f},"
                f"{ns['steal_scan']:.0f},{ns['batch_grab']:.0f},"
                f"{ns['event_append']:.0f},{row['wall_off_s']:.3f},"
                f"{row['wall_on_s']:.3f},{row['overhead_frac']:+.3f},"
                f"{row['tasks_per_s']:.0f}")
            if gates and row["overhead_frac"] >= OVERHEAD_GATE:
                failures.append(
                    f"n_tasks={n_tasks} num_domains={num_domains}: obs-on "
                    f"cost {row['overhead_frac']:+.1%} wall time "
                    f"(gate < {OVERHEAD_GATE:.0%})")
    fvs_rows = []
    for n_tasks, num_domains in fvs_scales:
        row, fvs_fails = measure_fast_vs_slow(n_tasks, num_domains,
                                              gates=gates)
        fvs_rows.append(row)
        failures.extend(fvs_fails)
        lines.append(
            f"# fast_vs_slow n_tasks={n_tasks} num_domains={num_domains}: "
            + " ".join(f"{p}={row.get(f'speedup_{p}', 0.0):.2f}x"
                       for p in FVS_GATED_PATHS))
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "scheduler_overhead",
                       "overhead_gate": OVERHEAD_GATE,
                       "batch_size": BATCH_SIZE, "repeats": repeats,
                       "results": rows, "fast_vs_slow": fvs_rows},
                      fh, indent=2)
            fh.write("\n")
    if failures:
        raise SystemExit("scheduler_overhead gate failure:\n  "
                         + "\n  ".join(failures))
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    out = main(task_scales=FAST_TASK_SCALES if fast else TASK_SCALES,
               domain_scales=FAST_DOMAIN_SCALES if fast else DOMAIN_SCALES,
               fvs_scales=FAST_FVS_SCALES if fast else FVS_SCALES,
               json_path="BENCH_overhead.json")
    for ln in out:
        print(ln)
    print(f"\n# scheduler_overhead complete (BENCH_overhead.json written"
          f"{', fast ladder' if fast else ''})")
