"""Scheduler self-overhead: ns per scheduling decision at production scale.

    PYTHONPATH=src python -m benchmarks.scheduler_overhead [--fast]

ROADMAP item 2 asks what the *scheduler itself* costs as task and domain
count scale toward production (10⁵–10⁶ tasks; cf. Wang et al. on
fine-grained parallelism overheads).  This benchmark answers with the
``repro.obs`` self-profiling hooks — ``Executor(profiler=...)`` wraps the
four hot decision sites in ``perf_counter_ns`` timers:

  submit_route   choosing a queue per routed submission
  steal_scan     one dequeue attempt (local check + governed victim scan)
  batch_grab     draining batch-mates from the chosen queue
  event_append   appending one event to the ring-buffer log

and with an obs-on vs obs-off A/B: the same workload driven under
``ObsSpec(enabled=True)`` (a live ``Observation`` attached, **no**
profiler) and under ``ObsSpec()`` — observation is passive, so the wall
time delta must stay inside noise.  Gates (skipped under ``gates=False``):

  * obs-on and obs-off runs produce bit-identical ``RuntimeStats``
    (the obs layer's load-bearing invariant, asserted per configuration);
  * obs-on throughput within ``OVERHEAD_GATE`` (5%) of obs-off
    (min-of-``repeats`` wall time on both arms, the cyclic GC paused
    during timed regions, so collector pauses and scheduler jitter do
    not fail the gate).

The wall-time gate binds at *every* scale.  A single sub-0.1s run is
noisier than the few-percent delta the gate watches, so short
configurations don't get exempted — they get more repeats: each A/B arm
is re-run until it has accumulated at least ``REPEAT_WALL_FLOOR_S`` of
measured wall time (capped at ``MAX_REPEATS``), and the gated
``overhead_frac`` picks the estimator that is tight at that scale.
Long rows (single run ≥ ``MIN_WALL_FOR_MIN_S``) gate on the min-of-N
ratio — the classic noise-floor estimator, robust to background spikes
landing in one arm of an 8-second run.  Short rows gate on the
*accumulated*-wall ratio over all repeats — CLT averaging over ~50
paired rounds, empirically ±1–2% at the 10³-task scale where min-of-N
still jitters ±5%.  The JSON records ``repeats_used`` and the
``estimator`` chosen per row; ``wall_off_s``/``wall_on_s``/
``tasks_per_s`` always report the min-of-N floors.

The profiled arm is reported but not gated: the timers themselves cost a
few hundred ns per decision and that cost is exactly what this benchmark
exists to measure, not to hide.

The driven workload is synthetic and arrival-paced (``num_domains`` tasks
per scheduling round, 20% of them homed hot on domain 0 so the steal scan
has real work), under a fixed batch-4 grab so all four hot paths fire.

CSV: n_tasks,num_domains,submit_route_ns,steal_scan_ns,batch_grab_ns,
event_append_ns,wall_off_s,wall_on_s,overhead_frac,tasks_per_s

``main(json_path=...)`` (default ``BENCH_overhead.json`` when run as a
script) writes the machine-readable summary: per configuration, ns/decision
and call counts for every hot path plus the obs-on/off wall-time delta.
``--fast`` runs a reduced ladder for CI (the committed artifact comes from
the full run).
"""
from __future__ import annotations

import gc
import json
import sys
import time
import warnings

TASK_SCALES = (1_000, 10_000, 100_000)
DOMAIN_SCALES = (4, 16)
FAST_TASK_SCALES = (1_000, 20_000)
FAST_DOMAIN_SCALES = (4,)
OVERHEAD_GATE = 0.05           # obs-on may cost at most 5% throughput
REPEAT_WALL_FLOOR_S = 1.0      # accumulated per-arm wall before gating
MAX_REPEATS = 256              # adaptive-repeat ceiling per arm
MIN_WALL_FOR_MIN_S = 0.1       # runs this long gate on the min-of-N ratio
BATCH_SIZE = 4                 # fixed batch so batch_grab fires
STEAL_PENALTY = 4.0
HOT_EVERY = 5                  # every 5th task homed on domain 0


def _spec(num_domains: int, *, obs_enabled: bool, profile: bool):
    from repro import spec

    return spec.RuntimeSpec(
        num_domains=num_domains,
        steal_order="cyclic",
        penalty=spec.PenaltySpec(kind="constant", value=STEAL_PENALTY),
        batch=spec.BatchSpec(kind="fixed", size=BATCH_SIZE),
        obs=spec.ObsSpec(enabled=obs_enabled, profile=profile),
    )


def _drive(built, n_tasks: int, num_domains: int) -> float:
    """Submit ``num_domains`` tasks per scheduling round (20% homed hot on
    domain 0), step between waves, drain; returns elapsed wall seconds.
    The big scales overflow the event ring buffer by design — the one-shot
    warning is expected and muted here (storm analysis is not run)."""
    ex = built.executor
    # GC hygiene: a collection pause landing inside one arm but not the
    # other would swamp the few-percent delta the gate watches.  The driven
    # structures are cycle-free (refcounting reclaims them), so the cyclic
    # collector is paused for the timed region.
    gc.collect()
    gc.disable()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            t0 = time.perf_counter()
            for i in range(n_tasks):
                home = 0 if i % HOT_EVERY == 0 else i % num_domains
                ex.submit(ex.make_task(home=home))
                if i % num_domains == num_domains - 1:
                    ex.step()
            ex.run_until_drained()
            return time.perf_counter() - t0
    finally:
        gc.enable()


def measure(n_tasks: int, num_domains: int,
            repeats: int = 5) -> dict:
    """One configuration: profiled ns/decision + obs-on/off wall A/B.

    ``repeats`` is the floor; short configurations repeat adaptively
    until each arm accumulates ``REPEAT_WALL_FLOOR_S`` of wall time
    (capped at ``MAX_REPEATS``) so every row participates in the overhead
    gate.  The gated fraction is min-of-N for long runs, accumulated-wall
    for short ones; the reported ``wall_*``/``tasks_per_s`` stay min-of-N
    floors.
    """
    # profiled arm: ns/decision per hot path (one run; the counters are
    # totals over millions of calls, repeat noise is already averaged out)
    built_prof = _spec(num_domains, obs_enabled=True, profile=True).build()
    _drive(built_prof, n_tasks, num_domains)
    prof = built_prof.obs.profiler.snapshot()
    stats_prof = built_prof.executor.metrics.snapshot()

    # A/B arms: min-of-repeats wall time, identical seeds and workload;
    # keep pairing (off then on) each round so slow drift in machine load
    # hits both arms alike
    wall_off = wall_on = float("inf")
    acc_off = acc_on = 0.0
    stats_off = stats_on = None
    repeats_used = 0
    while repeats_used < repeats or (
            min(acc_off, acc_on) < REPEAT_WALL_FLOOR_S
            and repeats_used < MAX_REPEATS):
        b_off = _spec(num_domains, obs_enabled=False, profile=False).build()
        w = _drive(b_off, n_tasks, num_domains)
        wall_off, acc_off = min(wall_off, w), acc_off + w
        stats_off = b_off.executor.metrics.snapshot()
        b_on = _spec(num_domains, obs_enabled=True, profile=False).build()
        w = _drive(b_on, n_tasks, num_domains)
        wall_on, acc_on = min(wall_on, w), acc_on + w
        stats_on = b_on.executor.metrics.snapshot()
        repeats_used += 1

    if stats_on != stats_off or stats_prof != stats_off:
        raise SystemExit(
            f"obs perturbed the schedule at n_tasks={n_tasks}, "
            f"num_domains={num_domains}: off={stats_off} on={stats_on} "
            f"profiled={stats_prof}")
    return {
        "n_tasks": n_tasks,
        "num_domains": num_domains,
        "ns_per_decision": prof["ns_per_call"],
        "calls": prof["calls"],
        "profile_total_ns": sum(prof["ns"].values()),
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "overhead_frac": (wall_on / wall_off - 1.0
                          if wall_off >= MIN_WALL_FOR_MIN_S
                          else acc_on / acc_off - 1.0),
        "tasks_per_s": n_tasks / wall_off,
        "stats_identical": True,
        "repeats_used": repeats_used,
        "estimator": ("min_of_n" if wall_off >= MIN_WALL_FOR_MIN_S
                      else "accumulated"),
        "gated": True,
    }


def main(task_scales=TASK_SCALES, domain_scales=DOMAIN_SCALES,
         repeats: int = 5, json_path: str | None = None,
         gates: bool = True) -> list[str]:
    lines = ["n_tasks,num_domains,submit_route_ns,steal_scan_ns,"
             "batch_grab_ns,event_append_ns,wall_off_s,wall_on_s,"
             "overhead_frac,tasks_per_s"]
    rows = []
    failures = []
    for num_domains in domain_scales:
        for n_tasks in task_scales:
            row = measure(n_tasks, num_domains, repeats=repeats)
            rows.append(row)
            ns = row["ns_per_decision"]
            lines.append(
                f"{n_tasks},{num_domains},{ns['submit_route']:.0f},"
                f"{ns['steal_scan']:.0f},{ns['batch_grab']:.0f},"
                f"{ns['event_append']:.0f},{row['wall_off_s']:.3f},"
                f"{row['wall_on_s']:.3f},{row['overhead_frac']:+.3f},"
                f"{row['tasks_per_s']:.0f}")
            if gates and row["overhead_frac"] >= OVERHEAD_GATE:
                failures.append(
                    f"n_tasks={n_tasks} num_domains={num_domains}: obs-on "
                    f"cost {row['overhead_frac']:+.1%} wall time "
                    f"(gate < {OVERHEAD_GATE:.0%})")
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "scheduler_overhead",
                       "overhead_gate": OVERHEAD_GATE,
                       "batch_size": BATCH_SIZE, "repeats": repeats,
                       "results": rows}, fh, indent=2)
            fh.write("\n")
    if failures:
        raise SystemExit("scheduler_overhead gate failure:\n  "
                         + "\n  ".join(failures))
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    out = main(task_scales=FAST_TASK_SCALES if fast else TASK_SCALES,
               domain_scales=FAST_DOMAIN_SCALES if fast else DOMAIN_SCALES,
               json_path="BENCH_overhead.json")
    for ln in out:
        print(ln)
    print(f"\n# scheduler_overhead complete (BENCH_overhead.json written"
          f"{', fast ladder' if fast else ''})")
