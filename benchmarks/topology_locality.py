"""Topology locality A/B: flat vs hierarchical distance-aware stealing.

    PYTHONPATH=src python -m benchmarks.topology_locality [--fast]

The paper's machine is two ccNUMA sockets; its locality queues exist
because a steal across the socket link costs more than one inside it.
``repro.topology`` makes that structure explicit — a ``DistanceMatrix``
the steal scan walks nearest-tier-first — and this benchmark measures what
the structure buys on the storm-prone workloads:

  topology_flat            8 domains on an explicit flat tree (distance 1
                           everywhere): builds the seed repo's exact
                           single-level scan — the baseline arm, and the
                           proof that a flat ``TopologySpec`` is a no-op.
  topology_two_level       the same greedy runtime on a 4+4 socket pair
                           (near 1, far 4): the scan exhausts in-socket
                           victims before touching the cross-socket link.
  topology_pods_adaptive   the full hierarchical control plane on a 2×4
                           pod tree: adaptive per-level θ, level-aware
                           breaker, breaker-aware cost routing, per-domain
                           governed batching.

Every arm × workload is a checked-in declarative experiment
(``repro.spec.topology_experiments``); this module owns no policy or
workload construction.  The flat arm's steals are additionally classified
*under the two-level lens* — the same 4+4 ``DistanceMatrix`` the two-level
arm actually consults — so "remote" means the same physical link in both
columns and the comparison is apples to apples.

Acceptance gates (asserted inline):
  * every recorded trace replays bit-identically from its header alone
    (schema v3 carries the topology — no factory, no spec lookup);
  * on every workload the two-level arm's cross-socket steals are below
    the flat arm's (what flat stealing silently did across the link);
  * two-level throughput >= flat throughput (locality must not cost
    progress — greedy one-task grabs make victim *eligibility*
    level-order-invariant, so this holds exactly).

CSV: scenario,arm,tasks,makespan,throughput,local_frac,steal_frac,
remote_steals,steal_penalty,replay_exact

``main(json_path=...)`` (default ``BENCH_topology.json`` as a script)
also writes the machine-readable summary per scenario/arm.
"""
from __future__ import annotations

import json
import sys

STEPS = 48
SEED = 0
ARMS = ("topology_flat", "topology_two_level", "topology_pods_adaptive")
SCENARIOS = ("hot_skew", "bursty")
SOCKET_GROUPS = [4, 4]        # the two-level lens: matches topology_two_level


def _remote_under_lens(events, lens) -> int:
    """Steals that crossed ``lens``'s level-2+ links, whatever the run's
    own topology thought (the flat arm consults none)."""
    from repro.trace import event_stolen
    return sum(1 for e in events
               if event_stolen(e) and lens.level(e.src_domain, e.domain) >= 2)


def _makespan(events) -> int:
    """Last execution step + 1 (replay's forced trailing rounds are idle
    by construction and say nothing about the policy)."""
    steps = [e.step for e in events if e.kind in ("run", "steal", "inline")]
    return (max(steps) + 1) if steps else 1


def main(steps: int = STEPS, seed: int = SEED,
         json_path: str | None = None) -> list[str]:
    from repro.spec import topology_experiments
    from repro.topology import grouped
    from repro.trace import dumps_lines, loads_lines, replay

    lens = grouped(SOCKET_GROUPS)
    experiments = topology_experiments(steps=steps, seed=seed)
    lines = ["scenario,arm,tasks,makespan,throughput,local_frac,steal_frac,"
             "remote_steals,steal_penalty,replay_exact"]
    results: dict[str, dict] = {}
    failures: list[str] = []
    for scenario in SCENARIOS:
        per_arm: dict[str, dict] = {}
        for arm in ARMS:
            exp = experiments[f"{arm}_{scenario}"]
            run = exp.run().primary
            # conformance gate: through the JSONL wire format, the header
            # alone (schema v3: spec + topology) must rebuild the recorded
            # hierarchical system bit-for-bit.
            rep = replay(loads_lines(dumps_lines(run.trace)))
            if not rep.matches_recorded:
                failures.append(f"{arm}/{scenario}: header-only replay "
                                f"diverged: {rep.mismatches()}")
            s = run.stats
            events = run.trace.events
            makespan = _makespan(events)
            remote = _remote_under_lens(events, lens)
            per_arm[arm] = {
                "tasks": int(s["executed"]), "makespan": makespan,
                "throughput": s["executed"] / makespan,
                "remote_steals_under_lens": remote,
                "replay_exact": rep.matches_recorded, **s,
            }
            lines.append(
                f"{scenario},{arm},{s['executed']:.0f},{makespan},"
                f"{s['executed'] / makespan:.4f},{s['local_fraction']:.3f},"
                f"{s['steal_fraction']:.3f},{remote},"
                f"{s['steal_penalty']:.0f},{int(rep.matches_recorded)}")
        flat, two = per_arm["topology_flat"], per_arm["topology_two_level"]
        if two["remote_steals_under_lens"] >= flat["remote_steals_under_lens"]:
            failures.append(
                f"{scenario}: two-level arm crossed the socket "
                f"{two['remote_steals_under_lens']}x vs flat's "
                f"{flat['remote_steals_under_lens']}x — nearest-first "
                "stealing failed to keep work in-socket")
        if two["throughput"] < flat["throughput"]:
            failures.append(
                f"{scenario}: two-level throughput {two['throughput']:.4f} "
                f"< flat {flat['throughput']:.4f} — locality cost progress")
        results[scenario] = per_arm
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "topology", "steps": steps, "seed": seed,
                       "socket_lens": SOCKET_GROUPS, "results": results},
                      fh, indent=2)
            fh.write("\n")
    if failures:
        raise SystemExit("topology locality gate failure:\n  "
                         + "\n  ".join(failures))
    return lines


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for ln in main(steps=24 if fast else STEPS,
                   json_path="BENCH_topology.json"):
        print(ln)
    print("\n# topology benchmark complete (BENCH_topology.json written)")
