"""Benchmark harness entry: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]
    PYTHONPATH=src python -m benchmarks.run --spec path/to/policy.json
    PYTHONPATH=src python -m benchmarks.run --policy controlled_replay
    PYTHONPATH=src python -m benchmarks.run --experiment replay_hot_skew
    PYTHONPATH=src python -m benchmarks.run --experiment all
    PYTHONPATH=src python -m benchmarks.run --compare A.jsonl B.jsonl

Prints ``name,us_per_call,derived`` CSV summary lines plus each benchmark's
own CSV block.  ``--full`` uses the paper's full 14400-task grid and 100
samples (slow; the recorded numbers live in EXPERIMENTS.md).

``--spec FILE`` / ``--policy NAME`` run the *runtime* benchmarks
(runtime_throughput, trace_replay, control_plane) against one serialized
``repro.spec`` policy — a JSON file or a registry name — instead of their
built-in policy grids: any scheduling configuration can be benchmarked
without a code edit.  The control-plane win gates are skipped in this mode
(an arbitrary policy makes no controlled-must-win promise).

``--experiment NAME|FILE|all`` executes complete declarative experiments
(``repro.spec.ExperimentSpec``: policy + workload + seeds in one JSON
block) end to end — build, drive the declared workload, record, and
header-only replay-conformance check.  ``all`` runs every checked-in
``specs/experiments/*.json`` golden file (the registry outside a repo
checkout) and refreshes the machine-readable ``BENCH_experiments.json``
artifact; single-name/file runs leave the committed artifact untouched.

``--compare A B`` is the ad-hoc trace-diff entry: each argument is a
recorded JSONL trace file (or rotating-segment directory), and the output
is ``repro.obs.diff_traces`` rendered as markdown — stats deltas, phase
histogram movement, steal-matrix movement, and percentile shifts under
the deterministic min-effect threshold.
"""
from __future__ import annotations

import glob
import os
import sys
import time


def _block(title: str, lines: list[str]) -> None:
    print(f"\n# === {title} ===")
    for ln in lines:
        print(ln)


def _cli_spec(argv: list[str]):
    """The ``RuntimeSpec`` named by --spec FILE / --policy NAME, or None.

    Unknown names/paths exit with the available registry names instead of
    leaking a traceback.
    """
    from repro import spec as rspec

    for flag, resolve in (("--spec", rspec.load), ("--policy", rspec.named)):
        if flag in argv:
            i = argv.index(flag)
            if i + 1 >= len(argv):
                raise SystemExit(f"{flag} needs an argument")
            arg = argv[i + 1]
            try:
                return resolve(arg)
            except (rspec.SpecError, OSError) as e:
                raise SystemExit(
                    f"{flag} {arg!r}: {e}\navailable registry policies: "
                    f"{', '.join(rspec.policy_names())}") from None
    return None


def _cli_experiments(argv: list[str]):
    """``(name -> ExperimentSpec, is_full_set)`` for --experiment
    NAME|FILE|all, or None when the flag is absent.  ``is_full_set`` is the
    single source of truth for whether this run may refresh the committed
    ``BENCH_experiments.json`` artifact."""
    from repro import spec as rspec

    if "--experiment" not in argv:
        return None
    i = argv.index("--experiment")
    if i + 1 >= len(argv):
        raise SystemExit("--experiment needs an argument (a registered "
                         "experiment name, a JSON file, or 'all')")
    arg = argv[i + 1]
    if arg == "all":
        # prefer the checked-in golden files (so the CI gate parses, runs,
        # and replay-checks exactly what is committed); fall back to the
        # in-code registry outside a repo checkout
        exp_dir = os.path.join("specs", "experiments")
        if os.path.isdir(exp_dir):
            files = sorted(glob.glob(os.path.join(exp_dir, "*.json")))
            if not files:
                raise SystemExit(f"--experiment all: {exp_dir}/ exists but "
                                 "holds no *.json experiment files — the "
                                 "gate would validate nothing")
            out = {}
            for path in files:
                try:
                    out[os.path.splitext(os.path.basename(path))[0]] = \
                        rspec.load_experiment(path)
                except rspec.SpecError as e:
                    raise SystemExit(f"--experiment all: {path}: {e}") \
                        from None
            return out, True
        return {name: rspec.experiment(name)
                for name in rspec.experiment_names()}, True
    if arg.endswith(".json") or os.path.exists(arg):
        try:
            return {os.path.splitext(os.path.basename(arg))[0]:
                    rspec.load_experiment(arg)}, False
        except (rspec.SpecError, OSError) as e:
            raise SystemExit(f"--experiment {arg!r}: {e}") from None
    try:
        return {arg: rspec.experiment(arg)}, False
    except rspec.SpecError:
        raise SystemExit(
            f"--experiment: unknown experiment {arg!r}\navailable registry "
            f"experiments: {', '.join(rspec.experiment_names())}\n"
            "(or pass a JSON file path, or 'all')") from None


def compare_traces(path_a: str, path_b: str) -> str:
    """The ``--compare`` body: read two recorded traces and render their
    ``diff_traces`` comparison as markdown (labels are the file names)."""
    from repro.obs import diff_traces, render_diff
    from repro.trace import TraceReader, TraceSchemaError

    traces = []
    for path in (path_a, path_b):
        try:
            traces.append(TraceReader(path).read())
        except (TraceSchemaError, OSError) as e:
            raise SystemExit(f"--compare: {path}: {e}") from None
    diff = diff_traces(traces[0], traces[1])
    return render_diff(diff, label_a=os.path.basename(path_a),
                       label_b=os.path.basename(path_b))


def run_experiments(experiments: dict,
                    json_path: str | None = None) -> list[str]:
    """Execute declarative experiments end to end.

    Per experiment and repeat: build the declared system, drive the
    declared workload while recording, then assert the recorded trace
    replays bit-identically from its own header (the conformance gate).
    Returns CSV lines; writes the machine-readable summary to
    ``json_path``.

    CSV: experiment,repeat,tasks,steps,throughput,local_frac,steal_frac,
    steal_penalty,idle_polls,replay_exact

    A second per-experiment block aggregates across repeats — throughput,
    locality, remote steals and the exact sojourn p50/p95/p99 (pooled task
    timings over every repeat's replayed trace, via ``repro.obs``'s
    nearest-rank percentiles).  The same sojourn percentiles land per run
    in ``BENCH_experiments.json``, alongside an ``aggregates`` block
    (``spec.aggregate_runs``: mean/min/max/stdev per numeric stat over the
    seed-shifted repeats — the Fig. 4 variability ladder the sentinel's
    tolerances are calibrated against).
    """
    import json

    from repro.check import check_trace
    from repro.obs import percentiles
    from repro.spec import aggregate_runs
    from repro.trace import dumps_lines, loads_lines, replay

    lines = ["experiment,repeat,tasks,steps,throughput,local_frac,"
             "steal_frac,steal_penalty,idle_polls,replay_exact"]
    results: dict[str, dict] = {}
    summary_rows: list[str] = []
    diverged: list[str] = []
    for name, exp in experiments.items():
        result = exp.run()
        runs = []
        agg = {"throughput": [], "local": [], "remote": 0}
        sojourns: list[float] = []
        for r, run in enumerate(result.runs):
            # conformance check: through the JSONL wire format, the header
            # alone must reconstruct the recorded system bit-for-bit.  The
            # measured outcome is reported per run; any divergence fails
            # the whole command *after* the artifact is written, so the
            # CSV/JSON always carry honest values.
            rep = replay(loads_lines(dumps_lines(run.trace)))
            if not rep.matches_recorded:
                diverged.append(f"{name} repeat {r}: {rep.mismatches()}")
            # structural legality (repro.check): replay says the stats
            # match; the model checker says the *schedule itself* was legal
            mc = check_trace(run.trace, path=f"{name}[{r}]")
            if not mc.ok:
                diverged.extend(str(v) for v in mc.violations)
            s = run.stats
            steps = run.executor.step_count
            lines.append(
                f"{name},{r},{s['executed']:.0f},{steps},"
                f"{s['executed'] / max(steps, 1):.4f},"
                f"{s['local_fraction']:.3f},{s['steal_fraction']:.3f},"
                f"{s['steal_penalty']:.0f},{s['idle_polls']:.0f},"
                f"{int(rep.matches_recorded)}")
            run_sojourns = [t.sojourn for t in rep.task_times().values()]
            sojourns.extend(run_sojourns)
            agg["throughput"].append(s["executed"] / max(steps, 1))
            agg["local"].append(s["local_fraction"])
            agg["remote"] += int(s["remote_steals"])
            runs.append({"seed": run.seed, "steps": steps,
                         "replay_exact": rep.matches_recorded,
                         "model_check": mc.ok,   # bool: sentinel-neutral
                         "sojourn": (percentiles(run_sojourns)
                                     if run_sojourns else None), **s})
        results[name] = {"experiment": exp.to_dict(), "runs": runs,
                         "aggregates": result.aggregates()}
        p = percentiles(sojourns) if sojourns else \
            {"p50": float("nan"), "p95": float("nan"), "p99": float("nan")}
        summary_rows.append(
            f"{name},{sum(agg['throughput']) / len(agg['throughput']):.4f},"
            f"{sum(agg['local']) / len(agg['local']):.3f},{agg['remote']},"
            f"{p['p50']:.1f},{p['p95']:.1f},{p['p99']:.1f}")
    lines.append("")
    lines.append("experiment,throughput,local_frac,remote_steals,"
                 "sojourn_p50,sojourn_p95,sojourn_p99")
    lines.extend(summary_rows)
    if json_path:
        with open(json_path, "w", encoding="utf-8") as fh:
            json.dump({"bench": "experiments", "results": results},
                      fh, indent=2)
            fh.write("\n")
    if diverged:
        raise SystemExit("replay-conformance failure — header-only replay "
                         "diverged from recorded stats:\n  "
                         + "\n  ".join(diverged))
    return lines


def run_with_spec(spec, full: bool = False) -> None:
    """Drive the runtime benchmarks with ``spec`` as the policy under test."""
    from benchmarks import control_plane, runtime_throughput, trace_replay

    if spec.num_domains != runtime_throughput.NUM_DOMAINS:
        raise SystemExit(
            f"--spec/--policy: the runtime benchmarks drive fixed "
            f"{runtime_throughput.NUM_DOMAINS}-domain workloads; the given "
            f"spec declares num_domains={spec.num_domains} "
            f"(serving-topology specs like 'controlled_serving' benchmark "
            f"through examples/control_serving.py instead)")

    lines = runtime_throughput.main(n_tasks=1600 if full else 160, spec=spec)
    _block("Runtime throughput under --spec policy", lines)
    lines = trace_replay.main(steps=96 if full else 24, spec=spec)
    _block("Trace replay: recorded baseline vs --spec policy", lines)
    lines = control_plane.main(steps=96 if full else 24, spec=spec,
                               gates=False, json_path="BENCH_spec.json")
    _block("Control plane: uncontrolled vs --spec policy (no win gates)",
           lines)
    print("\n# spec-mode run complete (BENCH_spec.json written)")


def main() -> None:
    full = "--full" in sys.argv
    if "--compare" in sys.argv:
        i = sys.argv.index("--compare")
        if len(sys.argv) < i + 3:
            raise SystemExit("--compare needs two trace paths "
                             "(JSONL files or segment directories)")
        print(compare_traces(sys.argv[i + 1], sys.argv[i + 2]), end="")
        return
    cli_experiments = _cli_experiments(sys.argv[1:])
    if cli_experiments is not None:
        # only the full `all` gate refreshes the committed artifact; a
        # single-experiment run must not clobber it with partial data
        experiments, full_set = cli_experiments
        json_path = "BENCH_experiments.json" if full_set else None
        lines = run_experiments(experiments, json_path=json_path)
        _block("Declarative experiments (policy + workload + seeds)", lines)
        print("\n# experiment run complete"
              + (" (BENCH_experiments.json written)" if json_path else ""))
        return
    spec = _cli_spec(sys.argv[1:])
    if spec is not None:
        run_with_spec(spec, full=full)
        return
    from repro.core import PAPER_GRID, SMALL_GRID
    grid = PAPER_GRID if full else SMALL_GRID
    summary = []

    from benchmarks import fig3_policies
    t0 = time.time()
    lines = fig3_policies.main(grid=grid, samples=15 if full else 4)
    dt = time.time() - t0
    _block("Fig 3: scheduling policies x test beds (MLUPs)", lines)
    lq = [l for l in lines if ",omp_lq,s-1/kji" in l]
    ft = [l for l in lines if ",refs,ref_first_touch" in l]
    ratio = (float(lq[0].split(",")[3]) / float(ft[0].split(",")[3])
             if lq and ft else 0.0)
    summary.append(("fig3_policies", dt * 1e6 / max(len(lines), 1),
                    f"lq_vs_firsttouch={ratio:.3f}"))

    from benchmarks import fig4_variability
    t0 = time.time()
    lines = fig4_variability.main(grid=grid, samples=100 if full else 7)
    dt = time.time() - t0
    _block("Fig 4: run-to-run variability", lines)
    max_iqr = max(float(l.split(",")[-1]) for l in lines[1:])
    summary.append(("fig4_variability", dt * 1e6 / max(len(lines), 1),
                    f"max_rel_iqr={max_iqr:.4f}"))

    from benchmarks import runtime_throughput
    t0 = time.time()
    lines = runtime_throughput.main(n_tasks=1600 if full else 160,
                                    json_path="BENCH_runtime.json")
    dt = time.time() - t0
    _block("Runtime: online policies x arrival scenarios", lines)
    rows = {tuple(l.split(",")[:2]): l.split(",") for l in lines[1:]}
    skew_lq = float(rows[("skewed", "locality")][3])
    pen_lq = float(rows[("skewed", "locality")][5])
    pen_ad = float(rows[("skewed", "adaptive")][5])
    summary.append(("runtime_throughput", dt * 1e6 / max(len(lines), 1),
                    f"skew_lq_local={skew_lq:.2f},"
                    f"adapt_penalty_save={1 - pen_ad / max(pen_lq, 1):.2f}"))

    from benchmarks import trace_replay
    t0 = time.time()
    lines = trace_replay.main(steps=96 if full else 24)
    dt = time.time() - t0
    _block("Trace replay: governor A/B on identical recorded traces", lines)
    rows = {tuple(l.split(",")[:2]): l.split(",") for l in lines[1:]}
    hot_greedy = float(rows[("hot_skew", "greedy")][5])
    hot_meas = float(rows[("hot_skew", "measured")][5])
    theta = rows[("hot_skew", "measured")][8]
    summary.append(("trace_replay", dt * 1e6 / max(len(lines), 1),
                    f"hot_measured_penalty_save="
                    f"{1 - hot_meas / max(hot_greedy, 1):.2f},theta={theta}"))

    from benchmarks import control_plane
    t0 = time.time()
    lines = control_plane.main(steps=96 if full else 24,
                               json_path="BENCH_control.json")
    dt = time.time() - t0
    _block("Control plane: controlled vs uncontrolled on recorded traces",
           lines)
    rows = {tuple(l.split(",")[:2]): l.split(",") for l in lines[1:]}
    thr_un = float(rows[("hot_skew", "uncontrolled")][4])
    thr_co = float(rows[("hot_skew", "controlled")][4])
    storms = sum(int(r[8]) for k, r in rows.items() if k[1] == "uncontrolled")
    storms_co = sum(int(r[8]) for k, r in rows.items() if k[1] == "controlled")
    summary.append(("control_plane", dt * 1e6 / max(len(lines), 1),
                    f"hot_thr_gain={thr_co / max(thr_un, 1e-9):.2f}x,"
                    f"storms={storms}->{storms_co}"))

    from benchmarks import topology_locality
    t0 = time.time()
    lines = topology_locality.main(steps=96 if full else 48,
                                   json_path="BENCH_topology.json")
    dt = time.time() - t0
    _block("Topology: flat vs hierarchical distance-aware stealing", lines)
    rows = {tuple(l.split(",")[:2]): l.split(",") for l in lines[1:]}
    rem_flat = int(rows[("hot_skew", "topology_flat")][7])
    rem_two = int(rows[("hot_skew", "topology_two_level")][7])
    loc_pods = float(rows[("hot_skew", "topology_pods_adaptive")][5])
    summary.append(("topology_locality", dt * 1e6 / max(len(lines), 1),
                    f"hot_cross_socket={rem_flat}->{rem_two},"
                    f"pods_local={loc_pods:.2f}"))

    from benchmarks import table1_stream
    t0 = time.time()
    lines = table1_stream.main()
    dt = time.time() - t0
    _block("Table 1: STREAM envelopes (model vs paper)", lines)
    errs = [float(l.split(",")[4]) for l in lines[1:] if l.split(",")[4]]
    summary.append(("table1_stream", dt * 1e6 / max(len(lines), 1),
                    f"max_rel_err={max(errs):.3f}"))

    from benchmarks import jacobi_weak_scaling
    t0 = time.time()
    lines = jacobi_weak_scaling.main(device_counts=(4, 8) if not full
                                     else (4, 8, 16))
    dt = time.time() - t0
    _block("Jacobi distributed: locality vs scattered collective bytes", lines)
    ratios = [float(l.split(",")[3]) for l in lines[1:]
              if l.split(",")[1] == "scattered"]
    summary.append(("jacobi_weak_scaling", dt * 1e6 / max(len(lines), 1),
                    f"max_scatter_ratio={max(ratios) if ratios else 0:.1f}x"))

    from benchmarks import roofline_lm
    t0 = time.time()
    lines = roofline_lm.main("single")
    dt = time.time() - t0
    _block("Roofline: 40 (arch x shape) cells, single-pod", lines)
    ok = sum(1 for l in lines[1:] if ",ok," in l)
    summary.append(("roofline_lm", dt * 1e6 / max(len(lines), 1),
                    f"cells_ok={ok}"))

    print("\n# === summary (name,us_per_call,derived) ===")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
