"""Beyond-paper: the distributed Jacobi sweep's collective traffic under
locality (contiguous) vs locality-oblivious (scattered) block assignment,
measured from compiled HLO at increasing device counts.

This is the paper's central claim transplanted to the TPU tier: the
nonlocal-traffic gap grows linearly with blocks-per-device for the
scattered schedule while staying constant for the locality schedule.

Runs in a subprocess (needs multi-device host platform); emits CSV:
devices,schedule,collective_bytes_per_dev,ratio
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(n)d"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.stencil.jacobi import (JacobiGridConfig, make_contiguous_sweep,
                                  make_scattered_sweep, scatter_lattice)
from repro.roofline.hlo_cost import analyze_text

n = %(n)d
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
cfg = JacobiGridConfig(ni=16 * n, nj=24, nk=32)
f = jnp.zeros((cfg.ni, cfg.nj, cfg.nk), jnp.float32)
c = jnp.float32(1/6)
out = {}
with jax.set_mesh(mesh):
    fs = jax.device_put(f, NamedSharding(mesh, P("data", None, None)))
    txt = jax.jit(make_contiguous_sweep(cfg)).lower(fs, c).compile().as_text()
    out["contiguous"] = sum(analyze_text(txt).coll.values())
    bpd = 4
    fs2 = jax.device_put(scatter_lattice(f, n, bpd),
                         NamedSharding(mesh, P("data", None, None)))
    txt2 = jax.jit(make_scattered_sweep(cfg, blocks_per_dev=bpd)).lower(fs2, c).compile().as_text()
    out["scattered"] = sum(analyze_text(txt2).coll.values())
print("RESULT " + json.dumps(out))
"""


def main(device_counts=(4, 8)) -> list[str]:
    lines = ["devices,schedule,collective_bytes_per_dev,ratio_vs_contiguous"]
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    for n in device_counts:
        proc = subprocess.run([sys.executable, "-c", _CHILD % {"n": n}],
                              env=env, capture_output=True, text=True,
                              timeout=600)
        if proc.returncode != 0:
            lines.append(f"{n},ERROR,{proc.stderr[-120:]},")
            continue
        for ln in proc.stdout.splitlines():
            if ln.startswith("RESULT "):
                res = json.loads(ln[len("RESULT "):])
                ratio = res["scattered"] / max(res["contiguous"], 1)
                lines.append(f"{n},contiguous,{res['contiguous']:.0f},1.0")
                lines.append(f"{n},scattered,{res['scattered']:.0f},{ratio:.1f}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
