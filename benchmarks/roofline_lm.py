"""Roofline table for the 40 assigned (arch x shape) cells, read from the
dry-run artifact (experiments/dryrun.json — regenerate with
`python -m repro.launch.dryrun`).

Emits CSV:
arch,shape,mesh,status,bottleneck,t_compute_s,t_memory_s,t_collective_s,
peak_gib_per_chip,useful_flops_ratio
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun.json"


def main(mesh: str = "single") -> list[str]:
    lines = ["arch,shape,mesh,status,bottleneck,t_compute_s,t_memory_s,"
             "t_collective_s,peak_gib_per_chip,useful_flops_ratio"]
    if not DRYRUN.exists():
        lines.append("MISSING,run `python -m repro.launch.dryrun` first,,,,,,,,")
        return lines
    data = json.loads(DRYRUN.read_text())
    for key in sorted(data):
        arch, shape, m = key.split("|")
        if m != mesh:
            continue
        v = data[key]
        if v["status"] != "ok":
            lines.append(f"{arch},{shape},{m},{v['status']},,,,,,")
            continue
        r = v["roofline"]
        lines.append(
            f"{arch},{shape},{m},ok,{r['bottleneck']},"
            f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
            f"{r['t_collective_s']:.3e},"
            f"{v['memory']['peak_estimate_per_chip']/2**30:.2f},"
            f"{v['useful_flops_ratio']:.3f}")
    return lines


if __name__ == "__main__":
    import sys
    mesh = "multi" if "--multi" in sys.argv else "single"
    for line in main(mesh):
        print(line)
