"""Paper Fig. 4: run-to-run performance variability (quantile bands).

OpenMP tasking (left panel) and TBB parallel_for (right panel) across
seeds; the paper's observation is that the spread is surprisingly small.
Emits CSV: system,policy,median,q05,q25,q75,q95,rel_iqr
"""
from __future__ import annotations

import numpy as np

from repro.core import (SMALL_GRID, PAPER_GRID, NEHALEM_EP, ISTANBUL,
                        OpenMPLocalityQueues, OpenMPTasking, TBBParallelFor,
                        place, run_samples, summarize, tbb_first_touch)


def main(grid=SMALL_GRID, samples: int = 9) -> list[str]:
    lines = ["system,policy,median,q05,q25,q75,q95,rel_iqr"]
    for topo in (NEHALEM_EP, ISTANBUL):
        cases = []
        homes_s1 = place("static1", grid, topo)
        cases.append(("omp_task_kji",
                      lambda: OpenMPTasking(submit_order="kji"), homes_s1))
        cases.append(("omp_lq_kji",
                      lambda: OpenMPLocalityQueues(submit_order="kji"),
                      homes_s1))
        rng = np.random.default_rng(5)
        homes_tbb, threads = tbb_first_touch(grid, topo, rng)
        cases.append(("tbb_parallel_for",
                      lambda t=threads: TBBParallelFor(affinity=False),
                      homes_tbb))
        for label, mk, homes in cases:
            s = summarize(run_samples(grid, topo, mk, homes,
                                      n_samples=samples))
            rel_iqr = (s["q75"] - s["q25"]) / s["median_mlups"]
            lines.append(f"{topo.name},{label},{s['median_mlups']:.0f},"
                         f"{s['q05']:.0f},{s['q25']:.0f},{s['q75']:.0f},"
                         f"{s['q95']:.0f},{rel_iqr:.4f}")
    return lines


if __name__ == "__main__":
    import sys
    full = "--full" in sys.argv
    for line in main(grid=PAPER_GRID if full else SMALL_GRID,
                     samples=100 if full else 9):
        print(line)
