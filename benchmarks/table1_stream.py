"""Paper Table 1: STREAM-copy bandwidth envelopes the cost model reproduces.

Emits CSV: system,quantity,model_gbs,paper_gbs,rel_err
"""
from __future__ import annotations

from repro.core import TESTBED, stream_sanity

PAPER = {
    "istanbul": {"full": 38.6, "socket": 9.9},
    "nehalem_ep": {"full": 36.6, "socket": 18.9},
    "nehalem_ex": {"full": 33.4, "socket": 8.15},
}


def main() -> list[str]:
    lines = ["system,quantity,model_gbs,paper_gbs,rel_err"]
    for name, topo in TESTBED.items():
        s = stream_sanity(topo)
        pairs = [("full_system", s["full_local_bw"], PAPER[name]["full"]),
                 ("single_socket", s["serial_ld0_bw"], PAPER[name]["socket"])]
        for qty, model, paper in pairs:
            lines.append(f"{name},{qty},{model:.2f},{paper:.2f},"
                         f"{abs(model-paper)/paper:.3f}")
        lines.append(f"{name},interleaved,{s['interleaved_bw']:.2f},,")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
