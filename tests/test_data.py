"""Data pipeline: determinism, learnable structure, locality sharding."""
import numpy as np

from repro.data.pipeline import (DataConfig, ShardedLoader, SyntheticCorpus,
                                 make_batch_iterator)


class TestDeterminism:
    def test_shards_reproducible(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
        np.testing.assert_array_equal(c1.shard_tokens(3, 128),
                                      c2.shard_tokens(3, 128))

    def test_shards_differ(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        c = SyntheticCorpus(cfg)
        assert not np.array_equal(c.shard_tokens(0, 128), c.shard_tokens(1, 128))

    def test_iterator_replay(self):
        it1 = make_batch_iterator(500, 16, 4, seed=9)
        it2 = make_batch_iterator(500, 16, 4, seed=9)
        for _ in range(3):
            b1, b2 = next(it1), next(it2)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
            np.testing.assert_array_equal(b1["labels"], b2["labels"])

    def test_labels_are_shifted_tokens(self):
        b = next(make_batch_iterator(500, 16, 2, seed=1))
        assert b["tokens"].shape == (2, 16)
        assert b["labels"].shape == (2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestStructure:
    def test_bigram_structure_learnable(self):
        """The injected deterministic bigram makes next-token prediction
        beat the unigram entropy — a ~100M model has signal to learn."""
        cfg = DataConfig(vocab_size=200, seq_len=32, global_batch=4)
        c = SyntheticCorpus(cfg)
        toks = c.shard_tokens(0, 50000)
        prev, nxt = toks[:-1], toks[1:]
        predicted = (prev + c.bigram_shift[prev % 257]) % cfg.vocab_size
        hit = float(np.mean(nxt == predicted))
        # ~50% of positions substitute the deterministic bigram, but the
        # predictor only fires when the PREVIOUS token was left random too,
        # so the observable hit rate is ~25% — far above the 1/V floor.
        assert hit > 0.2


class TestLocalitySharding:
    def test_all_shards_covered_once(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                         num_shards=16, num_hosts=4)
        loaders = [ShardedLoader(cfg, host_id=h) for h in range(4)]
        owned = sorted(s for l in loaders for s in l.my_shards)
        assert owned == list(range(16))

    def test_locality_fraction_high(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8,
                         num_shards=64, num_hosts=8)
        l = ShardedLoader(cfg, host_id=0)
        assert l.assignment.locality_fraction > 0.9

    def test_prefetch_iterator_yields(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4,
                         num_shards=4, num_hosts=1)
        l = ShardedLoader(cfg, host_id=0)
        it = iter(l)
        b = next(it)
        assert b["tokens"].shape == (4, 8)
        l.close()
