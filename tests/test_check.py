"""repro.check: the determinism linter and the trace model checker.

Two families of guarantees:

  * the *shipped tree* gates green — zero unsuppressed lint violations,
    every suppression reasoned, every committed trace fixture and fresh
    registry-policy trace structurally legal;
  * every *rule* actually fires — seeded source snippets for each lint
    rule, seeded trace mutations (duplicate exec, illegal steal level,
    non-monotone step, FIFO swap, stripped meta, tampered stats) for each
    model rule, asserting the checker names the violated rule.
"""
from __future__ import annotations

import dataclasses
import json

import pytest

from repro import check
from repro.check.__main__ import main as check_main
from repro.runtime import AdaptiveSteal, Worker
from repro.spec import ObsSpec, registry
from repro.spec.validate import probe_trace
from repro.trace import TraceReader, dumps_lines, loads_lines

FIXTURE = "tests/data/v1_trace_fixture.jsonl"
SEGMENTS = "tests/data/v1_segments"


def rules_of(violations):
    return {v.rule for v in violations if not v.suppressed}


# ---------------------------------------------------------------------------
# the determinism linter
# ---------------------------------------------------------------------------

class TestLintRules:
    def test_wall_clock_module_call(self):
        src = "import time\n\ndef f():\n    return time.time()\n"
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"wall-clock"}

    def test_wall_clock_from_import(self):
        src = ("from time import perf_counter_ns\n\n"
               "def f():\n    return perf_counter_ns()\n")
        assert rules_of(check.lint_source(src, "control/fake.py")) \
            == {"wall-clock"}

    def test_datetime_now(self):
        src = ("import datetime\n\n"
               "def f():\n    return datetime.datetime.now()\n")
        assert rules_of(check.lint_source(src, "obs/fake.py")) \
            == {"wall-clock"}

    def test_stdlib_random(self):
        src = "import random\n\ndef f(xs):\n    random.shuffle(xs)\n"
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"unseeded-rng"}

    def test_np_random_module_function(self):
        src = "import numpy as np\n\ndef f():\n    return np.random.rand(3)\n"
        assert rules_of(check.lint_source(src, "trace/fake.py")) \
            == {"unseeded-rng"}

    def test_unseeded_default_rng(self):
        src = ("import numpy as np\n\n"
               "def f():\n    return np.random.default_rng()\n")
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"unseeded-rng"}

    def test_seeded_default_rng_ok(self):
        src = ("import numpy as np\n\n"
               "def f(seed):\n"
               "    rng = np.random.default_rng(seed)\n"
               "    return rng.integers(0, 4)\n")
        assert check.lint_source(src, "runtime/fake.py") == []

    def test_unordered_iteration(self):
        src = ("def f(xs):\n"
               "    s = set(xs)\n"
               "    for x in s:\n"
               "        yield x\n")
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"unordered-iter"}

    def test_sorted_set_iteration_ok(self):
        src = ("def f(xs):\n"
               "    for x in sorted(set(xs)):\n"
               "        yield x\n")
        assert check.lint_source(src, "runtime/fake.py") == []

    def test_set_comprehension_iterable(self):
        src = "def f(xs):\n    return [x for x in {1, 2, 3}]\n"
        assert rules_of(check.lint_source(src, "control/fake.py")) \
            == {"unordered-iter"}

    def test_id_ordering(self):
        src = "def f(task, d):\n    d[id(task)] = 1\n"
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"id-order"}

    def test_env_read(self):
        src = "import os\n\ndef f():\n    return os.environ['SEED']\n"
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"env-read"}

    def test_env_read_out_of_scope_package(self):
        # env-read is scoped to runtime/control/obs; launch code may read it
        src = "import os\n\ndef f():\n    return os.environ['SEED']\n"
        assert check.lint_source(src, "launch/fake.py") == []

    def test_state_view(self):
        src = ("class Gov:\n"
               "    def __init__(self):\n"
               "        self._idle = {}\n"
               "    def idle(self):\n"
               "        return self._idle\n")
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"state-view"}

    def test_state_view_copy_ok(self):
        src = ("class Gov:\n"
               "    def __init__(self):\n"
               "        self._idle = {}\n"
               "    def idle(self):\n"
               "        return dict(self._idle)\n")
        assert check.lint_source(src, "runtime/fake.py") == []

    def test_out_of_scope_package_is_quiet(self):
        # models/ is the jax side: clocks and device RNG are its job
        src = "import time\n\ndef f():\n    return time.time()\n"
        assert check.lint_source(src, "models/fake.py") == []


class TestSuppressions:
    SRC = ("import time\n\ndef f():\n"
           "    # repro: allow[wall-clock] {reason}\n"
           "    return time.time()\n")

    def test_reasoned_suppression_silences(self):
        out = check.lint_source(
            self.SRC.format(reason="sanctioned probe"), "runtime/fake.py")
        assert len(out) == 1 and out[0].suppressed
        assert out[0].reason == "sanctioned probe"

    def test_bare_suppression_is_flagged(self):
        src = ("import time\n\ndef f():\n"
               "    # repro: allow[wall-clock]\n"
               "    return time.time()\n")
        rules = rules_of(check.lint_source(src, "runtime/fake.py"))
        assert "bad-suppression" in rules
        assert "wall-clock" in rules          # no reason -> nothing silenced

    def test_unknown_rule_is_flagged(self):
        src = "# repro: allow[not-a-rule] because reasons\nX = 1\n"
        assert rules_of(check.lint_source(src, "runtime/fake.py")) \
            == {"bad-suppression"}

    def test_docstring_mention_is_not_a_suppression(self):
        src = ('"""Docs: use `# repro: allow[unknown-thing]` comments."""\n'
               "X = 1\n")
        assert check.lint_source(src, "runtime/fake.py") == []


class TestHookPurity:
    IMPURE = ("import time\n\n"
              "def hook(task, domain, step):\n"
              "    _helper()\n\n"
              "def _helper():\n"
              "    time.time()\n\n"
              "class Recorder:\n"
              "    def attach(self, ex):\n"
              "        ex.submit_hook = hook\n")

    def test_impure_hook_flagged_transitively(self):
        out = check.check_hook_purity({"runtime/fake.py": self.IMPURE})
        assert rules_of(out) == {"hook-purity"}
        (v,) = out
        assert "wall-clock" in v.message and "submit_hook" in v.message
        assert v.line == 7                     # the impure site, not the root

    def test_pure_hook_ok(self):
        src = ("def hook(task, domain, step):\n"
               "    return domain\n\n"
               "class Recorder:\n"
               "    def attach(self, ex):\n"
               "        ex.submit_hook = hook\n")
        assert check.check_hook_purity({"runtime/fake.py": src}) == []

    def test_governor_object_methods_are_roots(self):
        src = ("import time\n\n"
               "class Gov:\n"
               "    def on_idle(self, worker):\n"
               "        time.time()\n\n"
               "def build(ex):\n"
               "    ex.governor = Gov()\n")
        out = check.check_hook_purity({"runtime/fake.py": src})
        assert rules_of(out) == {"hook-purity"}

    def test_suppression_applies_at_impure_site(self):
        src = self.IMPURE.replace(
            "    time.time()",
            "    # repro: allow[hook-purity] sanctioned in this test\n"
            "    time.time()")
        out = check.check_hook_purity({"runtime/fake.py": src})
        assert all(v.suppressed for v in out)


class TestShippedTree:
    def test_tree_lints_clean(self):
        active = [v for v in check.lint_tree() if not v.suppressed]
        assert active == [], "\n".join(str(v) for v in active)

    def test_every_suppression_carries_a_reason(self):
        for v in check.lint_tree():
            if v.suppressed:
                assert v.reason, f"reasonless suppression: {v}"

    def test_cli_gate_passes_on_tree(self, capsys):
        assert check_main(["--quiet"]) == 0


# ---------------------------------------------------------------------------
# the trace model checker
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fixture_trace():
    return TraceReader(FIXTURE).read()


class TestModelFixtures:
    def test_v1_fixture_is_legal(self):
        result = check.check_path(FIXTURE)
        assert result.ok, result.violations

    def test_v1_segments_are_legal(self):
        result = check.check_path(SEGMENTS)
        assert result.ok, result.violations

    @pytest.mark.parametrize("policy", ["replay_baseline",
                                        "topology_two_level",
                                        "topology_pods_adaptive"])
    def test_fresh_registry_policy_traces_are_legal(self, policy):
        trace = probe_trace(registry.named(policy))
        result = check.check_trace(trace, path=policy)
        assert result.ok, result.violations

    def test_fresh_v4_obs_trace_is_legal(self):
        spec = dataclasses.replace(registry.named("replay_baseline"),
                                   obs=ObsSpec(enabled=True))
        trace = probe_trace(spec)
        assert trace.obs_dict is not None      # schema v4 header
        result = check.check_trace(trace, path="obs_enabled")
        assert result.ok, result.violations


def mutate(trace, *, events=None, meta=None, stats=None, submissions=None):
    """A shallow variant of ``trace`` with the given parts replaced."""
    return dataclasses.replace(
        trace,
        meta=dict(trace.meta) if meta is None else meta,
        submissions=list(trace.submissions) if submissions is None
        else submissions,
        events=list(trace.events) if events is None else events,
        stats=dict(trace.stats) if stats is None else stats)


class TestModelMutations:
    def exec_index(self, trace, stolen=False):
        from repro.trace import event_stolen
        for i, e in enumerate(trace.events):
            if e.kind in ("run", "steal", "inline") and e.task_uid >= 0:
                if not stolen or event_stolen(e):
                    return i
        pytest.skip("fixture lacks the needed event shape")

    def test_duplicate_exec_names_exec_unique(self, fixture_trace):
        i = self.exec_index(fixture_trace)
        events = list(fixture_trace.events)
        events.append(events[i])
        bad = mutate(fixture_trace, events=events)
        assert "exec-unique" in rules_of(
            check.check_trace(bad).violations)

    def test_illegal_steal_domain_names_steal_level(self, fixture_trace):
        i = self.exec_index(fixture_trace, stolen=True)
        events = list(fixture_trace.events)
        events[i] = dataclasses.replace(events[i], src_domain=99)
        bad = mutate(fixture_trace, events=events)
        assert "steal-level" in rules_of(check.check_trace(bad).violations)

    def test_steal_under_nosteal_names_steal_level(self, fixture_trace):
        self.exec_index(fixture_trace, stolen=True)   # needs >=1 steal
        meta = dict(fixture_trace.meta)
        meta["governor"] = "NoSteal"
        bad = mutate(fixture_trace, meta=meta)
        assert "steal-level" in rules_of(check.check_trace(bad).violations)

    def test_non_monotone_step_names_step_monotone(self, fixture_trace):
        events = list(fixture_trace.events)
        events[-1] = dataclasses.replace(events[-1], step=0)
        bad = mutate(fixture_trace, events=events)
        assert "step-monotone" in rules_of(
            check.check_trace(bad).violations)

    def test_fifo_swap_names_fifo_order(self, fixture_trace):
        # swap the uids of two executions served from the same queue
        events = list(fixture_trace.events)
        by_src = {}
        pair = None
        for i, e in enumerate(events):
            if e.kind in ("run", "steal", "inline") and e.task_uid >= 0:
                src = e.src_domain if e.src_domain >= 0 else e.domain
                if src in by_src:
                    pair = (by_src[src], i)
                    break
                by_src[src] = i
        assert pair is not None
        a, b = pair
        events[a], events[b] = (
            dataclasses.replace(events[a], task_uid=events[b].task_uid),
            dataclasses.replace(events[b], task_uid=events[a].task_uid))
        bad = mutate(fixture_trace, events=events)
        assert "fifo-order" in rules_of(check.check_trace(bad).violations)

    def test_missing_meta_key_names_fidelity_keys(self, fixture_trace):
        meta = dict(fixture_trace.meta)
        del meta["seed"]
        bad = mutate(fixture_trace, meta=meta)
        assert "fidelity-keys" in rules_of(
            check.check_trace(bad).violations)

    def test_tampered_stats_names_stats_consistency(self, fixture_trace):
        stats = dict(fixture_trace.stats)
        stats["executed"] = stats["executed"] + 1
        bad = mutate(fixture_trace, stats=stats)
        assert "stats-consistency" in rules_of(
            check.check_trace(bad).violations)

    def test_duplicate_submission_names_submit_unique(self, fixture_trace):
        subs = list(fixture_trace.submissions)
        subs.append(subs[0])
        bad = mutate(fixture_trace, submissions=subs)
        assert "submit-unique" in rules_of(
            check.check_trace(bad).violations)

    def test_windowed_trace_skips_stream_checks(self, fixture_trace):
        # claim the ring buffer dropped events: occupancy checks must skip
        # (recorded as notes), not fire false violations
        counts = dict(fixture_trace.event_counts)
        first = next(iter(counts))
        counts[first] = counts[first] + 5
        bad = dataclasses.replace(mutate(fixture_trace),
                                  event_counts=counts)
        result = check.check_trace(bad)
        assert "fifo-order" not in rules_of(result.violations)
        assert any("skipped" in n for n in result.notes)


class TestModelCli:
    def test_cli_exits_nonzero_and_names_rule(self, tmp_path, capsys,
                                              fixture_trace):
        events = list(fixture_trace.events)
        i = next(i for i, e in enumerate(events)
                 if e.kind in ("run", "steal", "inline"))
        events.append(events[i])               # duplicate execution
        bad = mutate(fixture_trace, events=events)
        path = tmp_path / "bad.jsonl"
        path.write_text("\n".join(dumps_lines(bad)) + "\n")
        report = tmp_path / "report.json"
        rc = check_main(["model", str(path), "--json", str(report),
                         "--quiet"])
        assert rc == 1
        data = json.loads(report.read_text())
        rules = {v["rule"] for m in data["model"] for v in m["violations"]}
        assert "exec-unique" in rules

    def test_cli_unreadable_trace_fails_closed(self, tmp_path):
        missing = tmp_path / "nope.jsonl"
        assert check_main(["model", str(missing), "--quiet"]) == 1

    def test_cli_all_mode_over_fixtures(self, capsys):
        assert check_main(["all", FIXTURE, SEGMENTS, "--quiet"]) == 0


# ---------------------------------------------------------------------------
# satellite: AdaptiveSteal state hygiene
# ---------------------------------------------------------------------------

class TestAdaptiveStateHygiene:
    def test_depth_reads_do_not_grow_idle_state(self):
        gov = AdaptiveSteal()
        w = Worker(wid=3, domain=0)
        gov.min_victim_depth(w)
        gov.min_victim_depth_at(w, level=1)
        assert gov.idle_counts() == {}         # probes left no residue

    def test_idle_counts_is_a_snapshot(self):
        gov = AdaptiveSteal()
        w = Worker(wid=1, domain=0)
        gov.on_idle(w)
        snap = gov.idle_counts()
        snap[1] = 99
        snap[7] = 5
        assert gov.idle_counts() == {1: 1}

    def test_level_penalty_estimates_is_a_snapshot(self):
        gov = AdaptiveSteal()
        w = Worker(wid=0, domain=0)
        gov.on_execute(w, stolen=True, penalty=8.0, level=2)
        snap = gov.level_penalty_estimates()
        snap[2] = -1.0
        assert gov.level_penalty_estimates()[2] == 8.0

    def test_idle_decay_still_reaches_floor(self):
        gov = AdaptiveSteal(penalty_hint=16.0)
        w = Worker(wid=0, domain=0)
        for _ in range(64):
            gov.on_idle(w)
        assert gov.min_victim_depth(w) == 1    # starved worker still steals
