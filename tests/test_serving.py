"""Serving engine: router policies preserve outputs, change locality stats."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=96)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n=8, replicas=2, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        toks = rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 14)))
        home = int(rng.integers(0, replicas)) if rng.random() < 0.7 else -1
        out.append(Request(uid=i, tokens=toks, max_new=4, home_replica=home))
    return out


class TestRouterPolicies:
    def test_outputs_identical_across_policies(self, small_model):
        cfg, model, params = small_model
        outs = {}
        for policy in ("locality", "round_robin", "single_queue"):
            eng = ServingEngine(model, params, num_replicas=2, max_seq=64,
                                policy=policy)
            for r in _requests(cfg):
                eng.submit(r)
            done = eng.run_until_drained()
            outs[policy] = {r.uid: tuple(r.out_tokens) for r in done}
        assert outs["locality"] == outs["round_robin"] == outs["single_queue"]

    def test_locality_policy_maximizes_local_fraction(self, small_model):
        cfg, model, params = small_model
        stats = {}
        for policy in ("locality", "round_robin"):
            eng = ServingEngine(model, params, num_replicas=2, max_seq=64,
                                policy=policy)
            for r in _requests(cfg, n=12, seed=2):
                eng.submit(r)
            eng.run_until_drained()
            stats[policy] = eng.stats
        assert stats["locality"].locality_fraction >= \
            stats["round_robin"].locality_fraction

    def test_steal_happens_under_skewed_load(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, num_replicas=2, max_seq=64,
                            policy="locality")
        # all requests homed on replica 0: replica 1 must steal
        rng = np.random.default_rng(1)
        for i in range(6):
            toks = rng.integers(0, cfg.vocab_size, size=8)
            eng.submit(Request(uid=i, tokens=toks, max_new=2, home_replica=0))
        eng.run_until_drained()
        assert eng.stats.stolen > 0
        assert eng.stats.served == 6

    def test_trace_hook_records_replayable_router_trace(self, small_model):
        from repro import trace as rtrace
        cfg, model, params = small_model
        rec = rtrace.TraceRecorder()
        eng = ServingEngine(model, params, num_replicas=2, max_seq=64,
                            policy="locality", trace=rec)
        for r in _requests(cfg, n=8, seed=3):
            eng.submit(r)
        eng.run_until_drained()
        t = rec.finish()
        assert t.n_tasks == 8
        assert t.stats["executed"] == eng.stats.served
        # submission costs carry the prompt length (the engine's task cost)
        assert all(s.cost >= 1 for s in t.submissions)
        # the recorded router schedule replays deterministically (payloads
        # are opaque, so replay re-decides scheduling, not decoding)
        res = rtrace.replay(t, lambda tr: rtrace.executor_from_meta(
            tr, steal_penalty=lambda task, w: task.cost))
        assert res.stats["executed"] == 8

    def test_greedy_decode_matches_model(self, small_model):
        """Engine output == hand-rolled prefill+argmax decode."""
        cfg, model, params = small_model
        import jax.numpy as jnp
        toks = np.arange(7) % cfg.vocab_size
        eng = ServingEngine(model, params, num_replicas=1, max_seq=64)
        eng.submit(Request(uid=0, tokens=toks, max_new=3))
        done = eng.run_until_drained()

        caches = model.init_cache(1, 64)
        logits, caches = model.prefill(
            params, {"tokens": jnp.asarray(toks, jnp.int32)[None]}, caches)
        pos = len(toks)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        expect = []
        for _ in range(3):
            expect.append(int(cur[0, 0]))
            logits, caches = model.decode_step(params, cur, pos, caches)
            cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            pos += 1
        assert done[0].out_tokens == expect
