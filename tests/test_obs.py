"""repro.obs: spans, metrics, Perfetto export, profiler, obs passivity.

The load-bearing gate lives in ``TestObsPassivity``: enabling observation
(``ObsSpec.enabled``, with or without the profiler) must leave the schedule
bit-identical — same ``RuntimeStats``, same replay — for every registry
policy.  The hypothesis classes gate the span-tree structural invariants
``repro.obs.spans`` promises (well-nestedness, one path per task, exact
partition of submitted uids into observed + missing).
"""
import dataclasses
import json
import warnings

import pytest

from repro import obs, spec, trace
from repro.runtime import EventLog


def _workload(num_domains=4, steps=16, seed=2, p_hot=0.8):
    return trace.lognormal_costs(
        trace.hot_skew(trace.poisson(rate=num_domains, steps=steps,
                                     num_domains=num_domains, seed=seed),
                       hot_domain=0, p_hot=p_hot, seed=seed),
        median=2.0, sigma=0.75, seed=seed)


def _recorded(s=None, **wl_kwargs):
    """Build ``s`` (default: an observed, recorded 4-domain spec), drive the
    standard workload, return the finished trace."""
    if s is None:
        s = spec.RuntimeSpec(
            num_domains=4,
            penalty=spec.PenaltySpec(kind="constant", value=4.0),
            trace=spec.TraceSpec(record=True),
            obs=spec.ObsSpec(enabled=True))
    built = s.build()
    trace.drive(built.executor, _workload(num_domains=s.num_domains,
                                          **wl_kwargs))
    return built, built.recorder.finish()


class TestPercentiles:
    def test_nearest_rank_is_exact_and_observed(self):
        vals = list(range(1, 11))                    # 1..10
        assert obs.percentile(vals, 50) == 5
        assert obs.percentile(vals, 95) == 10
        assert obs.percentile(vals, 0) == 1
        assert obs.percentile(vals, 100) == 10
        # nearest-rank always returns a member of the sample
        assert obs.percentile([3.5, 1.25, 9.75], 50) in (1.25, 3.5, 9.75)

    def test_order_independence(self):
        a = [5.0, 1.0, 9.0, 3.0, 7.0]
        for q in (10, 50, 90, 99):
            assert obs.percentile(a, q) == obs.percentile(sorted(a), q)

    def test_empty_and_out_of_range_raise(self):
        with pytest.raises(ValueError, match="empty"):
            obs.percentile([], 50)
        with pytest.raises(ValueError, match="outside"):
            obs.percentile([1.0], 101)

    def test_percentiles_dict_labels(self):
        d = obs.percentiles(list(range(100)), qs=(50, 99, 99.9))
        assert set(d) == {"p50", "p99", "p99.9"}
        assert d["p50"] == 49


class TestHistogram:
    def test_snapshot_is_deterministic(self):
        a, b = obs.Histogram(), obs.Histogram()
        vals = [0.1, 1.0, 7.0, 7.0, 300.0, 1e9]
        a.record_many(vals)
        b.record_many(reversed(vals))
        assert a.snapshot() == b.snapshot()

    def test_single_value_quantile_exact(self):
        h = obs.Histogram()
        h.record(7.0)
        assert h.quantile(50) == 7.0 == h.quantile(99)

    def test_overflow_bucket_reports_observed_max(self):
        h = obs.Histogram(lo=1.0, growth=2.0, buckets=3)  # bounds 1,2,4
        h.record(100.0)
        h.record(9.0)
        assert h.quantile(99) == 100.0
        assert h.nonzero_buckets() == [[100.0, 2]]

    def test_quantile_clamped_to_observed_range(self):
        h = obs.Histogram(lo=1.0, growth=2.0, buckets=8)
        h.record_many([3.0, 3.0, 3.0])               # land in bucket ub=4
        assert h.quantile(50) == 3.0                 # clamped to vmax

    def test_empty_histogram(self):
        h = obs.Histogram()
        assert h.snapshot() == {"count": 0}
        assert h.mean == 0.0
        with pytest.raises(ValueError, match="empty"):
            h.quantile(50)

    def test_bad_ladder_rejected(self):
        with pytest.raises(ValueError):
            obs.Histogram(lo=0.0)
        with pytest.raises(ValueError):
            obs.Histogram(growth=1.0)
        with pytest.raises(ValueError):
            obs.Histogram(buckets=0)

    def test_mean_min_max(self):
        h = obs.Histogram()
        h.record_many([2.0, 4.0, 6.0])
        s = h.snapshot()
        assert (s["mean"], s["min"], s["max"]) == (4.0, 2.0, 6.0)


class TestRegistry:
    def test_get_or_create_returns_same_instrument(self):
        r = obs.Registry()
        c = r.counter("x")
        c.inc(3)
        assert r.counter("x") is c
        assert r.snapshot()["x"] == 3

    def test_kind_mismatch_raises(self):
        r = obs.Registry()
        r.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            r.histogram("x")

    def test_snapshot_sorted_and_json_ready(self):
        r = obs.Registry()
        r.gauge("b").set(2.5)
        r.counter("a").inc()
        r.histogram("c").record(1.0)
        snap = r.snapshot()
        assert list(snap) == ["a", "b", "c"]
        json.dumps(snap)                              # must serialize

    def test_counter_monotone(self):
        with pytest.raises(ValueError, match="up"):
            obs.Counter().inc(-1)

    def test_spec_ladder_applies_to_histograms(self):
        r = obs.Registry(hist_lo=1.0, hist_growth=4.0, hist_buckets=2)
        assert r.histogram("h").bounds == (1.0, 4.0)


class TestObsSpec:
    def test_round_trip(self):
        s = spec.ObsSpec(enabled=True, profile=True, hist_lo=0.25,
                         hist_growth=3.0, hist_buckets=12)
        assert spec.ObsSpec.from_dict(s.to_dict()) == s

    def test_profile_requires_enabled(self):
        with pytest.raises(spec.SpecError, match="profile"):
            spec.ObsSpec(profile=True)

    def test_bad_ladder_rejected(self):
        with pytest.raises(spec.SpecError):
            spec.ObsSpec(hist_lo=0.0)
        with pytest.raises(spec.SpecError):
            spec.ObsSpec(hist_growth=1.0)
        with pytest.raises(spec.SpecError):
            spec.ObsSpec(hist_buckets=0)

    def test_runtime_spec_embeds_obs(self):
        s = spec.RuntimeSpec(num_domains=2,
                             obs=spec.ObsSpec(enabled=True))
        assert spec.RuntimeSpec.from_dict(s.to_dict()) == s
        assert s.to_dict()["obs"]["enabled"] is True


class TestSpanAssembly:
    def test_every_observed_task_has_canonical_child_path(self):
        _, t = _recorded()
        forest = obs.assemble_spans(t)
        assert len(forest) > 0
        for span in forest:
            names = [c.name for c in span.children]
            assert names in (["queued", "exec"],
                             ["queued", "steal", "exec"])
            assert span.well_nested()

    def test_forest_partitions_submitted_uids(self):
        _, t = _recorded()
        forest = obs.assemble_spans(t)
        uids = {s.uid for s in t.submissions}
        assert set(forest.spans) | set(forest.missing) == uids
        assert not set(forest.spans) & set(forest.missing)

    def test_assembly_is_deterministic(self):
        _, t = _recorded()
        assert obs.assemble_spans(t) == obs.assemble_spans(t)

    def test_steal_spans_priced_by_embedded_topology(self):
        s = spec.RuntimeSpec(
            num_domains=4,
            topology=spec.TopologySpec(kind="grouped", groups=(2, 2),
                                       near=1.0, far=10.0),
            penalty=spec.PenaltySpec(kind="constant", value=4.0),
            trace=spec.TraceSpec(record=True),
            obs=spec.ObsSpec(enabled=True))
        _, t = _recorded(s)
        forest = obs.assemble_spans(t)
        steal_spans = [c for span in forest for c in span.children
                       if c.name == "steal"]
        assert steal_spans, "hot-skew run should steal"
        for c in steal_spans:
            assert c.attrs["level"] in (1, 2)
            assert c.attrs["distance"] in (1.0, 10.0)
        # cross-group steals must be priced as remote
        assert any(c.attrs["level"] == 2 and c.attrs["distance"] == 10.0
                   for c in steal_spans)

    def test_batch_members_share_grab_metadata(self):
        s = spec.RuntimeSpec(
            num_domains=4, batch=spec.BatchSpec(kind="fixed", size=4),
            penalty=spec.PenaltySpec(kind="constant", value=4.0),
            trace=spec.TraceSpec(record=True),
            obs=spec.ObsSpec(enabled=True))
        _, t = _recorded(s)
        forest = obs.assemble_spans(t)
        sizes = set()
        for span in forest:
            ex = span.children[-1]
            assert 0 <= ex.attrs["batch_index"] < ex.attrs["batch_size"]
            sizes.add(ex.attrs["batch_size"])
        assert max(sizes) > 1, "batch-4 run should have multi-task grabs"


class TestObsPassivity:
    """The load-bearing invariant: observation never perturbs the schedule."""

    def _stats(self, base, obs_spec, tmp_path):
        s = dataclasses.replace(base, obs=obs_spec)
        try:
            built = s.build()
        except spec.SpecError as e:
            if "trace_path" not in str(e):
                raise
            tmp_path.mkdir(parents=True, exist_ok=True)
            built = s.build(trace_path=str(tmp_path))
        trace.drive(built.executor, _workload(num_domains=s.num_domains))
        return built, built.executor.metrics.snapshot()

    @pytest.mark.parametrize("name", spec.policy_names())
    def test_obs_on_off_bit_identical_stats(self, name, tmp_path):
        base = spec.named(name)
        _, off = self._stats(base, spec.ObsSpec(), tmp_path / "off")
        _, on = self._stats(base, spec.ObsSpec(enabled=True),
                            tmp_path / "on")
        _, prof = self._stats(base,
                              spec.ObsSpec(enabled=True, profile=True),
                              tmp_path / "prof")
        assert off == on == prof

    def test_observed_trace_still_replays_exactly(self):
        _, t = _recorded()
        rep = trace.replay(trace.loads_lines(trace.dumps_lines(t)),
                           assert_match=True)
        assert rep.matches_recorded


class TestObserve:
    def test_report_counters_match_trace_stats(self):
        _, t = _recorded()
        rep = obs.observe(t)
        snap = rep.snapshot()
        m = snap["metrics"]
        assert m["tasks_submitted"] == len(t.submissions)
        assert (m["tasks_observed"] + m["tasks_unobserved"]
                == m["tasks_submitted"])
        assert m["events_dropped"] == 0
        # no ring-buffer drop in a run this small: every execution event is
        # retained, so the span-derived steal count equals the stats account
        assert m["steals"] == t.stats["stolen"]
        assert m["remote_steals"] == t.stats["remote_steals"]

    def test_exact_percentiles_are_observed_sojourns(self):
        _, t = _recorded()
        rep = obs.observe(t)
        sojourns = sorted(s.duration for s in rep.spans)
        for key in ("p50", "p95", "p99"):
            assert rep.percentiles["sojourn"][key] in sojourns

    def test_histogram_vs_exact_percentile_bound(self):
        """Bucket-resolution p50 never under-reports the exact p50 by more
        than the clamp allows — it is >= the exact value's bucket lower
        neighbourhood (conservative estimate contract)."""
        _, t = _recorded()
        rep = obs.observe(t)
        h = rep.registry.histogram("sojourn")
        assert h.quantile(50) >= rep.percentiles["sojourn"]["p50"] * 0.5

    def test_observation_report_folds_profile(self):
        built, t = _recorded(spec.RuntimeSpec(
            num_domains=4,
            penalty=spec.PenaltySpec(kind="constant", value=4.0),
            trace=spec.TraceSpec(record=True),
            obs=spec.ObsSpec(enabled=True, profile=True)))
        rep = built.obs.report(t)
        assert rep.profile is not None
        assert set(rep.profile["calls"]) == set(obs.PATHS)
        assert rep.profile["calls"]["steal_scan"] > 0
        assert rep.profile["calls"]["event_append"] > 0
        assert rep.profile["calls"]["submit_route"] > 0
        assert "profile" in rep.snapshot()


class TestProfiler:
    def test_unit_accounting(self):
        p = obs.HotPathProfiler()
        p.add("steal_scan", 100)
        p.add("steal_scan", 50)
        assert p.calls["steal_scan"] == 2
        assert p.ns_per_call()["steal_scan"] == 75.0
        assert p.ns_per_call()["batch_grab"] == 0.0
        assert p.total_ns == 150

    def test_merge(self):
        a, b = obs.HotPathProfiler(), obs.HotPathProfiler()
        a.add("submit_route", 10)
        b.add("submit_route", 30)
        a.merge(b)
        assert a.ns_per_call()["submit_route"] == 20.0

    def test_snapshot_shape(self):
        snap = obs.HotPathProfiler().snapshot()
        assert set(snap) == {"ns", "calls", "ns_per_call"}
        json.dumps(snap)

    def test_unprofiled_executor_pays_no_timer(self):
        built = spec.RuntimeSpec(num_domains=2).build()
        assert built.obs is None
        assert built.executor.profiler is None


class TestChromeExport:
    def _events(self):
        _, t = _recorded()
        return t, obs.chrome_trace_events(t)

    def test_slices_match_executions(self):
        t, evs = self._events()
        exec_events = [e for e in t.events
                       if e.kind in obs.spans.EXEC_KINDS]
        slices = [e for e in evs if e["ph"] == "X"]
        assert len(slices) == len(exec_events)
        for s in slices:
            assert s["dur"] > 0

    def test_steal_flow_arrows_pair_up(self):
        t, evs = self._events()
        starts = [e for e in evs if e["ph"] == "s"]
        ends = [e for e in evs if e["ph"] == "f"]
        stolen = [e for e in t.events if trace.event_stolen(e)]
        assert len(starts) == len(ends) == len(stolen)
        assert {e["id"] for e in starts} == {e["id"] for e in ends}

    def test_metadata_names_every_domain_and_worker(self):
        t, evs = self._events()
        meta = [e for e in evs if e["ph"] == "M"]
        pids = {e["pid"] for e in meta if e["name"] == "process_name"}
        assert pids == set(range(t.meta["num_domains"]))

    def test_export_writes_valid_json(self, tmp_path):
        t, _ = self._events()
        path = tmp_path / "timeline.perfetto-trace"
        obs.export_chrome_trace(t, path)
        doc = json.loads(path.read_text())
        assert doc["traceEvents"]
        assert doc["otherData"]["num_domains"] == t.meta["num_domains"]
        assert doc["otherData"]["governor"] == t.meta.get("governor", "")


class TestSchemaV4:
    def test_observed_header_carries_obs_block(self):
        built, t = _recorded()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        assert head["schema"] == 5
        assert head["obs"] == built.spec.obs.to_dict()
        t2 = trace.loads_lines(lines)
        assert t2.obs_dict == built.spec.obs.to_dict()

    def test_unobserved_header_has_no_obs_block(self):
        s = spec.RuntimeSpec(num_domains=4,
                             trace=spec.TraceSpec(record=True))
        _, t = _recorded(s)
        head = json.loads(trace.dumps_lines(t)[0])
        assert "obs" not in head
        assert t.obs_dict is None

    def test_v3_trace_still_loads_and_replays(self):
        _, t = _recorded()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        head["schema"] = 3
        head.pop("obs")
        head["spec"].pop("obs")              # a v3 writer never knew obs
        t3 = trace.loads_lines([json.dumps(head)] + lines[1:])
        assert t3.obs_dict is None
        assert trace.replay(t3, assert_match=True).matches_recorded

    def test_events_dropped_property(self):
        _, t = _recorded()
        assert t.events_dropped == 0


class TestOverflowAccounting:
    def _overflowed_log(self):
        log = EventLog(maxlen=4)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            for i in range(7):
                log.emit(i, "run", 0, 0, i)
        return log

    def test_one_shot_overflow_warning(self):
        log = EventLog(maxlen=3)
        for i in range(3):
            log.emit(i, "run", 0, 0, i)
        with pytest.warns(RuntimeWarning, match="overflow"):
            log.emit(3, "run", 0, 0, 3)
        with warnings.catch_warnings():
            warnings.simplefilter("error")   # a second warning would raise
            log.emit(4, "run", 0, 0, 4)
        assert log.dropped == 2

    def test_storm_windows_refuse_holed_event_log(self):
        log = self._overflowed_log()
        with pytest.raises(trace.DroppedEventsError, match="ring buffer"):
            trace.windows(log, width=2)
        # explicit materialization is the documented override
        assert trace.windows(list(log), width=2)

    def test_storm_windows_refuse_dropped_trace(self):
        class Holed:
            events_dropped = 3
            events = []
        with pytest.raises(trace.DroppedEventsError):
            trace.windows(Holed(), width=2)

    def test_whole_log_passes_without_drops(self):
        log = EventLog(maxlen=64)
        for i in range(8):
            log.emit(i, "run", 0, 0, i)
        assert trace.windows(log, width=4)

    def test_observe_counts_dropped_events(self):
        s = spec.RuntimeSpec(
            num_domains=4, event_maxlen=16,
            penalty=spec.PenaltySpec(kind="constant", value=4.0),
            trace=spec.TraceSpec(record=True),
            obs=spec.ObsSpec(enabled=True))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            _, t = _recorded(s, steps=24)
        assert t.events_dropped > 0
        rep = obs.observe(t)
        m = rep.registry.snapshot()
        assert m["events_dropped"] == t.events_dropped
        assert m["tasks_unobserved"] > 0
        assert rep.spans.missing
