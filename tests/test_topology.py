"""Hierarchical locality domains: the distance tree, nearest-first
stealing, level-aware control, and the flat-vs-hierarchical replay
conformance matrix."""
import dataclasses
import json

import pytest

from repro import spec, trace
from repro.runtime import AdaptiveSteal, DomainQueues, Executor, Task
from repro.topology import DistanceMatrix, TopologyError, flat, grouped, pods


def _drain(ex):
    ex.run_until_drained()
    return ex.metrics.snapshot()


def _submit_wave(ex, n=120, hot=0, p_hot=0.75, seed=3):
    import numpy as np
    rng = np.random.default_rng(seed)
    for i in range(n):
        d = hot if rng.random() < p_hot else int(rng.integers(ex.num_domains))
        ex.submit(Task(uid=i, home=d, cost=1.0 + (i % 3)), domain=d)


class TestDistanceMatrix:
    def test_flat_builder_single_level(self):
        m = flat(4)
        assert not m.hierarchical and m.num_levels == 1
        assert m.distance(0, 3) == 1.0 and m.distance(2, 2) == 0.0
        assert m.level(0, 3) == 1
        assert m.peers(1, 1) == (0, 2, 3)
        # cyclic order within the level reproduces the flat (d+off)%n scan
        assert m.cyclic_peers(1, 1) == (2, 3, 0)

    def test_grouped_two_levels(self):
        m = grouped([2, 2], near=1.0, far=4.0)
        assert m.hierarchical and m.num_levels == 2
        assert m.distance(0, 1) == 1.0 and m.distance(0, 2) == 4.0
        assert m.level(0, 1) == 1 and m.level(1, 3) == 2
        assert m.peers(0, 1) == (1,) and m.peers(0, 2) == (2, 3)
        assert m.remote_level() == 2

    def test_pods_distance_from_core_topology(self):
        from repro.core.topology import tpu_topology
        m = pods(2, 4)
        assert m.num_domains == 8 and m.num_levels == 2
        want = 1.0 / tpu_topology(2, 256).remote_factor
        assert m.distance(0, 4) == pytest.approx(want)
        assert m.distance(0, 3) == 1.0

    def test_round_trip_and_equality(self):
        m = grouped([3, 2], far=6.0)
        m2 = DistanceMatrix.from_dict(json.loads(json.dumps(m.to_dict())))
        assert m2 == m and hash(m2) == hash(m)
        assert m2.cyclic_peers(4, 2) == m.cyclic_peers(4, 2)

    @pytest.mark.parametrize("bad", [
        [[0.0, 1.0]],                          # not square
        [[0.0, 1.0], [2.0, 0.0]],              # asymmetric
        [[1.0, 1.0], [1.0, 0.0]],              # nonzero diagonal
        [[0.0, 0.0], [0.0, 0.0]],              # zero off-diagonal
        [[0.0, -1.0], [-1.0, 0.0]],            # negative distance
    ])
    def test_invalid_matrices_rejected(self, bad):
        with pytest.raises(TopologyError):
            DistanceMatrix(bad)

    def test_builder_validation(self):
        with pytest.raises(TopologyError):
            grouped([])
        with pytest.raises(TopologyError):
            grouped([2, 0])
        with pytest.raises(TopologyError):
            grouped([2, 2], near=2.0, far=1.0)


class TestTopologySpec:
    def test_round_trip_all_kinds(self):
        for ts in (spec.TopologySpec(kind="flat"),
                   spec.TopologySpec(kind="grouped", groups=(4, 4), far=4.0),
                   spec.TopologySpec(kind="pods", num_pods=2,
                                     domains_per_pod=4)):
            s = spec.RuntimeSpec(num_domains=8, topology=ts)
            assert spec.RuntimeSpec.from_json(s.to_json()) == s

    def test_declared_domains(self):
        assert spec.TopologySpec(kind="flat").declared_domains() is None
        assert spec.TopologySpec(kind="grouped",
                                 groups=(3, 5)).declared_domains() == 8
        assert spec.TopologySpec(kind="pods", num_pods=3,
                                 domains_per_pod=2).declared_domains() == 6

    def test_domain_count_cross_check(self):
        with pytest.raises(spec.SpecError, match="declares 8"):
            spec.RuntimeSpec(num_domains=4, topology=spec.TopologySpec(
                kind="grouped", groups=(4, 4)))

    def test_grouped_needs_groups(self):
        with pytest.raises(spec.SpecError, match="groups"):
            spec.TopologySpec(kind="grouped")
        with pytest.raises(spec.SpecError, match="groups"):
            spec.TopologySpec(kind="flat", groups=(2, 2))

    def test_unknown_field_rejected(self):
        d = spec.TopologySpec(kind="flat").to_dict()
        d["grops"] = [2, 2]
        with pytest.raises(spec.SpecError, match="grops"):
            spec.TopologySpec.from_dict(d)

    def test_build_topology(self):
        m = spec.build_topology(spec.TopologySpec(kind="grouped",
                                                  groups=(4, 4)), 8)
        assert m.hierarchical and m.num_domains == 8
        assert spec.build_topology(None, 4) is None
        with pytest.raises(spec.SpecError, match="declares 8"):
            spec.build_topology(spec.TopologySpec(kind="grouped",
                                                  groups=(4, 4)), 6)


class TestNearestFirstStealing:
    @pytest.mark.parametrize("order", DomainQueues.STEAL_ORDERS)
    def test_flat_topology_is_bit_identical_to_none(self, order):
        """An explicit flat DistanceMatrix must take the literally-original
        steal scan (same RNG draws, same floats) — for every steal order."""
        snaps = []
        for topo in (None, flat(6)):
            ex = Executor(6, steal_order=order, topology=topo, seed=11,
                          steal_penalty=lambda t, w: 4.0)
            _submit_wave(ex, n=150, hot=2)
            snaps.append(_drain(ex))
        assert snaps[0] == snaps[1]

    def test_near_tier_wins_over_cyclic_order(self):
        """Worker in domain 3 of a 4+4 machine, work in 0 (same socket) and
        4 (other socket): the flat cyclic scan picks 4 first, the
        hierarchical scan exhausts the socket first and picks 0."""
        m = grouped([4, 4])
        q_flat = DomainQueues(8)
        q_hier = DomainQueues(8, topology=m)
        for q in (q_flat, q_hier):
            q.enqueue("near", 0)
            q.enqueue("far", 4)
        got_flat = q_flat.dequeue(3)
        got_hier = q_hier.dequeue(3)
        assert got_flat.item == "far" and got_flat.domain == 4
        assert got_hier.item == "near" and got_hier.domain == 0
        assert got_hier.level == 1 and got_hier.distance == 1.0
        nxt = q_hier.dequeue(3)
        assert nxt.item == "far" and nxt.level == 2 and nxt.distance == 4.0

    def test_per_level_min_victim_sequence(self):
        """``None`` in a tier's slot forbids it; a short sequence extends
        with its last entry."""
        m = grouped([2, 2])
        q = DomainQueues(4, topology=m)
        q.enqueue("remote", 2)
        assert q.dequeue(0, min_victim=[1, None]) is None   # remote cut
        got = q.dequeue(0, min_victim=[1, 1])
        assert got.item == "remote" and got.level == 2
        q.enqueue("a", 2)
        q.enqueue("b", 2)
        # short sequence [2] extends: remote tier also needs depth >= 2
        got = q.dequeue(0, min_victim=[2])
        assert got.item == "a"
        assert q.dequeue(0, min_victim=[2]) is None          # depth 1 now

    def test_remote_steal_accounting(self):
        """Executed cross-tier steals are counted and the penalty scales
        with the link distance."""
        ex = Executor(4, worker_domains=[0], topology=grouped([2, 2]),
                      steal_penalty=lambda t, w: 2.0, seed=0)
        ex.submit(Task(uid=0, home=2, cost=1.0), domain=2)
        s = _drain(ex)
        assert s["stolen"] == 1 and s["remote_steals"] == 1
        assert s["steal_penalty"] == 2.0 * grouped([2, 2]).distance(0, 2)

    def test_per_level_theta_learning(self):
        gov = AdaptiveSteal(penalty_hint=4.0, task_cost=1.0)
        w = type("W", (), {"wid": 0})()
        gov.on_execute(w, True, 6.0, 1.0, level=1)
        gov.on_execute(w, True, 24.0, 1.0, level=2)
        est = gov.level_penalty_estimates()
        assert est[1] == 6.0 and est[2] == 24.0
        assert gov.threshold_at(2) > gov.threshold_at(1)
        # unobserved tiers fall back to the global estimate
        assert gov.threshold_at(3) == gov.threshold
        fresh = AdaptiveSteal()
        fresh.seed_level_penalties(est)
        assert fresh.level_penalty_estimates() == est


class TestLevelAwareBreaker:
    def _breaker(self, **kw):
        from repro.control import StormBreaker
        return StormBreaker(width=4, min_executed=4, cooldown=2, **kw)

    def test_remote_storm_trips_remote_state_first(self):
        b = self._breaker()
        b.observe_window(8, 4, 0, remote=4)      # remote-dominated storm
        assert b.remote_tripped and not b.tripped
        assert b.remote_trips == 1 and b.trips == 0

    def test_persistent_storm_escalates_to_full_trip(self):
        b = self._breaker()
        b.observe_window(8, 4, 0, remote=4)
        assert not b.tripped
        b.observe_window(8, 4, 0, remote=4)      # storm while throttling
        assert b.tripped

    def test_local_storm_trips_full_breaker_directly(self):
        b = self._breaker()
        b.observe_window(8, 6, 0, remote=0)
        assert b.tripped and not b.remote_tripped

    def test_remote_trip_blocks_only_deep_levels(self):
        b = self._breaker(mode="block")
        w = type("W", (), {"wid": 0})()
        b.observe_window(8, 4, 0, remote=4)
        assert b.min_victim_depth_at(w, 1) == 1      # near tier untouched
        assert b.min_victim_depth_at(w, 2) is None   # deep links cut
        assert b.min_victim_depth(w) == 1            # flat face unchanged

    def test_state_round_trip(self):
        b = self._breaker()
        b.observe_window(8, 4, 0, remote=4)
        b.observe_window(8, 6, 0, remote=0)
        st = b.breaker_state()
        fresh = self._breaker()
        fresh.seed_state(**st)
        assert fresh.breaker_state() == st
        assert fresh.tripped == b.tripped
        assert fresh.remote_tripped == b.remote_tripped


class TestBreakerAwareRouter:
    def _built(self):
        s = dataclasses.replace(
            spec.named("topology_pods_adaptive"),
            trace=spec.TraceSpec())
        return s.build()

    def test_full_trip_suspends_spilling(self):
        b = self._built()
        ex, router, breaker = b.executor, b.control.router, b.control.breaker
        # pile work straight onto domain 0 (past the router) so a homed
        # task would normally spill
        for i in range(40):
            ex.queues.enqueue(Task(uid=i, home=0, cost=4.0), 0)
        assert router.route(Task(uid=99, home=0, cost=1.0)) != 0
        breaker.seed_state(cooldown_left=2, trips=1)
        assert router.route(Task(uid=100, home=0, cost=1.0)) == 0

    def test_remote_trip_keeps_spills_in_socket(self):
        b = self._built()
        ex, router, breaker = b.executor, b.control.router, b.control.breaker
        # home pod (0-3) loaded directly, other pod (4-7) empty: the best
        # candidate is cross-pod, and worth it (gap >> spill * distance)
        for i in range(600):
            ex.queues.enqueue(Task(uid=i, home=i % 4, cost=8.0), i % 4)
        assert router.route(Task(uid=998, home=0, cost=1.0)) >= 4
        before = router.remote_spills
        breaker.seed_state(remote_cooldown_left=2, remote_trips=1)
        got = router.route(Task(uid=999, home=0, cost=1.0))
        assert got < 4 and router.remote_spills == before


class TestPerDomainBatching:
    def test_size_for_tracks_each_domain(self):
        from repro.control import BatchGovernor
        g = BatchGovernor(target_service=8.0, batch_cap=8, ema=1.0,
                          per_domain=True)
        g.on_batch(1, 8.0, domain=0)     # expensive queue -> thin batches
        g.on_batch(1, 1.0, domain=1)     # cheap queue -> wide batches
        assert g.size_for(0) == 1 and g.size_for(1) == 8
        assert g.size_for(5) == g.size   # unobserved -> global estimate

    def test_state_round_trip(self):
        from repro.control import BatchGovernor
        g = BatchGovernor(per_domain=True)
        g.on_batch(2, 6.0, domain=3)
        fresh = BatchGovernor(per_domain=True)
        fresh.seed_state(service_estimate=g.service_estimate, size=g.size,
                         domain_estimates=g.domain_service_estimates())
        assert fresh.size_for(3) == g.size_for(3)
        assert fresh.domain_service_estimates() == g.domain_service_estimates()


class TestCheckpointCompleteness:
    def test_breaker_and_batch_state_restored_warm(self):
        b = spec.named("topology_pods_adaptive").build()
        _submit_wave(b.executor, n=200, hot=0, p_hot=0.85)
        b.executor.run_until_drained()
        b.control.breaker.seed_state(cooldown_left=2, remote_cooldown_left=1,
                                     trips=3, remote_trips=2)
        ck = spec.checkpoint(b.executor)
        assert ck.governor.breaker.state is not None
        assert ck.batch.state is not None and ck.batch.state.domain_estimates
        ck2 = spec.RuntimeSpec.from_json(ck.to_json())
        assert ck2 == ck
        b2 = ck2.build()
        assert (b2.control.breaker.breaker_state()
                == b.control.breaker.breaker_state())
        assert (b2.control.batcher.domain_service_estimates()
                == b.control.batcher.domain_service_estimates())
        assert (b2.control.batcher.service_estimate
                == b.control.batcher.service_estimate)

    def test_static_system_still_refuses(self):
        b = spec.named("paper_cyclic").build()
        b.executor.submit(Task(uid=0, home=0, cost=1.0), domain=0)
        b.executor.run_until_drained()
        with pytest.raises(spec.SpecError, match="learned"):
            spec.checkpoint(b.executor)


class TestReplayConformanceMatrix:
    @pytest.mark.parametrize("name", sorted(spec.topology_experiments(
        steps=12)))
    def test_header_only_replay_is_exact(self, name):
        """Every flat/hierarchical policy × workload cell must replay
        bit-identically from its recorded header alone (schema v3)."""
        exp = spec.topology_experiments(steps=12)[name]
        run = exp.run().primary
        t = trace.loads_lines(trace.dumps_lines(run.trace))
        rep = trace.replay(t)
        assert rep.matches_recorded, rep.mismatches()
        if exp.policy.topology.kind != "flat":
            assert t.topology_dict is not None
            assert rep.executor.topology.hierarchical

    def test_flat_topology_matches_no_topology_end_to_end(self):
        """The flat cell of the matrix equals the same policy with the
        topology block deleted — today's goldens are reproduced exactly."""
        exp = spec.topology_experiments(steps=12)["topology_flat_hot_skew"]
        bare = dataclasses.replace(exp, policy=dataclasses.replace(
            exp.policy, topology=None))
        s_topo = exp.run().primary.stats
        s_bare = bare.run().primary.stats
        assert s_topo == s_bare


class TestTraceBackCompat:
    def _hier_run(self):
        exp = spec.topology_experiments(steps=12)[
            "topology_two_level_hot_skew"]
        return exp.run().primary.trace

    def test_v2_header_without_topology_still_parses(self):
        """A v2-era trace (schema 2, no topology key) must stay readable
        and replay through the flat machine it recorded."""
        t = self._hier_run()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        assert head["schema"] == 5
        head["schema"] = 2
        head.pop("topology")
        # drop the spec's topology and obs blocks too: a real v2 writer
        # never knew them
        head["spec"].pop("topology")
        head["spec"].pop("obs")
        t2 = trace.loads_lines([json.dumps(head)] + lines[1:])
        assert t2.topology_dict is None
        ex = trace.executor_from_spec(t2)
        assert ex.topology is None

    def test_v1_minimal_header_still_parses(self):
        t = self._hier_run()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        head = {k: head[k] for k in ("record", "kind", "num_domains",
                                     "worker_domains", "steal_order",
                                     "pool_cap", "seed", "governor")}
        head["schema"] = 1
        t1 = trace.loads_lines([json.dumps(head)] + lines[1:])
        assert t1.spec_dict is None and t1.topology_dict is None
        ex = trace.executor_from_meta(t1)
        assert ex.topology is None and ex.num_domains == 8

    def test_unsupported_schema_rejected(self):
        t = self._hier_run()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        head["schema"] = 6
        with pytest.raises(trace.TraceSchemaError, match="schema"):
            trace.loads_lines([json.dumps(head)] + lines[1:])

    def test_hierarchical_replay_from_meta_alone(self):
        """Strip the spec: the schema-v3 topology block in the header is
        enough for ``executor_from_meta`` to rebuild the exact nearest-first
        scan (the recorded constant penalty supplied explicitly)."""
        t = self._hier_run()
        lines = trace.dumps_lines(t)
        head = json.loads(lines[0])
        head.pop("spec")
        head.pop("experiment", None)
        t2 = trace.loads_lines([json.dumps(head)] + lines[1:])
        rep = trace.replay(t2, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=lambda task, w: 6.0))
        assert rep.matches_recorded, rep.mismatches()
        assert rep.executor.topology.hierarchical

    def test_remote_storm_detector_on_recorded_events(self):
        t = self._hier_run()
        m = DistanceMatrix.from_dict(t.topology_dict)
        wins = trace.windows(t.events, width=8, topology=m)
        assert sum(w.remote_steals for w in wins) == t.stats["remote_steals"]
        storms = trace.detect_remote_storms(t.events, m, width=8)
        for w in storms:
            assert w.remote_fraction >= 0.25
