"""Per-arch smoke tests: reduced config, one train step + decode consistency
on CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.models.model import build_model

ARCHS = list_archs()
KEY = jax.random.key(0)


def _extras(cfg, b, dtype=jnp.float32):
    ex = {}
    if cfg.encoder is not None:
        ex["frames"] = jax.random.normal(
            jax.random.key(3), (b, cfg.encoder.num_frames, cfg.encoder.d_model),
            dtype) * 0.1
    if cfg.vision is not None:
        ex["vision"] = jax.random.normal(
            jax.random.key(3), (b, cfg.vision.num_image_tokens, cfg.d_model),
            dtype) * 0.1
    return ex


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_registry(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    assert cfg.vocab_padded() % 128 == 0
    assert len(cfg.layer_kinds()) == cfg.num_layers
    assert cfg.num_params() > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg, max_pos=64)
    params = model.init_params(KEY)
    b, s = 2, 16
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32), **_extras(cfg, b)}
    logits, _, aux = model.forward(params, batch["tokens"], extras=batch)
    assert logits.shape == (b, s, cfg.vocab_padded())
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), arch
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg, max_pos=64)
    params = model.init_params(jax.random.key(1))
    b, t = 2, 24
    tokens = jax.random.randint(jax.random.key(2), (b, t), 0, cfg.vocab_size)
    extras = _extras(cfg, b)

    logits_full, _, _ = model.forward(params, tokens, extras=extras)
    tp = t - 8
    caches = model.init_cache(b, 40)
    lg, caches = model.prefill(params, {"tokens": tokens[:, :tp], **extras},
                               caches)
    np.testing.assert_allclose(lg[:, -1], logits_full[:, tp - 1],
                               atol=2e-4, rtol=1e-3)
    for step in range(tp, t):
        lg, caches = model.decode_step(params, tokens[:, step:step + 1],
                                       step, caches)
        np.testing.assert_allclose(lg[:, 0], logits_full[:, step],
                                   atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-9b"])
def test_ring_buffer_cache_smaller_than_sequence(arch):
    """Local-attention archs keep ring-buffer caches of window size."""
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg, max_pos=64)
    specs = model.cache_specs(
        type("S", (), {"global_batch": 2, "seq_len": 48, "kind": "decode"})())
    leaves = jax.tree.leaves(specs)
    kv_seq_lens = {l.shape[-3] for l in leaves if len(l.shape) >= 4}
    assert cfg.attn_window in kv_seq_lens or \
        {min(cfg.attn_window, 48)} & kv_seq_lens


def test_one_train_step_updates_params():
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import make_train_step
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=64)
    params = model.init_params(KEY)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2)))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    new_params, new_opt, metrics = step(params, opt, batch)
    assert int(new_opt["step"]) == 1
    assert jnp.isfinite(metrics["loss"])
    # something moved
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                     b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params)))
    assert diff > 0


def test_microbatched_step_matches_single_batch_grads():
    """Grad accumulation over k microbatches == one big batch (linearity)."""
    import dataclasses
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import make_train_step
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model1 = build_model(cfg, max_pos=64)
    cfg2 = dataclasses.replace(cfg, microbatches=2)
    model2 = build_model(cfg2, max_pos=64)
    params = model1.init_params(KEY)
    batch = {"tokens": jax.random.randint(jax.random.key(5), (4, 16), 0, 100),
             "labels": jax.random.randint(jax.random.key(6), (4, 16), 0, 100)}
    s1 = jax.jit(make_train_step(model1, AdamWConfig(lr=1e-2)))
    s2 = jax.jit(make_train_step(model2, AdamWConfig(lr=1e-2)))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-4, rtol=2e-2)
