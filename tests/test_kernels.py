"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.jacobi.kernel import jacobi_sweep_pallas
from repro.kernels.jacobi.ref import jacobi_sweep_ref
from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_scan_ref
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref

RNG = np.random.default_rng(0)


class TestJacobi:
    @pytest.mark.parametrize("shape,block", [
        ((20, 20, 60), (10, 10)),
        ((8, 16, 128), (4, 8)),
        ((10, 10, 600), (10, 10)),     # the paper's block geometry
        ((30, 20, 32), (10, 5)),
        ((4, 4, 16), (2, 2)),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_oracle(self, shape, block, dtype):
        f = jnp.asarray(RNG.standard_normal(shape), dtype)
        out = jacobi_sweep_pallas(f, 1 / 6, di=block[0], dj=block[1],
                                  interpret=True)
        ref = jacobi_sweep_ref(f, 1 / 6)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_c_coefficient(self):
        f = jnp.asarray(RNG.standard_normal((8, 8, 16)), jnp.float32)
        out = jacobi_sweep_pallas(f, 0.25, di=4, dj=4)
        ref = jacobi_sweep_ref(f, 0.25)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_rejects_indivisible(self):
        f = jnp.zeros((9, 8, 16), jnp.float32)
        with pytest.raises(ValueError):
            jacobi_sweep_pallas(f, di=4, dj=4)


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,tq,tk,hd,causal,win,bq,bk", [
        (2, 4, 2, 128, 128, 32, True, 0, 64, 64),
        (1, 8, 1, 256, 256, 64, True, 0, 128, 128),     # MQA
        (2, 4, 4, 128, 128, 16, False, 0, 64, 32),      # bidirectional
        (1, 4, 2, 256, 256, 32, True, 96, 64, 64),      # sliding window
        (1, 2, 2, 64, 192, 32, True, 0, 32, 64),        # Tk > Tq (offset)
    ])
    def test_matches_oracle(self, b, hq, hkv, tq, tk, hd, causal, win, bq, bk):
        qo = tk - tq
        q = jnp.asarray(RNG.standard_normal((b, hq, tq, hd)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, hkv, tk, hd)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, hkv, tk, hd)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=win, q_offset=qo,
                              bq=bq, bk=bk, interpret=True)
        ref = mha_ref(q, k, v, causal=causal, window=win, q_offset=qo)
        np.testing.assert_allclose(out, ref, atol=3e-5)

    def test_bf16(self):
        q = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.bfloat16)
        k = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.bfloat16)
        v = jnp.asarray(RNG.standard_normal((1, 2, 128, 32)), jnp.bfloat16)
        out = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
        ref = mha_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=3e-2)


class TestRGLRU:
    @pytest.mark.parametrize("b,t,w,chunk", [
        (2, 128, 64, 32), (1, 256, 128, 128), (3, 64, 32, 64),
    ])
    def test_matches_oracle(self, b, t, w, chunk):
        a = jnp.asarray(RNG.uniform(0.5, 0.999, (b, t, w)), jnp.float32)
        bb = jnp.asarray(RNG.standard_normal((b, t, w)) * 0.1, jnp.float32)
        out = rglru_scan_pallas(a, bb, chunk=chunk, interpret=True)
        ref = rglru_scan_ref(a, bb)
        np.testing.assert_allclose(out, ref, atol=1e-5)


class TestWKV6:
    @pytest.mark.parametrize("b,t,h,hd,chunk", [
        (2, 64, 2, 16, 32), (1, 128, 4, 32, 64), (2, 32, 1, 8, 32),
    ])
    def test_matches_oracle(self, b, t, h, hd, chunk):
        r = jnp.asarray(RNG.standard_normal((b, t, h, hd)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, t, h, hd)) * 0.3, jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, t, h, hd)) * 0.3, jnp.float32)
        w = jnp.asarray(RNG.uniform(0.8, 0.999, (b, t, h, hd)), jnp.float32)
        u = jnp.asarray(RNG.standard_normal((h, hd)) * 0.3, jnp.float32)
        o, sT = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
        oref, sref = wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(o, oref, atol=1e-4)
        np.testing.assert_allclose(sT, sref, atol=1e-4)

    def test_state_continuity_between_chunks(self):
        """Running 2T in one call == two T calls with state carried by hand
        (validates the chunk-boundary handling)."""
        b, t, h, hd = 1, 64, 2, 16
        r = jnp.asarray(RNG.standard_normal((b, 2 * t, h, hd)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, 2 * t, h, hd)) * 0.3, jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, 2 * t, h, hd)) * 0.3, jnp.float32)
        w = jnp.asarray(RNG.uniform(0.8, 0.999, (b, 2 * t, h, hd)), jnp.float32)
        u = jnp.asarray(RNG.standard_normal((h, hd)) * 0.3, jnp.float32)
        o_full, s_full = wkv6_pallas(r, k, v, w, u, chunk=32, interpret=True)
        o1, s1 = wkv6_ref(r[:, :t], k[:, :t], v[:, :t], w[:, :t], u)
        o2, s2 = wkv6_ref(r[:, t:], k[:, t:], v[:, t:], w[:, t:], u, s0=s1)
        np.testing.assert_allclose(o_full[:, :t], o1, atol=1e-4)
        np.testing.assert_allclose(o_full[:, t:], o2, atol=1e-4)
        np.testing.assert_allclose(s_full, s2, atol=1e-4)


class TestJacobiTemporal:
    """Temporal blocking (the paper's §4 outlook): two sweeps per HBM pass."""

    @pytest.mark.parametrize("shape,block", [
        ((20, 20, 32), (5, 5)),
        ((12, 8, 16), (4, 4)),
        ((10, 10, 600), (10, 10)),    # the paper's block geometry
        ((8, 8, 8), (2, 2)),          # minimal halo-legal block
    ])
    def test_two_steps_match_double_sweep(self, shape, block):
        from repro.kernels.jacobi.temporal import jacobi_two_step_pallas
        f = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
        out = jacobi_two_step_pallas(f, 1 / 6, di=block[0], dj=block[1],
                                     interpret=True)
        ref = jacobi_sweep_ref(jacobi_sweep_ref(f, 1 / 6), 1 / 6)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_rejects_shallow_blocks(self):
        from repro.kernels.jacobi.temporal import jacobi_two_step_pallas
        with pytest.raises(ValueError):
            jacobi_two_step_pallas(jnp.zeros((4, 4, 8), jnp.float32),
                                   di=1, dj=1)
