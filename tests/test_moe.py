"""MoE dispatch semantics: conservation, capacity, locality bias."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.models.moe import moe_block, moe_init


def _cfg(**kw):
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    if kw:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **kw))
    return cfg


KEY = jax.random.key(0)


class TestDispatch:
    def test_output_shape_and_finite(self):
        cfg = _cfg()
        p = moe_init(KEY, cfg)
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
        out, aux = moe_block(p, x, cfg)
        assert out.shape == x.shape
        assert jnp.isfinite(out).all() and jnp.isfinite(aux)

    def test_no_drop_equals_dense_expert_mix(self):
        """With capacity for everyone, the MoE output equals the explicit
        per-token top-k expert mixture computed naively."""
        cfg = _cfg(capacity_factor=16.0)
        m = cfg.moe
        p = moe_init(KEY, cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model)) * 0.3
        out, _ = moe_block(p, x, cfg)

        logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
        gates = jax.nn.softmax(logits, -1)
        topv, topi = jax.lax.top_k(gates, m.top_k)
        topv = topv / topv.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        for b in range(x.shape[0]):
            for t in range(x.shape[1]):
                acc = jnp.zeros((cfg.d_model,), x.dtype)
                for j in range(m.top_k):
                    e = int(topi[b, t, j])
                    h = jax.nn.silu(x[b, t] @ p["w_gate"][e]) * (x[b, t] @ p["w_up"][e])
                    acc = acc + topv[b, t, j].astype(x.dtype) * (h @ p["w_down"][e])
                ref = ref.at[b, t].set(acc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-3)

    def test_capacity_drops_tokens(self):
        """Tiny capacity factor ⇒ overflow tokens get zero expert output
        (residual passthrough happens in the caller)."""
        cfg = _cfg(capacity_factor=0.01)
        p = moe_init(KEY, cfg)
        x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model))
        out, _ = moe_block(p, x, cfg)
        norms = jnp.linalg.norm(out[0], axis=-1)
        assert (norms < 1e-6).any(), "expected dropped tokens with cap=1"

    def test_locality_bias_shifts_assignment(self):
        cfg0 = _cfg(locality_bias=0.0)
        cfg1 = _cfg(locality_bias=50.0)   # crank it: all tokens go local
        p = moe_init(KEY, cfg0)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg0.d_model))

        def top1(cfg):
            logits = (x.reshape(4, 16, -1) @ p["router"].astype(x.dtype)
                      ).astype(jnp.float32)
            from repro.models.moe import _local_expert_bias
            if cfg.moe.locality_bias:
                logits = logits + _local_expert_bias(
                    4, cfg.moe.num_experts, cfg.moe.locality_bias)[:, None, :]
            return jnp.argmax(logits, -1)

        a0, a1 = top1(cfg0), top1(cfg1)
        # without a mesh there is one locality group — bias is a no-op
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))

    def test_aux_loss_decreases_with_balance(self):
        """A uniform router gives the minimal aux loss (≈ weight)."""
        cfg = _cfg()
        p = moe_init(KEY, cfg)
        # uniform logits
        p2 = dict(p)
        p2["router"] = jnp.zeros_like(p["router"])
        x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
        _, aux_uniform = moe_block(p2, x, cfg)
        # biased router: all mass on expert 0
        p3 = dict(p)
        p3["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(20.0)
        _, aux_biased = moe_block(p3, x, cfg)
        assert float(aux_biased) > float(aux_uniform)
