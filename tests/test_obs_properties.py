"""Property-based tests (hypothesis) for the span-tree invariants.

``repro.obs.spans`` promises, for any recorded run: every task span is
well-nested, every child path is the canonical ``queued [steal] exec``
sequence, the forest exactly partitions the submitted uids into observed +
missing, and assembly is a pure function of the trace.  This file drives
randomized policies (steal order, batching, topology) over randomized
hot-skew workloads and gates those invariants; it also gates the obs
passivity invariant (obs-on == obs-off stats) pointwise over the same
random policy space.
"""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import obs, spec, trace
from repro.obs.spans import EXEC_KINDS


def _workload(steps, seed, p_hot, num_domains=4):
    return trace.lognormal_costs(
        trace.hot_skew(trace.poisson(rate=num_domains, steps=steps,
                                     num_domains=num_domains, seed=seed),
                       hot_domain=0, p_hot=p_hot, seed=seed),
        median=2.0, sigma=0.75, seed=seed)


def _spec(steal_order, batch, grouped, *, obs_spec):
    topo = (spec.TopologySpec(kind="grouped", groups=(2, 2), near=1.0,
                              far=8.0) if grouped else None)
    return spec.RuntimeSpec(
        num_domains=4, steal_order=steal_order, topology=topo,
        batch=spec.BatchSpec(kind="fixed", size=batch),
        penalty=spec.PenaltySpec(kind="constant", value=4.0),
        trace=spec.TraceSpec(record=True), obs=obs_spec)


POLICY = dict(steal_order=st.sampled_from(["cyclic", "longest"]),
              batch=st.sampled_from([1, 3]),
              grouped=st.booleans())
WORKLOAD = dict(steps=st.integers(4, 24), seed=st.integers(0, 12),
                p_hot=st.floats(0.0, 1.0))


class TestSpanProperties:
    @settings(max_examples=15, deadline=None)
    @given(**POLICY, **WORKLOAD)
    def test_span_tree_invariants(self, steal_order, batch, grouped, steps,
                                  seed, p_hot):
        s = _spec(steal_order, batch, grouped,
                  obs_spec=spec.ObsSpec(enabled=True))
        built = s.build()
        trace.drive(built.executor, _workload(steps, seed, p_hot))
        t = built.recorder.finish()
        forest = obs.assemble_spans(t)

        uids = {sub.uid for sub in t.submissions}
        assert set(forest.spans) | set(forest.missing) == uids
        assert not set(forest.spans) & set(forest.missing)
        submitted = {sub.uid: sub for sub in t.submissions}
        for span in forest:
            assert span.well_nested()
            assert span.duration >= 0
            names = [c.name for c in span.children]
            assert names in (["queued", "exec"],
                             ["queued", "steal", "exec"])
            assert span.start == float(submitted[span.attrs["uid"]].step)
            ex = span.children[-1]
            assert ex.attrs["kind"] in EXEC_KINDS
            assert 0 <= ex.attrs["batch_index"] < ex.attrs["batch_size"]
            assert ex.end == span.end

    @settings(max_examples=10, deadline=None)
    @given(**POLICY, **WORKLOAD)
    def test_assembly_is_pure(self, steal_order, batch, grouped, steps,
                              seed, p_hot):
        s = _spec(steal_order, batch, grouped,
                  obs_spec=spec.ObsSpec(enabled=True))
        built = s.build()
        trace.drive(built.executor, _workload(steps, seed, p_hot))
        t = built.recorder.finish()
        assert obs.assemble_spans(t) == obs.assemble_spans(t)
        a = obs.observe(t).registry.snapshot()
        b = obs.observe(t).registry.snapshot()
        assert a == b


class TestObsPassivityProperties:
    @settings(max_examples=10, deadline=None)
    @given(**POLICY, **WORKLOAD,
           profile=st.booleans())
    def test_obs_never_perturbs_the_schedule(self, steal_order, batch,
                                             grouped, steps, seed, p_hot,
                                             profile):
        outs = []
        for o in (spec.ObsSpec(),
                  spec.ObsSpec(enabled=True, profile=profile)):
            built = _spec(steal_order, batch, grouped, obs_spec=o).build()
            trace.drive(built.executor, _workload(steps, seed, p_hot))
            outs.append(built.executor.metrics.snapshot())
        assert outs[0] == outs[1]


class TestAnalyticsProperties:
    """PR 8's analysis layer under the same randomized policy space: the
    self-diff of any recorded trace is all-zero, and the critical-path
    decomposition is a bit-exact identity on the recorded sojourns."""

    @settings(max_examples=15, deadline=None)
    @given(**POLICY, **WORKLOAD)
    def test_self_diff_is_all_zero(self, steal_order, batch, grouped, steps,
                                   seed, p_hot):
        s = _spec(steal_order, batch, grouped,
                  obs_spec=spec.ObsSpec(enabled=True))
        built = s.build()
        trace.drive(built.executor, _workload(steps, seed, p_hot))
        t = built.recorder.finish()
        d = obs.diff_traces(t, t)
        assert d.is_zero
        assert d.significant_shifts() == {}

    @settings(max_examples=15, deadline=None)
    @given(**POLICY, **WORKLOAD)
    def test_critpath_sums_bit_exactly(self, steal_order, batch, grouped,
                                       steps, seed, p_hot):
        from repro.trace.replay import task_times

        s = _spec(steal_order, batch, grouped,
                  obs_spec=spec.ObsSpec(enabled=True))
        built = s.build()
        trace.drive(built.executor, _workload(steps, seed, p_hot))
        t = built.recorder.finish()
        rep = obs.decompose(t)
        timings = task_times(t.submissions, t.events)
        assert set(rep.tasks) == set(timings)
        for uid, blame in rep.tasks.items():
            assert blame.sojourn == timings[uid].sojourn
