"""Multi-device integration checks (run in a subprocess with 8 host devices
so the main pytest process keeps its single-device view).

Each check prints "OK <name>"; test_distributed.py asserts on the output.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config, reduce_config  # noqa: E402
from repro.distributed.collectives import (compressed_psum,  # noqa: E402
                                           lse_combine)
from repro.distributed.pipeline import pipelined_apply  # noqa: E402
from repro.distributed.sharding import make_rules, use_rules  # noqa: E402
from repro.kernels.jacobi.ref import jacobi_sweep_ref  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402
from repro.models.model import build_model, param_shardings  # noqa: E402
from repro.roofline.hlo_cost import analyze_text  # noqa: E402
from repro.stencil.jacobi import (JacobiGridConfig,  # noqa: E402
                                  make_contiguous_sweep, make_scattered_sweep,
                                  reassemble_scattered, scatter_lattice)
from repro.train.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

assert len(jax.devices()) == 8


def check_stencil_locality():
    """Contiguous (locality) vs scattered block assignment: identical math,
    strictly fewer collective bytes for the locality schedule — the paper's
    claim, measured in compiled HLO."""
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = JacobiGridConfig(ni=80, nj=24, nk=32)
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.standard_normal((cfg.ni, cfg.nj, cfg.nk)), jnp.float32)
    c = jnp.float32(1 / 6)
    ref = jacobi_sweep_ref(f)
    with jax.set_mesh(mesh):
        fs = jax.device_put(f, NamedSharding(mesh, P("data", None, None)))
        contig = jax.jit(make_contiguous_sweep(cfg))
        out = contig(fs, c)
        np.testing.assert_allclose(out, ref, atol=1e-5)
        cost_c = analyze_text(contig.lower(fs, c).compile().as_text())

        bpd = 2
        scat = jax.jit(make_scattered_sweep(cfg, blocks_per_dev=bpd))
        fs2 = jax.device_put(scatter_lattice(f, 8, bpd),
                             NamedSharding(mesh, P("data", None, None)))
        out2 = reassemble_scattered(scat(fs2, c), 8, bpd)
        np.testing.assert_allclose(out2, ref, atol=1e-5)
        cost_s = analyze_text(scat.lower(fs2, c).compile().as_text())

    coll_c = sum(cost_c.coll.values())
    coll_s = sum(cost_s.coll.values())
    assert coll_c < coll_s, (coll_c, coll_s)
    print(f"OK stencil_locality contiguous={coll_c:.0f}B "
          f"scattered={coll_s:.0f}B ratio={coll_s/max(coll_c,1):.1f}x")


def check_sharded_train_matches_single():
    """One train step on a (2,4) mesh == the same step on one device."""
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=64)
    params = model.init_params(jax.random.key(0))
    opt = init_opt_state(params)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 16), 0, 100),
             "labels": jax.random.randint(jax.random.key(2), (8, 16), 0, 100)}
    step = make_train_step(model, AdamWConfig(lr=1e-2))

    # single device
    p1, _, m1 = jax.jit(step)(params, opt, batch)

    # sharded
    mesh = make_debug_mesh(2, 4)
    rules = make_rules(mesh, fsdp=False, shard_heads=False)
    with jax.set_mesh(mesh), use_rules(rules):
        p_sh = param_shardings(cfg, params, rules)
        params_s = jax.device_put(params, p_sh)
        opt_s = init_opt_state(params_s)
        batch_s = jax.device_put(batch, rules.sharding("batch", None))
        p2, _, m2 = jax.jit(step, in_shardings=(p_sh, None, None))(
            params_s, opt_s, batch_s)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)
    print("OK sharded_train_matches_single")


def check_pipeline_parallel():
    """GPipe over a 4-stage axis == sequential layer application."""
    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    n_stage, m, mb, d = 4, 8, 4, 16
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((n_stage, d, d)) * 0.2, jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, mb, d)), jnp.float32)

    def layer_fn(wi, xi):
        return jnp.tanh(xi @ wi[0])

    def run(w, x):
        return jax.shard_map(
            lambda w_, x_: pipelined_apply(layer_fn, w_, x_, axis="pod"),
            mesh=mesh,
            in_specs=(P("pod", None, None), P()),
            out_specs=P(),
            check_vma=False,
        )(w, x)

    with jax.set_mesh(mesh):
        out = jax.jit(run)(w, x)

    ref = x
    for s in range(n_stage):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(out, ref, atol=1e-5)
    print("OK pipeline_parallel")


def check_collectives():
    mesh = jax.make_mesh((8,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

    def f(x):
        def inner(xl):
            r_none, _ = compressed_psum(xl, "d", compression="none")
            r_bf16, _ = compressed_psum(xl, "d", compression="bf16")
            r_int8, _ = compressed_psum(xl, "d", compression="int8")
            return r_none, r_bf16, r_int8
        return jax.shard_map(inner, mesh=mesh, in_specs=P("d", None),
                             out_specs=(P("d", None),) * 3)(x)

    with jax.set_mesh(mesh):
        r_none, r_bf16, r_int8 = jax.jit(f)(x)
    expect = np.tile(np.asarray(x).sum(0), (8, 1))
    np.testing.assert_allclose(r_none, expect, rtol=1e-6)
    np.testing.assert_allclose(r_bf16, expect, rtol=2e-2)
    np.testing.assert_allclose(r_int8, expect, rtol=8e-2, atol=2.0)

    # lse_combine == softmax over the full (sharded) axis
    logits = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16)),
                         jnp.float32)
    v = jnp.asarray(np.random.default_rng(2).standard_normal((8, 16, 4)),
                    jnp.float32)

    def g(logits, v):
        def inner(ll, vv):
            m = ll.max(axis=-1)
            e = jnp.exp(ll - m[..., None])
            part = jnp.einsum("bs,bsd->bd", e, vv)
            return lse_combine(part, m, e.sum(-1), "d")
        return jax.shard_map(inner, mesh=mesh,
                             in_specs=(P(None, "d"), P(None, "d", None)),
                             out_specs=P(None, None))(logits, v)

    with jax.set_mesh(mesh):
        out = jax.jit(g)(logits[None].reshape(1, 8 * 16),
                         v.reshape(1, 8 * 16, 4))
    w = jax.nn.softmax(logits.reshape(1, -1), -1)
    ref = jnp.einsum("bs,bsd->bd", w, v.reshape(1, -1, 4))
    np.testing.assert_allclose(out, ref, atol=1e-5)
    print("OK collectives")


def check_seq_parallel_attention():
    """shard_map context-parallel attention == single-device chunked/banded."""
    import numpy as np
    from repro.models.attention import (banded_attention, chunked_attention,
                                        seq_parallel_attention)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rules = make_rules(mesh, fsdp=False, shard_heads=False)
    rng = np.random.default_rng(0)
    b, t, h, kv, hd = 2, 4096, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, t, h, hd)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32) * 0.3
    with jax.set_mesh(mesh), use_rules(rules):
        for window in (0, 300):
            out = jax.jit(lambda q, k, v, w=window: seq_parallel_attention(
                q, k, v, pos_offset=0, window=w, rules=rules))(q, k, v)
            assert out is not None
            if window:
                ref = banded_attention(q, k, v, 0, window)
            else:
                ref = chunked_attention(q, k, v, 0)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=2e-5)
    print("OK seq_parallel_attention")


def check_dryrun_cell_small_mesh():
    """The dryrun path itself, on the debug mesh (end-to-end integration)."""
    from repro.launch.dryrun import batch_shardings, cell_rules
    from repro.configs import SHAPES
    import dataclasses
    cfg = dataclasses.replace(reduce_config(get_config("qwen2-0.5b")),
                              dtype="bfloat16")
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
    mesh = make_debug_mesh(2, 4)
    rules = cell_rules(cfg, shape, mesh)
    model = build_model(cfg, max_pos=64)
    with jax.set_mesh(mesh), use_rules(rules):
        params_abs = model.abstract_params()
        p_sh = param_shardings(cfg, params_abs, rules)
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        batch_abs = model.input_specs(shape)
        b_sh = batch_shardings(batch_abs, rules)
        step = make_train_step(model)
        compiled = jax.jit(step, in_shardings=(p_sh, None, b_sh),
                           out_shardings=(p_sh, None, None)
                           ).lower(params_abs, opt_abs, batch_abs).compile()
        assert compiled.memory_analysis().temp_size_in_bytes > 0
    print("OK dryrun_cell_small_mesh")


if __name__ == "__main__":
    check_stencil_locality()
    check_sharded_train_matches_single()
    check_pipeline_parallel()
    check_collectives()
    check_seq_parallel_attention()
    check_dryrun_cell_small_mesh()
    print("ALL DISTRIBUTED CHECKS PASSED")
