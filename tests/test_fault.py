"""Fault tolerance: elastic re-mesh planning, schedule rebuild, stragglers."""
import numpy as np
import pytest

from repro.distributed.fault import (DeviceSet, StragglerMonitor,
                                     plan_elastic_mesh, rebuild_schedule)


class TestElasticMesh:
    def test_healthy_fleet_unchanged(self):
        plan = plan_elastic_mesh(DeviceSet(pods=2, data=16, model=16))
        assert plan["mesh_shape"] == (2, 16, 16)
        assert plan["lost_fraction"] == 0.0

    def test_single_chip_failure_drops_its_data_row(self):
        devs = DeviceSet(pods=2, data=16, model=16,
                         failed=frozenset({(0, 3, 7)}))
        plan = plan_elastic_mesh(devs)
        # rectangularity: both pods keep 15 rows
        assert plan["mesh_shape"] == (2, 15, 16)
        assert (0, 3) not in plan["kept_rows"]

    def test_whole_pod_loss(self):
        failed = frozenset((1, d, m) for d in range(16) for m in range(16))
        plan = plan_elastic_mesh(DeviceSet(2, 16, 16, failed=failed))
        assert plan["mesh_shape"] == (1, 16, 16)
        assert plan["lost_fraction"] == pytest.approx(0.5)

    def test_total_loss_raises(self):
        failed = frozenset((p, d, 0) for p in range(2) for d in range(4))
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(DeviceSet(2, 4, 4, failed=failed))


class TestScheduleRebuild:
    def test_rebuild_preserves_surviving_locality(self):
        homes = np.array([0, 0, 1, 1, 2, 2, 3, 3])
        cost = np.ones(8)
        a = rebuild_schedule(homes, cost, old_domains=4, new_domains=2)
        assert sorted(t for lst in a.lists for t in lst) == list(range(8))
        # tasks homed in surviving domains 0/1 stay local
        for d in (0, 1):
            for t in np.flatnonzero(homes == d):
                if t in a.lists[d]:
                    continue
            # balance may move some, but locality_fraction counts them
        assert a.locality_fraction >= 0.5

    def test_orphaned_tasks_rebalanced(self):
        homes = np.full(12, 3)          # everything on a dead domain
        a = rebuild_schedule(homes, np.ones(12), 4, 2)
        sizes = [len(l) for l in a.lists]
        assert sum(sizes) == 12
        assert max(sizes) - min(sizes) <= 1


class TestStragglerMonitor:
    def test_flags_slow_domain(self):
        mon = StragglerMonitor(num_domains=4, threshold=1.3)
        for _ in range(10):
            out = mon.update([1.0, 1.0, 1.0, 2.0])
        assert out["stragglers"] == [3]
        assert 0.0 < out["shed_fraction"][3] <= 0.5

    def test_no_false_positives_on_uniform(self):
        mon = StragglerMonitor(num_domains=4)
        for _ in range(5):
            out = mon.update([1.0, 1.01, 0.99, 1.0])
        assert out["stragglers"] == []
