"""Paper-claim validation: the discrete-event simulator must reproduce the
qualitative results of Fig. 3 / Fig. 4 and Table 1 on all three test-bed
systems."""
import numpy as np
import pytest

from repro.core import (ISTANBUL, NEHALEM_EP, NEHALEM_EX, SMALL_GRID, TESTBED,
                        OpenMPLocalityQueues, OpenMPTasking, StaticWorksharing,
                        TBBLocalityQueues, TBBParallelFor, place, run_samples,
                        simulate, stream_sanity, summarize, tbb_first_touch)


def _ws(topo, placement, seed=0):
    homes = place(placement, SMALL_GRID, topo)
    return simulate(SMALL_GRID, topo, StaticWorksharing(), homes, seed=seed)


@pytest.mark.parametrize("topo", [ISTANBUL, NEHALEM_EP, NEHALEM_EX],
                         ids=lambda t: t.name)
class TestReferenceLines:
    """The three horizontal lines of Fig. 3 (per system)."""

    def test_ordering_serial_rr_firsttouch(self, topo):
        serial = _ws(topo, "serial").mlups
        rr = _ws(topo, "round_robin").mlups
        ft = _ws(topo, "static").mlups
        assert serial < rr < ft, (serial, rr, ft)

    def test_first_touch_matches_stream(self, topo):
        """Optimal placement comes close to the STREAM envelope (§1.4)."""
        ft = _ws(topo, "static")
        from repro.core import block_bytes, bytes_per_site
        stream_mlups = topo.full_bw * 1e9 / bytes_per_site(topo.nt_stores) / 1e6
        assert ft.mlups > 0.9 * stream_mlups
        assert ft.local_fraction == 1.0

    def test_serial_is_single_domain_bound(self, topo):
        serial = _ws(topo, "serial")
        from repro.core import bytes_per_site
        one_ld_mlups = topo.local_bw * 1e9 / bytes_per_site(topo.nt_stores) / 1e6
        assert serial.mlups <= 1.02 * one_ld_mlups


@pytest.mark.parametrize("topo", [NEHALEM_EP, ISTANBUL], ids=lambda t: t.name)
class TestOpenMPTasking:
    """Fig. 3 columns 1–2: plain tasking vs locality queues."""

    def test_plain_tasking_never_beats_round_robin(self, topo):
        """Paper §2.1: 'this code is never faster than standard worksharing
        with round-robin placement'."""
        rr = _ws(topo, "round_robin").mlups
        for init in ("static", "static1"):
            for order in ("ijk", "kji"):
                homes = place(init, SMALL_GRID, topo)
                r = simulate(SMALL_GRID, topo,
                             OpenMPTasking(submit_order=order), homes, seed=1)
                assert r.mlups <= 1.08 * rr, (init, order, r.mlups, rr)

    def test_static_ijk_especially_unfortunate(self, topo):
        """Paper §2.1: static init + ijk submit order is the worst combo."""
        results = {}
        for init in ("static", "static1"):
            for order in ("ijk", "kji"):
                homes = place(init, SMALL_GRID, topo)
                r = simulate(SMALL_GRID, topo,
                             OpenMPTasking(submit_order=order), homes, seed=1)
                results[(init, order)] = r.mlups
        assert results[("static", "ijk")] == min(results.values())

    def test_locality_queues_recover_static_performance(self, topo):
        """Paper §2.2: with kji order or static,1 init, locality queues come
        within 10% of static first-touch worksharing."""
        ft = _ws(topo, "static").mlups
        for init, order in [("static", "kji"), ("static1", "ijk"),
                            ("static1", "kji")]:
            homes = place(init, SMALL_GRID, topo)
            r = simulate(SMALL_GRID, topo,
                         OpenMPLocalityQueues(submit_order=order), homes, seed=1)
            assert r.mlups > 0.9 * ft, (init, order, r.mlups, ft)
            assert r.local_fraction > 0.95

    def test_locality_queues_static_ijk_still_poor(self, topo):
        """Paper §2.2: static+ijk starves all but one queue (the 256-task cap
        keeps the submission window inside a single domain)."""
        ft = _ws(topo, "static").mlups
        homes = place("static", SMALL_GRID, topo)
        r = simulate(SMALL_GRID, topo, OpenMPLocalityQueues(submit_order="ijk"),
                     homes, seed=1)
        assert r.mlups < 0.75 * ft
        assert r.steal_fraction > 0.1


class TestTBB:
    """Fig. 3 columns 3–4."""

    def test_affinity_partitioner_restores_locality(self):
        topo = ISTANBUL
        rng = np.random.default_rng(7)
        homes, threads = tbb_first_touch(SMALL_GRID, topo, rng)
        aff = simulate(SMALL_GRID, topo,
                       TBBParallelFor(affinity=True, replay=threads),
                       homes, seed=7)
        noaff = simulate(SMALL_GRID, topo, TBBParallelFor(affinity=False),
                         homes, seed=7)
        ft = _ws(topo, "static").mlups
        assert aff.mlups > 0.95 * ft
        assert noaff.mlups < 0.85 * aff.mlups

    def test_tbb_locality_queues_marginal_over_affinity(self):
        """Paper §3.2: TBB+LQ does not outperform the affinity partitioner."""
        topo = ISTANBUL
        rng = np.random.default_rng(7)
        homes, threads = tbb_first_touch(SMALL_GRID, topo, rng)
        aff = simulate(SMALL_GRID, topo,
                       TBBParallelFor(affinity=True, replay=threads),
                       homes, seed=7)
        lq = simulate(SMALL_GRID, topo, TBBLocalityQueues(), homes, seed=7)
        assert abs(lq.mlups - aff.mlups) / aff.mlups < 0.1

    def test_unpinned_affinity_degrades(self):
        topo = NEHALEM_EP
        rng = np.random.default_rng(3)
        homes, threads = tbb_first_touch(SMALL_GRID, topo, rng)
        pinned = simulate(SMALL_GRID, topo,
                          TBBParallelFor(affinity=True, replay=threads),
                          homes, seed=3, pinned=True)
        unpinned = simulate(SMALL_GRID, topo,
                            TBBParallelFor(affinity=True, replay=threads),
                            homes, seed=3, pinned=False)
        assert unpinned.local_fraction < pinned.local_fraction


class TestVariabilityFig4:
    def test_variability_is_small(self):
        """Fig. 4: run-to-run quantile spread is a few percent."""
        topo = NEHALEM_EP
        homes = place("static1", SMALL_GRID, topo)
        res = run_samples(SMALL_GRID, topo,
                          lambda: OpenMPLocalityQueues(submit_order="kji"),
                          homes, n_samples=9)
        s = summarize(res)
        spread = (s["q75"] - s["q25"]) / s["median_mlups"]
        assert spread < 0.08


class TestStreamTable1:
    @pytest.mark.parametrize("name", list(TESTBED))
    def test_model_matches_table1(self, name):
        topo = TESTBED[name]
        s = stream_sanity(topo)
        # full-machine local bandwidth ≈ Table 1 full-system STREAM (±10%)
        table1_full = {"istanbul": 38.6, "nehalem_ep": 36.6, "nehalem_ex": 33.4}
        assert abs(s["full_local_bw"] - table1_full[name]) / table1_full[name] < 0.1
        # serial placement saturates exactly one socket
        assert abs(s["serial_ld0_bw"] - topo.local_bw) / topo.local_bw < 0.05
        assert s["serial_ld0_bw"] < s["interleaved_bw"] < s["full_local_bw"]
