"""Trace analytics: critical-path blame exactness, A/B diff invariants,
report rendering determinism, and the sentinel's tolerance policy.

The two load-bearing invariants (ISSUE 8 acceptance criteria):

  * ``obs.decompose`` phase splits sum *bit-exactly* to the recorded
    sojourn (``trace.replay.TaskTiming``) for every registry policy ×
    standard workload cell;
  * ``obs.diff_traces(t, t)`` is all-zero for every registry policy.

Plus the sentinel unit contract: deterministic metrics fail on any drift,
wall metrics gate loosely lower-is-better, a deleted metric fails, a new
metric passes — so ``make sentinel`` fails on an injected regression and
nothing else.
"""
import os

import pytest

from benchmarks import sentinel
from repro import obs, spec, trace
from repro.trace.replay import task_times

SPECS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "specs")
MATRIX_WORKLOADS = ("poisson", "bursty", "diurnal", "hot_skew")


def _recorded(policy: str, workload: str, steps: int = 16):
    """One recorded trace of a registry policy driving a standard
    workload (through the JSONL wire format, like a real analysis)."""
    s = spec.named(policy)
    wl = spec.standard_workloads(num_domains=s.num_domains, steps=steps,
                                 seed=9)[workload].build()
    built = s.build()
    rec = built.recorder
    if rec is None:
        rec = trace.TraceRecorder()
        rec.attach(built.executor)
    trace.drive(built.executor, wl)
    return trace.loads_lines(trace.dumps_lines(rec.finish()))


class TestCritpathExactness:
    """decompose() is an *identity* on the recorded sojourn, not a model:
    per task, queue_wait + (exec + steal_transfer) reproduces
    ``TaskTiming.sojourn`` bit-for-bit, on every policy × workload cell."""

    @pytest.mark.parametrize("workload", MATRIX_WORKLOADS)
    @pytest.mark.parametrize("policy", spec.policy_names())
    def test_phases_sum_bit_exactly_to_sojourn(self, policy, workload):
        t = _recorded(policy, workload)
        rep = obs.decompose(t)
        timings = task_times(t.submissions, t.events)
        assert set(rep.tasks) == set(timings), (policy, workload)
        for uid, blame in rep.tasks.items():
            tt = timings[uid]
            assert blame.sojourn == tt.sojourn, (policy, workload, uid)
            assert blame.queue_wait == tt.wait
            assert blame.exec + blame.steal_transfer == tt.service

    def test_observed_plus_missing_partitions_submissions(self):
        t = _recorded("paper_cyclic", "hot_skew")
        rep = obs.decompose(t)
        uids = {s.uid for s in t.submissions}
        assert set(rep.tasks) | set(rep.missing) == uids
        assert not set(rep.tasks) & set(rep.missing)

    def test_blame_tables_reconcile_to_totals(self):
        t = _recorded("topology_two_level", "hot_skew")
        rep = obs.decompose(t)
        for table in (rep.by_domain, rep.by_level):
            total = sum(r["total"] for r in table.values())
            assert total == pytest.approx(rep.total_sojourn, rel=1e-12)
            assert sum(r["tasks"] for r in table.values()) == len(rep.tasks)
        # every phase column reconciles too
        for phase in obs.PHASES:
            assert sum(r[phase] for r in rep.by_level.values()) \
                == pytest.approx(rep.totals[phase], rel=1e-12)

    def test_levels_priced_by_header_topology(self):
        t = _recorded("topology_two_level", "hot_skew")
        rep = obs.decompose(t)
        assert t.topology_dict is not None
        # the hot-skew run on the two-socket machine crosses sockets
        assert any(lv >= 2 for lv in rep.by_level), rep.by_level.keys()
        for blame in rep.tasks.values():
            if blame.level == 0:
                assert blame.steal_transfer == 0.0

    def test_flat_trace_prices_every_steal_level_1(self):
        t = _recorded("paper_cyclic", "hot_skew")
        assert t.topology_dict is None
        rep = obs.decompose(t)
        assert set(rep.by_level) <= {0, 1}

    def test_dominant_and_top_are_deterministic(self):
        t = _recorded("controlled_replay", "bursty")
        a, b = obs.decompose(t), obs.decompose(t)
        assert [x.uid for x in a.top(5)] == [x.uid for x in b.top(5)]
        assert a.dominant_contributors() == b.dominant_contributors()
        assert a.snapshot() == b.snapshot()


class TestDiffTraces:
    @pytest.mark.parametrize("policy", spec.policy_names())
    def test_self_diff_is_all_zero(self, policy):
        t = _recorded(policy, "hot_skew", steps=12)
        d = obs.diff_traces(t, t)
        assert d.is_zero, policy
        assert d.significant_shifts() == {}
        assert d.snapshot()["is_zero"] is True

    def test_different_policies_produce_nonzero_diff(self):
        a = _recorded("paper_cyclic", "hot_skew")
        b = _recorded("controlled_replay", "hot_skew")
        assert not obs.diff_traces(a, b).is_zero

    def test_min_effect_threshold_gates_significance(self):
        # below both thresholds: not significant
        from repro.obs.diff import _shift
        assert not _shift(100.0, 100.4, 0.5, 0.02).significant
        assert not _shift(100.0, 101.9, 0.5, 0.02).significant  # < 2% rel
        # clears max(abs, rel)
        assert _shift(100.0, 102.1, 0.5, 0.02).significant
        assert _shift(0.0, 0.5, 0.5, 0.02).significant
        assert not _shift(0.0, 0.4, 0.5, 0.02).significant

    def test_steal_matrix_priced_per_side(self):
        flat = _recorded("paper_cyclic", "hot_skew")
        topo = _recorded("topology_two_level", "hot_skew")
        d = obs.diff_traces(flat, topo)
        # flat side contributes only level 1; topo side reaches level 2
        assert any(lv >= 2 and s.b > 0 for lv, s in d.steal_levels.items())
        assert all(s.a == 0 for lv, s in d.steal_levels.items() if lv >= 2)

    def test_histogram_deltas_share_fixed_buckets(self):
        a = _recorded("paper_cyclic", "poisson")
        b = _recorded("controlled_replay", "poisson")
        d = obs.diff_traces(a, b)
        for h in d.phases.values():
            assert h.count_a == d.tasks.a and h.count_b == d.tasks.b
            # conservation: net bucket movement equals the count delta
            assert sum(r[3] for r in h.buckets) == h.count_b - h.count_a


class TestReports:
    def test_render_blame_is_deterministic_markdown(self):
        t = _recorded("topology_pods_adaptive", "bursty")
        one = obs.render_blame(obs.decompose(t))
        two = obs.render_blame(obs.decompose(t))
        assert one == two
        assert one.startswith("## Critical-path blame")
        for section in ("### By domain", "### By topology level",
                        "### Dominant contributors"):
            assert section in one

    def test_render_diff_flags_identity(self):
        t = _recorded("paper_cyclic", "poisson", steps=8)
        text = obs.render_diff(obs.diff_traces(t, t), "x", "y")
        assert "**Identical**" in text
        a = _recorded("paper_cyclic", "hot_skew")
        b = _recorded("controlled_replay", "hot_skew")
        assert "**Identical**" not in obs.render_diff(obs.diff_traces(a, b))

    def test_markdown_table_shape(self):
        text = obs.markdown_table(["a", "b"], [[1, 2.5], ["x", "y"]])
        lines = text.splitlines()
        assert lines[0] == "| a | b |"
        assert lines[1].count("---") == 2
        assert lines[2] == "| 1 | 2.5 |"


class TestSentinel:
    """The tolerance policy, pure-unit: no benchmark re-runs."""

    def test_flatten_skips_bools_and_experiment_blocks(self):
        flat = sentinel.flatten({"a": 1, "ok": True, "experiment": {"n": 9},
                                 "nest": {"b": 2.5}, "row": [3, {"c": 4}]})
        assert flat == {"a": 1.0, "nest.b": 2.5, "row[0]": 3.0,
                        "row[1].c": 4.0}

    def test_exact_metric_fails_on_any_drift(self):
        base = {"results": {"x": {"makespan": 27}}}
        ok = sentinel.compare(base, {"results": {"x": {"makespan": 27}}},
                              "control")
        assert all(f.status == "ok" for f in ok)
        bad = sentinel.compare(base, {"results": {"x": {"makespan": 28}}},
                               "control")
        assert [f.status for f in bad] == ["regression"]

    def test_injected_regression_fails_and_improvement_passes(self):
        base = {"rows": {"1000x4": {"ns_per_decision": {"steal_scan": 100.0}}}}
        worse = {"rows": {"1000x4":
                          {"ns_per_decision": {"steal_scan": 100.0 * 3.5}}}}
        better = {"rows": {"1000x4":
                           {"ns_per_decision": {"steal_scan": 50.0}}}}
        within = {"rows": {"1000x4":
                           {"ns_per_decision": {"steal_scan": 200.0}}}}
        assert [f.status for f in sentinel.compare(base, worse, "overhead")] \
            == ["regression"]
        assert [f.status for f in sentinel.compare(base, better, "overhead")] \
            == ["improvement"]
        assert [f.status for f in sentinel.compare(base, within, "overhead")] \
            == ["ok"]

    def test_wall_readouts_are_informational(self):
        base = {"results": [{"wall_off_s": 0.1, "tasks_per_s": 1e5,
                             "overhead_frac": -0.01, "repeats_used": 5}]}
        fresh = {"results": [{"wall_off_s": 9.9, "tasks_per_s": 1.0,
                              "overhead_frac": 0.04, "repeats_used": 40}]}
        findings = sentinel.compare(base, fresh, "overhead")
        assert findings and all(f.status == "info" for f in findings)

    def test_missing_metric_fails_new_metric_passes(self):
        base, fresh = {"a": 1}, {"a": 1, "b": 2}
        statuses = {f.metric: f.status
                    for f in sentinel.compare(base, fresh, "control")}
        assert statuses == {"a": "ok", "b": "new"}
        statuses = {f.metric: f.status
                    for f in sentinel.compare(fresh, base, "control")}
        assert statuses["b"] == "missing"
        assert any(f.failed for f in sentinel.compare(fresh, base, "control"))

    def test_overhead_rows_intersect_on_configuration(self):
        base = {"bench": "x", "results": [
            {"n_tasks": 1000, "num_domains": 4, "v": 1},
            {"n_tasks": 100000, "num_domains": 16, "v": 2}]}
        fresh = {"bench": "x", "results": [
            {"n_tasks": 1000, "num_domains": 4, "v": 3}]}
        nb, nf = sentinel._intersect_overhead(base, fresh)
        assert list(nb["rows"]) == list(nf["rows"]) == ["1000x4"]

    def test_report_verdict_and_exit_semantics(self):
        ok = {"control": [sentinel.Finding("control", "m", 1.0, 1.0,
                                           "equal", "ok")]}
        bad = {"control": [sentinel.Finding("control", "m", 1.0, 2.0,
                                            "equal", "regression")]}
        assert "**PASS**" in sentinel.render_report(ok, {})
        text = sentinel.render_report(bad, {"topology": "no baseline"})
        assert "**FAIL**" in text and "Non-ok findings" in text
        assert "skipped `topology`" in text

    def test_trajectory_appends(self, tmp_path):
        path = str(tmp_path / "traj.json")
        findings = {"control": [sentinel.Finding("control", "m", 1.0, 1.0,
                                                 "equal", "ok")]}
        first = sentinel.append_trajectory(findings, path=path)
        assert first["ok"] is True
        bad = {"control": [sentinel.Finding("control", "m", 1.0, 2.0,
                                            "equal", "regression")]}
        second = sentinel.append_trajectory(bad, path=path)
        assert second["ok"] is False
        import json
        hist = json.load(open(path, encoding="utf-8"))
        assert [e["ok"] for e in hist["entries"]] == [True, False]
