"""Property-based tests (hypothesis) for the scheduling invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ISTANBUL, NEHALEM_EP, BlockGrid, LocalityQueues,
                        OpenMPLocalityQueues, OpenMPTasking,
                        StaticWorksharing, build_assignment, maxmin_rates,
                        place, round_robin_assignment, simulate)

TOPOS = [ISTANBUL, NEHALEM_EP]


@st.composite
def small_grids(draw):
    bi = draw(st.integers(2, 6))
    bj = draw(st.integers(2, 6))
    di = draw(st.sampled_from([2, 5]))
    dj = draw(st.sampled_from([2, 5]))
    return BlockGrid(ni=bi * di, nj=bj * dj, nk=16, di=di, dj=dj, dk=16)


class TestSimulatorConservation:
    @settings(max_examples=12, deadline=None)
    @given(grid=small_grids(),
           policy_kind=st.sampled_from(["static_ws", "omp_task", "omp_lq"]),
           placement=st.sampled_from(["serial", "static", "static1",
                                      "round_robin"]),
           order=st.sampled_from(["ijk", "kji"]),
           seed=st.integers(0, 5))
    def test_every_block_executed_exactly_once(self, grid, policy_kind,
                                               placement, order, seed):
        topo = NEHALEM_EP
        homes = place(placement, grid, topo, order="ijk")
        executed = []

        if policy_kind == "static_ws":
            pol = StaticWorksharing()
        elif policy_kind == "omp_task":
            pol = OpenMPTasking(submit_order=order, pool_cap=16)
        else:
            pol = OpenMPLocalityQueues(submit_order=order, pool_cap=16)

        orig_pop = pol.pop

        def spy_pop(thread):
            got = orig_pop(thread)
            if got is not None:
                executed.append(got.block)
            return got

        pol.pop = spy_pop
        r = simulate(grid, topo, pol, homes, seed=seed)
        assert sorted(executed) == list(range(grid.num_blocks))
        assert r.makespan_s > 0

    @settings(max_examples=10, deadline=None)
    @given(grid=small_grids(), seed=st.integers(0, 3))
    def test_locality_queue_steals_only_when_local_empty(self, grid, seed):
        topo = ISTANBUL
        q = LocalityQueues(topo.num_domains)
        rng = np.random.default_rng(seed)
        homes = rng.integers(0, topo.num_domains, grid.num_blocks)
        for blk in range(grid.num_blocks):
            q.enqueue(blk, int(homes[blk]))
        for ld in range(topo.num_domains):
            local_size = q.queue_sizes()[ld]      # live, pre-dequeue
            got = q.dequeue(ld)
            assert got is not None
            blk, stolen = got
            if local_size > 0:
                assert not stolen and homes[blk] == ld
            else:
                assert stolen and homes[blk] != ld


class TestAssignmentBuilder:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(4, 200), d=st.integers(2, 16),
           seed=st.integers(0, 10),
           imb=st.floats(0.01, 0.3))
    def test_partition_and_balance(self, n, d, seed, imb):
        rng = np.random.default_rng(seed)
        homes = rng.integers(-1, d, size=n)
        cost = rng.uniform(0.5, 2.0, size=n)
        a = build_assignment(homes, cost, d, max_imbalance=imb)
        # every task exactly once
        all_tasks = sorted(t for lst in a.lists for t in lst)
        assert all_tasks == list(range(n))
        # loads consistent
        for dd in range(d):
            assert abs(a.loads[dd] - sum(cost[t] * 1.0 for t in a.lists[dd])) \
                < 1e-6 + 0.3 * a.loads[dd]  # remote_penalty may inflate loads
        assert 0.0 <= a.locality_fraction <= 1.0

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(8, 100), d=st.integers(2, 8), seed=st.integers(0, 5))
    def test_locality_beats_round_robin(self, n, d, seed):
        rng = np.random.default_rng(seed)
        homes = rng.integers(0, d, size=n)
        cost = np.ones(n)
        a = build_assignment(homes, cost, d)
        rr = round_robin_assignment(n, cost, d)
        assert a.locality_fraction >= rr.locality_fraction

    def test_stealing_bounds_imbalance(self):
        # pathological: everything homed in domain 0
        n, d = 64, 4
        homes = np.zeros(n, dtype=np.int64)
        cost = np.ones(n)
        a = build_assignment(homes, cost, d, max_imbalance=0.1)
        assert a.imbalance <= 0.15
        assert a.moved > 0
        assert a.locality_fraction < 1.0   # balance was bought with locality


class TestCostModel:
    @settings(max_examples=20, deadline=None)
    @given(f=st.integers(1, 30), seed=st.integers(0, 20))
    def test_rates_respect_capacities(self, f, seed):
        topo = ISTANBUL
        rng = np.random.default_rng(seed)
        home = rng.integers(-1, topo.num_domains, size=f)
        exec_ld = rng.integers(0, topo.num_domains, size=f)
        rates = maxmin_rates(home, exec_ld, topo)
        assert (rates > 0).all()
        # per-flow cap
        assert (rates <= topo.core_bw + 1e-9).all()
        # per-bus capacity
        for l in range(topo.num_domains):
            w = np.where(home == l, 1.0, 0.0) + np.where(home == -1,
                                                         1.0 / topo.num_domains, 0.0)
            assert float(w @ rates) <= topo.local_bw * (1 + 1e-9)
