"""End-to-end behaviour tests for the paper's system.

The headline claims, executed:
  1. the locality-queue layer recovers static-first-touch throughput under
     dynamic scheduling (simulator, all three ccNUMA test beds);
  2. the same scheduler drives a real distributed JAX app end to end
     (training runs, learns, checkpoints, resumes — see test_checkpoint /
     test_distributed for the sharded halves);
  3. serving with locality queues preserves outputs while improving cache
     locality (test_serving).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduce_config
from repro.core import (TESTBED, SMALL_GRID, OpenMPLocalityQueues,
                        StaticWorksharing, place, simulate)
from repro.data.pipeline import make_batch_iterator
from repro.models.model import build_model
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def test_headline_claim_all_testbeds():
    """Locality queues within 10% of optimal static placement — the paper's
    central result — on Istanbul, Nehalem EP and Nehalem EX."""
    for topo in TESTBED.values():
        homes = place("static1", SMALL_GRID, topo)
        ft = simulate(SMALL_GRID, topo, StaticWorksharing(),
                      place("static", SMALL_GRID, topo)).mlups
        lq = simulate(SMALL_GRID, topo, OpenMPLocalityQueues("kji"),
                      homes, seed=0).mlups
        assert lq > 0.9 * ft, (topo.name, lq, ft)


def test_training_learns_synthetic_structure():
    """A reduced model on the synthetic corpus must beat the unigram floor
    quickly — the bigram structure is learnable."""
    cfg = reduce_config(get_config("qwen2-0.5b"))
    model = build_model(cfg, max_pos=64)
    data = make_batch_iterator(cfg.vocab_size, 32, 8, seed=0)
    trainer = Trainer(model, data,
                      LoopConfig(total_steps=25, checkpoint_every=1000,
                                 log_every=1000),
                      AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=25),
                      log_fn=lambda s: None)
    out = trainer.run()
    first, last = out["losses"][0], np.mean(out["losses"][-5:])
    assert last < first - 0.5, (first, last)


def test_all_archs_registered():
    archs = list_archs()
    assert len(archs) == 10
    for required in ("qwen2-0.5b", "qwen2-1.5b", "minicpm3-4b", "gemma3-1b",
                     "qwen3-moe-30b-a3b", "phi3.5-moe-42b-a6.6b",
                     "recurrentgemma-9b", "whisper-base",
                     "llama-3.2-vision-90b", "rwkv6-3b"):
        assert required in archs


def test_dryrun_results_present_and_clean():
    """The committed dry-run table must cover all 40 single-pod cells with
    no errors (deliverable e)."""
    import json
    from pathlib import Path
    p = Path(__file__).parent.parent / "experiments" / "dryrun.json"
    if not p.exists():
        pytest.skip("dryrun.json not generated yet")
    d = json.loads(p.read_text())
    single = {k: v for k, v in d.items() if k.endswith("|single")}
    assert len(single) == 40
    assert all(v["status"] in ("ok", "skipped") for v in single.values()), \
        {k: v.get("error") for k, v in single.items() if v["status"] == "error"}
    n_ok = sum(1 for v in single.values() if v["status"] == "ok")
    assert n_ok == 33   # 7 documented long_500k skips
