"""repro.control: batch grabs, storm breaker, cost router, controlled replay."""
import numpy as np
import pytest

from repro import trace
from repro.control import (BatchGovernor, ControlLoop, CostRouter,
                           StormBreaker)
from repro.runtime import (AdaptiveSteal, DomainQueues, Executor, GreedySteal,
                           Task, Worker)


def _penalty(task, worker) -> float:
    return 4.0 * task.cost


def _skewed_workload(steps=32, seed=0, num_domains=4):
    return trace.lognormal_costs(
        trace.hot_skew(trace.poisson(rate=num_domains, steps=steps,
                                     num_domains=num_domains, seed=seed),
                       hot_domain=0, p_hot=0.8, seed=seed),
        median=2.0, sigma=0.75, seed=seed)


class TestBatchGrabs:
    def test_batch_preserves_per_task_results(self):
        def run(batch):
            ex = Executor(2, batch=batch,
                          handler=lambda t, w: (t.payload, t.uid))
            for i in range(24):
                ex.submit(ex.make_task(payload=i * 10, home=i % 2))
            out = ex.run_until_drained()
            return out, ex.stats, ex.step_count

        out1, s1, steps1 = run(1)
        out4, s4, steps4 = run(4)
        assert sorted(out1) == sorted(out4) == [(i * 10, i) for i in range(24)]
        assert s1.executed == s4.executed == 24
        assert steps4 < steps1              # batching amortizes rounds

    def test_batch_drains_only_source_queue(self):
        # domain 1's worker steals a batch: every task in the grab must come
        # from the victim queue (stolen), never mixed with its own
        ex = Executor(2, batch=4, steal_penalty=lambda t, w: 1.0)
        for i in range(6):
            ex.submit(ex.make_task(payload=i, home=0))
        ex.step()
        kinds = [(e.kind, e.worker, e.src_domain) for e in ex.events
                 if e.kind in ("run", "steal")]
        assert ("steal", 1, 0) in kinds      # worker 0 grabs 4, worker 1
        assert all(src == 0 for _, _, src in kinds)   # steals the rest
        assert ex.stats.executed == 6        # one round served everything

    def test_budgeted_drain_bounds_grab_cost(self):
        q = DomainQueues(1)
        for uid, c in enumerate((3.0, 3.0, 3.0, 1.0)):
            q.enqueue(Task(uid=uid, cost=c), 0)
        first = q.dequeue(0).item
        got = q.drain(0, 8, budget=7.0, spent=first.cost)
        assert [t.uid for t in got] == [1]   # 3+3 fits, a third 3 would not
        assert len(q) == 2

    def test_batch_budget_respected_end_to_end(self):
        gov = BatchGovernor(target_service=4.0, batch_cap=8, init_size=8)
        ex = Executor(1, batch=gov)
        for i in range(8):
            ex.submit(ex.make_task(payload=i, home=0, cost=2.0))
        ex.step()
        assert ex.stats.executed == 2        # 2 x cost 2.0 fills budget 4

    def test_batch_handler_called_with_grabs(self):
        grabs = []

        def bh(tasks, worker):
            grabs.append([t.uid for t in tasks])
            return [t.payload for t in tasks]

        ex = Executor(2, batch=3, batch_handler=bh)
        for i in range(9):
            ex.submit(ex.make_task(payload=i, home=i % 2))
        out = ex.run_until_drained()
        assert sorted(out) == list(range(9))
        assert max(len(g) for g in grabs) > 1
        assert sorted(u for g in grabs for u in g) == list(range(9))

    def test_batch_handler_result_alignment_enforced(self):
        ex = Executor(1, batch=2, batch_handler=lambda ts, w: [None])
        ex.submit(ex.make_task(home=0))
        ex.submit(ex.make_task(home=0))
        with pytest.raises(ValueError, match="batch_handler"):
            ex.step()

    def test_events_and_stats_count_each_batched_task(self):
        ex = Executor(2, batch=4, steal_penalty=lambda t, w: 2.0)
        for i in range(12):
            ex.submit(ex.make_task(payload=i, home=0))
        ex.run_until_drained()
        s = ex.stats
        assert s.executed == 12
        assert s.local + s.stolen == 12
        counts = ex.events.counts()
        assert counts.get("run", 0) + counts.get("steal", 0) == 12
        assert s.steal_penalty == pytest.approx(2.0 * s.stolen)


class TestBatchGovernor:
    def test_adapts_size_to_service_budget(self):
        gov = BatchGovernor(target_service=8.0, batch_cap=8, ema=1.0)
        assert gov.size == 1
        gov.on_batch(1, 1.0)                 # cheap tasks -> big batches
        assert gov.size == 8
        gov.on_batch(8, 64.0)                # 8 cost units/task -> batch of 1
        assert gov.size == 1
        gov.on_batch(1, 4.0)
        assert gov.size == 2

    def test_penalties_shrink_batches(self):
        cheap = BatchGovernor(target_service=8.0, ema=1.0)
        stormy = BatchGovernor(target_service=8.0, ema=1.0)
        cheap.on_batch(4, 4.0)               # pure local cost
        stormy.on_batch(4, 20.0)             # same tasks + steal penalties
        assert stormy.size < cheap.size

    def test_validation(self):
        with pytest.raises(ValueError):
            BatchGovernor(target_service=0.0)
        with pytest.raises(ValueError):
            BatchGovernor(batch_min=4, batch_cap=2)

    def test_executor_feeds_governor(self):
        gov = BatchGovernor(target_service=4.0, batch_cap=4)
        ex = Executor(2, batch=gov)
        for i in range(16):
            ex.submit(ex.make_task(payload=i, home=i % 2, cost=1.0))
        ex.run_until_drained()
        assert gov.batches > 0 and gov.tasks == 16
        assert gov.size == 4                 # unit costs fill a budget of 4


class TestStormBreaker:
    def test_trips_on_steal_storm_and_cools_down(self):
        br = StormBreaker(GreedySteal(), width=4, cooldown=2, mode="block")
        assert not br.tripped
        br.observe_window(executed=8, stolen=6, inline=0)    # storm
        assert br.tripped and br.trips == 1
        assert br.min_victim_depth(Worker(0, 0)) is None     # stealing cut
        br.observe_window(executed=8, stolen=0, inline=0)    # quiet
        assert br.tripped                                    # still cooling
        br.observe_window(executed=8, stolen=0, inline=0)
        assert not br.tripped                                # cooled down
        assert br.min_victim_depth(Worker(0, 0)) == 1
        assert br.trips == 1

    def test_restorm_during_cooldown_rearms_once(self):
        br = StormBreaker(GreedySteal(), width=4, cooldown=3)
        br.observe_window(8, 6, 0)
        br.observe_window(8, 6, 0)           # still storming: re-arm
        assert br.trips == 1                 # one episode, not two

    def test_inline_burst_trips(self):
        br = StormBreaker(GreedySteal(), width=4, cooldown=1)
        br.observe_window(executed=8, stolen=0, inline=4)
        assert br.tripped

    def test_raise_mode_boosts_inner_threshold(self):
        inner = AdaptiveSteal(penalty_hint=2.0)
        br = StormBreaker(inner, mode="raise", boost=8)
        w = Worker(0, 0)
        base = br.min_victim_depth(w)
        br.observe_window(8, 6, 0)
        assert br.min_victim_depth(w) == base + 8

    def test_tiny_windows_never_trip(self):
        br = StormBreaker(GreedySteal(), min_executed=4)
        br.observe_window(executed=2, stolen=2, inline=0)
        assert not br.tripped

    def test_live_breaker_trips_under_hot_skew(self):
        loop = ControlLoop(breaker=StormBreaker(width=4, cooldown=2,
                                                mode="block"))
        ex = loop.attach(Executor(4, steal_penalty=_penalty))
        trace.drive(ex, _skewed_workload())
        assert loop.breaker.trips >= 1
        assert not loop.breaker.tripped      # drained queues = quiet windows

    def test_breaker_reduces_storm_windows(self):
        wl = _skewed_workload()

        def run(control):
            ex = Executor(4, steal_penalty=_penalty)
            if control:
                ControlLoop(breaker=StormBreaker(width=4, cooldown=2,
                                                 mode="block")).attach(ex)
            trace.drive(ex, wl)
            return ex

        plain, broken = run(False), run(True)
        storms = lambda ex: len(  # noqa: E731
            trace.detect_steal_storms(ex.events, width=4))
        assert broken.stats.executed == plain.stats.executed == wl.n_tasks
        assert storms(broken) < storms(plain)
        assert broken.stats.steal_penalty < plain.stats.steal_penalty


class TestCostWeightedStealOrder:
    def test_victim_is_most_queued_cost_not_depth(self):
        q = DomainQueues(3, steal_order="cost_weighted")
        q.enqueue(Task(uid=0, cost=1.0), 1)
        q.enqueue(Task(uid=1, cost=1.0), 1)      # domain 1: depth 2, cost 2
        q.enqueue(Task(uid=2, cost=9.0), 2)      # domain 2: depth 1, cost 9
        got = q.dequeue(0)
        assert got.domain == 2 and got.stolen
        assert q.cost(2) == 0.0
        assert q.queue_costs() == [0.0, 2.0, 0.0]

    def test_cost_tracking_through_drain(self):
        q = DomainQueues(2, steal_order="cost_weighted")
        for uid, c in enumerate((2.0, 3.0, 5.0)):
            q.enqueue(Task(uid=uid, cost=c), 0)
        assert q.cost(0) == pytest.approx(10.0)
        q.dequeue(0)
        assert q.cost(0) == pytest.approx(8.0)
        assert [t.cost for t in q.drain(0, 5)] == [3.0, 5.0]
        assert q.cost(0) == 0.0 and len(q) == 0


class TestCostRouter:
    def test_routes_to_least_backlog(self):
        ex = Executor(3)
        router = CostRouter(spill_penalty=None).bind(ex)
        ex.queues.enqueue(Task(uid=0, cost=5.0), 0)
        ex.queues.enqueue(Task(uid=1, cost=1.0), 1)
        assert router.route(Task(uid=2, cost=1.0)) == 2      # empty wins
        assert router.backlog_time(0) == 5.0

    def test_home_sticky_until_spill_penalty(self):
        ex = Executor(2)
        router = CostRouter(spill_penalty=4.0).bind(ex)
        ex.queues.enqueue(Task(uid=0, cost=3.0), 0)
        assert router.route(Task(uid=1, home=0)) == 0        # gap 3 <= 4
        ex.queues.enqueue(Task(uid=2, cost=3.0), 0)
        assert router.route(Task(uid=3, home=0)) == 1        # gap 6 > 4
        assert router.spilled == 1

    def test_measured_spill_tracks_governor_estimate(self):
        # spill="measured": the threshold is the governor's live penalty
        # estimate, not the static hint (ROADMAP control follow-up).
        gov = AdaptiveSteal(penalty_hint=10.0)
        ex = Executor(2, governor=gov)
        router = CostRouter(spill_penalty=4.0, measured=True).bind(ex)
        assert router.spill_threshold() == 10.0          # estimate, not 4.0
        # estimate decays toward observed penalties -> threshold follows
        for _ in range(50):
            gov.on_execute(Worker(wid=0, domain=0), stolen=True, penalty=2.0)
        assert router.spill_threshold() == pytest.approx(gov.penalty_estimate)
        assert router.spill_threshold() < 4.0

    def test_measured_spill_unwraps_breaker_and_falls_back(self):
        # a StormBreaker decorates the governor: the router must read the
        # inner estimate through it...
        gov = AdaptiveSteal(penalty_hint=7.0)
        ex = Executor(2, governor=StormBreaker(gov))
        router = CostRouter(spill_penalty=4.0, measured=True).bind(ex)
        assert router.spill_threshold() == 7.0
        # ...and governors that measure nothing fall back to the hint.
        ex2 = Executor(2, governor=GreedySteal())
        router2 = CostRouter(spill_penalty=4.0, measured=True).bind(ex2)
        assert router2.spill_threshold() == 4.0

    def test_measured_spill_changes_routing_decision(self):
        # same backlog gap: static hint 4.0 keeps the task home, a learned
        # low penalty (cheap steals -> cheap spills) sends it away.
        def mk(measured, learned):
            gov = AdaptiveSteal(penalty_hint=learned, ema=1.0)
            ex = Executor(2, governor=gov)
            r = CostRouter(spill_penalty=4.0, measured=measured).bind(ex)
            ex.queues.enqueue(Task(uid=0, cost=3.0), 0)
            return r.route(Task(uid=1, home=0))

        assert mk(False, 1.0) == 0        # static: gap 3 <= 4, stay home
        assert mk(True, 1.0) == 1         # measured: gap 3 > 1, spill

    def test_never_routes_to_unserved_domain(self):
        # domain 2 has no pinned worker: the router must not feed it
        ex = Executor(3, worker_domains=[0, 1])
        router = CostRouter(spill_penalty=0.0).bind(ex)
        for _ in range(8):
            d = router.route(Task(uid=0, cost=1.0))
            assert d in (0, 1)
            ex.queues.enqueue(Task(uid=0, cost=1.0), d)

    def test_beats_round_robin_backlog_on_lognormal_costs(self):
        # acceptance: on a hot-skewed heavy-tailed stream under budgeted
        # continuous batching, cost routing beats both round-robin and home
        # routing on mean end-to-end backlog time — wait plus service with
        # the serving engine's accounting (a task executed off its home
        # domain re-prefills, i.e. pays the nonlocal penalty).  Round-robin
        # balances items but scatters 3/4 of tasks off-home; home routing
        # keeps locality but force-feeds the hot queue; the router pays the
        # penalty only when the queueing-delay gap is worth it.
        miss_factor = 4.0
        wl = trace.lognormal_costs(
            trace.hot_skew(trace.poisson(rate=8, steps=48, num_domains=4,
                                         seed=0), hot_domain=0, p_hot=0.8,
                           seed=0),
            median=2.0, sigma=1.0, seed=0)

        def backlog_time(mode):
            ex = Executor(4, steal_penalty=lambda t, w: miss_factor * t.cost,
                          batch=BatchGovernor(target_service=8.0,
                                              batch_cap=8))
            if mode == "router":
                ex.router = CostRouter(spill_penalty=8.0).bind(ex).route
            homes = {}
            by_step = wl.by_step()
            for t in range(wl.horizon):
                for a in by_step.get(t, ()):
                    task = ex.make_task(home=a.home, cost=a.cost)
                    homes[task.uid] = a.home
                    ex.submit(task, domain=ex.next_round_robin()
                              if mode == "rr" else None)
                ex.step()
            ex.run_until_drained()
            assert ex.stats.executed == wl.n_tasks
            subs = {e.task_uid: e.step for e in ex.events
                    if e.kind == "submit"}
            soj, misses = [], 0
            for e in ex.events:
                if e.kind in ("run", "steal", "inline"):
                    miss = homes[e.task_uid] >= 0 \
                        and e.domain != homes[e.task_uid]
                    misses += miss
                    soj.append((e.step - subs[e.task_uid]) + e.cost
                               + (miss_factor * e.cost if miss else 0.0))
            return float(np.mean(soj)), misses

        router, router_miss = backlog_time("router")
        rr, rr_miss = backlog_time("rr")
        home, _ = backlog_time("home")
        assert router < rr < home
        assert router_miss < rr_miss    # fewer re-prefills than round-robin


class TestRoundRobinHotSkip:
    def test_skips_domain_over_twice_mean_depth(self):
        ex = Executor(4)
        for _ in range(12):
            ex.submit(ex.make_task(home=0))          # depths (12, 0, 0, 0)
        routed = []
        ex.submit_hook = lambda task, domain, step: routed.append(domain)
        for _ in range(6):
            ex.submit(ex.make_task())                # homeless -> round-robin
        assert 0 not in routed                       # hot domain skipped
        assert routed == [1, 2, 3, 1, 2, 3]

    def test_balanced_queues_keep_plain_cycle(self):
        ex = Executor(3)
        routed = []
        ex.submit_hook = lambda task, domain, step: routed.append(domain)
        for _ in range(6):
            ex.submit(ex.make_task())
        assert routed == [0, 1, 2, 0, 1, 2]

    def test_hot_skew_workload_regression(self):
        # 80% of arrivals homed hot on domain 0, the rest homeless: the
        # homeless remainder must not be force-fed to the hot queue
        wl = trace.hot_skew(trace.poisson(rate=4, steps=32, num_domains=4,
                                          seed=7), hot_domain=0, p_hot=0.8,
                            seed=7)
        overfed = []

        def hook(task, domain, step):
            if task.home < 0:
                sizes = ex.queues.queue_sizes()
                sizes[domain] -= 1               # depth before this enqueue
                cap = 2.0 * sum(sizes) / len(sizes)
                overfed.append(sizes[domain] > cap)

        ex = Executor(4, steal_penalty=_penalty, submit_hook=hook)
        by_step = wl.by_step()
        for t in range(wl.horizon):
            for a in by_step.get(t, ()):
                home = a.home if a.home == 0 else -1
                ex.submit(ex.make_task(home=home, cost=a.cost))
            ex.step()
        ex.run_until_drained()
        assert overfed and not any(overfed)
        assert ex.stats.executed == wl.n_tasks


class TestControlledReplay:
    def _loop(self):
        return ControlLoop.full(spill_penalty=4.0, width=4, cooldown=2)

    def test_controlled_run_replays_bit_identical(self):
        # acceptance: record a fully-controlled run, replay it with a fresh
        # identically-configured control plane -> RuntimeStats bit-identical
        rec = trace.TraceRecorder()
        ex = self._loop().attach(Executor(4, steal_penalty=_penalty))
        rec.attach(ex)
        trace.drive(ex, _skewed_workload())
        t = rec.finish()
        assert t.meta["governor"] == "StormBreaker"
        res = trace.replay(t, lambda tr: self._loop().attach(
            trace.executor_from_meta(tr, governor=GreedySteal(),
                                     steal_penalty=_penalty)),
            assert_match=True)
        assert res.matches_recorded

    def test_controlled_beats_uncontrolled_on_replayed_trace(self):
        # the benchmark's gate, in miniature: same recorded arrivals, the
        # controlled arm pays less steal penalty with no lost work
        rec = trace.TraceRecorder()
        ex = rec.attach(Executor(4, steal_penalty=_penalty))
        trace.drive(ex, _skewed_workload())
        t = rec.finish()
        un = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=_penalty), reroute=True)
        co = trace.replay(t, lambda tr: self._loop().attach(
            trace.executor_from_meta(tr, governor=GreedySteal(),
                                     steal_order="cost_weighted",
                                     steal_penalty=_penalty)), reroute=True)
        assert co.stats["executed"] == un.stats["executed"] == t.n_tasks
        assert co.stats["steal_penalty"] < un.stats["steal_penalty"]
        delta = trace.compare_replays(un, co)
        assert delta.mean_sojourn[1] <= delta.mean_sojourn[0]

    def test_reroute_rejects_assert_match(self):
        rec = trace.TraceRecorder()
        ex = rec.attach(Executor(2))
        ex.submit(ex.make_task(home=0))
        ex.run_until_drained()
        t = rec.finish()
        with pytest.raises(ValueError):
            trace.replay(t, reroute=True, assert_match=True)


class TestControlLoopWiring:
    def test_attach_splices_all_hooks(self):
        loop = ControlLoop.full()
        ex = loop.attach(Executor(4))
        assert ex.router is not None
        assert ex.batch is loop.batcher
        assert isinstance(ex.governor, StormBreaker)
        assert ex.step_hook is not None

    def test_breaker_wraps_existing_governor(self):
        inner = AdaptiveSteal(penalty_hint=3.0)
        loop = ControlLoop(breaker=StormBreaker())
        ex = loop.attach(Executor(2, governor=inner))
        assert ex.governor.inner is inner

    def test_single_attach(self):
        loop = ControlLoop.full()
        loop.attach(Executor(2))
        with pytest.raises(RuntimeError):
            loop.attach(Executor(2))


class TestServingBatchIdentity:
    @pytest.fixture(scope="class")
    def small_model(self):
        jax = pytest.importorskip("jax")
        from repro.configs import get_config, reduce_config
        from repro.models.model import build_model

        cfg = reduce_config(get_config("qwen2-0.5b"))
        model = build_model(cfg, max_pos=96)
        params = model.init_params(jax.random.key(0))
        return cfg, model, params

    def _requests(self, cfg, n=8, replicas=2, seed=0):
        from repro.serving.engine import Request

        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            toks = rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(6, 14)))
            home = int(rng.integers(0, replicas)) if rng.random() < 0.7 else -1
            out.append(Request(uid=i, tokens=toks, max_new=3,
                               home_replica=home))
        return out

    def test_batched_outputs_token_identical_all_policies(self, small_model):
        # acceptance: batching enabled vs disabled, identical tokens under
        # every routing policy
        from repro.serving.engine import ServingEngine

        cfg, model, params = small_model
        for policy in ("locality", "round_robin", "single_queue"):
            outs = {}
            for batch in (1, 3):
                eng = ServingEngine(model, params, num_replicas=2,
                                    max_seq=64, policy=policy, batch=batch)
                for r in self._requests(cfg):
                    eng.submit(r)
                done = eng.run_until_drained()
                assert eng.stats.served == 8
                outs[batch] = {r.uid: tuple(r.out_tokens) for r in done}
            assert outs[1] == outs[3], policy

    def test_controlled_engine_matches_uncontrolled_tokens(self, small_model):
        from repro.serving.engine import ServingEngine

        cfg, model, params = small_model

        def serve(control):
            eng = ServingEngine(model, params, num_replicas=2, max_seq=64,
                                policy="locality", control=control)
            for r in self._requests(cfg, seed=4):
                eng.submit(r)
            return {r.uid: tuple(r.out_tokens)
                    for r in eng.run_until_drained()}

        base = serve(None)
        controlled = serve(ControlLoop.full(batch_cap=4))
        assert controlled == base
