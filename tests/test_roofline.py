"""The loop-aware HLO cost analyzer vs hand-counted references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import LINK_BW, Roofline, collective_bytes
from repro.roofline.hlo_cost import analyze_text


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((1024, 2048), jnp.bfloat16)
        c = _compile(lambda a, b: a @ b, a, b)
        cost = analyze_text(c.as_text())
        expect = 2 * 512 * 1024 * 2048
        assert abs(cost.flops - expect) / expect < 0.05

    def test_scan_multiplies_by_trip_count(self):
        """The whole reason hlo_cost exists: XLA's own cost_analysis counts
        a while body once; we must count it trip_count times."""
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=10)
            return y
        c = _compile(f, jax.ShapeDtypeStruct((512, 1024), jnp.bfloat16),
                     jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16))
        cost = analyze_text(c.as_text())
        one = 2 * 512 * 1024 * 1024
        assert abs(cost.flops - 10 * one) / (10 * one) < 0.1
        # sanity: the built-in counter misses the multiplier
        ca = c.cost_analysis()
        if isinstance(ca, list):        # older jax wraps it in a list
            ca = ca[0]
        xla = ca["flops"]
        assert xla < 0.2 * cost.flops

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                ci, _ = jax.lax.scan(inner, c, None, length=4)
                return ci, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y
        c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                     jax.ShapeDtypeStruct((256, 256), jnp.float32))
        cost = analyze_text(c.as_text())
        one = 2 * 128 * 256 * 256
        assert abs(cost.flops - 12 * one) / (12 * one) < 0.1

    def test_grad_adds_backward_flops(self):
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = _compile(jax.grad(lambda a, b: jnp.sum(a @ b), argnums=(0, 1)),
                     a, b)
        cost = analyze_text(c.as_text())
        one = 2 * 256 ** 3
        assert cost.flops > 1.8 * one     # two backward matmuls


class TestBytes:
    def test_matmul_bytes_reasonable(self):
        a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        c = _compile(lambda a, b: a @ b, a, a)
        cost = analyze_text(c.as_text())
        minimum = 3 * 1024 * 1024 * 4
        assert minimum <= cost.bytes <= 4 * minimum


@pytest.mark.skipif(len(jax.devices()) != 1, reason="single-device test run")
class TestRooflineTerms:
    def test_bottleneck_selection(self):
        r = Roofline(flops=197e12, hbm_bytes=1e9, coll_bytes=0,
                     coll_by_kind={})
        assert r.bottleneck == "compute"
        assert abs(r.t_compute - 1.0) < 1e-9
        r2 = Roofline(flops=1e12, hbm_bytes=819e9 * 2, coll_bytes=0,
                      coll_by_kind={})
        assert r2.bottleneck == "memory"
        r3 = Roofline(flops=0, hbm_bytes=0, coll_bytes=LINK_BW * 3,
                      coll_by_kind={})
        assert abs(r3.t_collective - 3.0) < 1e-9
