"""Checkpoint manager: roundtrip, retention, async errors, crash-resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data.pipeline import make_batch_iterator
from repro.models.model import build_model
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "stack": [jnp.arange(6).reshape(2, 3).astype(jnp.float32)]},
        "opt": {"m": jnp.zeros((8, 8)), "step": jnp.asarray(7, jnp.int32)},
    }


class TestRoundtrip:
    def test_save_restore_identical(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=False)
        state = _state()
        mgr.save(10, state)
        restored = mgr.restore(10, jax.tree.map(jnp.zeros_like, state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state())
        assert mgr.all_steps() == [3, 4]
        assert mgr.latest_step() == 4

    def test_async_write_then_wait(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=True)
        mgr.save(5, _state())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path, async_write=False)
        mgr.save(1, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.zeros((2, 2))})

    def test_restore_latest_empty_dir(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        step, state = mgr.restore_latest({"w": jnp.zeros(3)})
        assert step is None


class TestCrashResume:
    def test_interrupted_run_resumes_identically(self, tmp_path):
        """Train 12 steps straight vs train 6 + 'crash' + resume 6: the
        final params must match exactly (deterministic data replay)."""
        cfg = reduce_config(get_config("qwen2-0.5b"))
        model = build_model(cfg, max_pos=64)
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)

        def data():
            return make_batch_iterator(cfg.vocab_size, 16, 4, seed=3)

        # uninterrupted reference
        t_ref = Trainer(model, data(),
                        LoopConfig(total_steps=12, checkpoint_every=100,
                                   checkpoint_dir=None, log_every=100), opt,
                        log_fn=lambda s: None)
        ref = t_ref.run(seed=1)

        # crash after step 6 (checkpoint_every=6 → checkpoint exists)
        d1 = str(tmp_path / "ck")
        t1 = Trainer(model, data(),
                     LoopConfig(total_steps=6, checkpoint_every=6,
                                checkpoint_dir=d1, log_every=100), opt,
                     log_fn=lambda s: None)
        t1.run(seed=1)

        # resume to 12
        t2 = Trainer(model, data(),
                     LoopConfig(total_steps=12, checkpoint_every=6,
                                checkpoint_dir=d1, log_every=100), opt,
                     log_fn=lambda s: None)
        resumed = t2.run(seed=1)

        for a, b in zip(jax.tree.leaves(ref["params"]),
                        jax.tree.leaves(resumed["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-6)
