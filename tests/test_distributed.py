"""Multi-device integration tests.

Run in a subprocess so the 8-device XLA_FLAGS override never leaks into this
pytest process (smoke tests must see 1 device, per the dry-run contract).
"""
import os
import subprocess
import sys
from pathlib import Path

import jax.sharding
import pytest

CHECKS = Path(__file__).parent / "distributed_checks.py"


@pytest.mark.timeout(900)
@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="distributed checks need jax with "
                           "sharding.AxisType/set_mesh/shard_map")
def test_distributed_checks_subprocess():
    env = dict(os.environ)
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(CHECKS)], env=env, capture_output=True,
        text=True, timeout=880)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for name in ("stencil_locality", "sharded_train_matches_single",
                 "pipeline_parallel", "collectives",
                 "seq_parallel_attention", "dryrun_cell_small_mesh"):
        assert f"OK {name}" in out, out[-4000:]
    assert "ALL DISTRIBUTED CHECKS PASSED" in out
