"""repro.trace: workloads, trace round-trip, deterministic replay, storms,
measured-penalty feedback."""
import dataclasses
import os

import numpy as np
import pytest

from repro import trace
from repro.runtime import AdaptiveSteal, Event, Executor, GreedySteal, Worker


def _penalty(task, worker) -> float:
    return 4.0 * task.cost


def _recorded_run(workload=None, seed=0, steal_order="cyclic",
                  steal_penalty=_penalty):
    wl = workload or trace.hot_skew(
        trace.poisson(rate=4, steps=24, num_domains=4, seed=seed),
        hot_domain=0, p_hot=0.8, seed=seed)
    rec = trace.TraceRecorder()
    ex = rec.attach(Executor(4, steal_order=steal_order,
                             steal_penalty=steal_penalty, seed=seed))
    trace.drive(ex, wl)
    return rec.finish(), ex


class TestWorkloads:
    def test_generators_deterministic_per_seed(self):
        for gen in (lambda s: trace.poisson(3.0, 20, 4, seed=s),
                    lambda s: trace.bursty(1.0, 8.0, 20, 4, seed=s),
                    lambda s: trace.diurnal(6.0, 20, 4, seed=s)):
            assert gen(5) == gen(5)
            assert gen(5) != gen(6)

    def test_arrivals_well_formed(self):
        for wl in trace.standard_scenarios(num_domains=4, steps=32).values():
            assert wl.n_tasks > 0
            assert all(0 <= a.home < 4 for a in wl.arrivals)
            assert all(a.step >= 0 and a.cost > 0 for a in wl.arrivals)
            assert wl.horizon >= max(a.step for a in wl.arrivals)

    def test_hot_skew_rehomes_requested_fraction(self):
        base = trace.poisson(rate=5, steps=200, num_domains=4, seed=0)
        hot = trace.hot_skew(base, hot_domain=2, p_hot=0.8, seed=1)
        assert hot.n_tasks == base.n_tasks
        frac = sum(a.home == 2 for a in hot.arrivals) / hot.n_tasks
        assert 0.7 < frac < 0.95          # 0.8 target + base's 1/4 overlap

    def test_lognormal_costs_heavy_tail(self):
        wl = trace.lognormal_costs(
            trace.poisson(rate=5, steps=100, num_domains=4, seed=0),
            median=2.0, sigma=1.0, seed=3)
        costs = [a.cost for a in wl.arrivals]
        assert min(costs) > 0
        assert max(costs) > np.median(costs) * 3   # tail present

    def test_drive_lands_arrivals_on_step_clock(self):
        wl = trace.poisson(rate=2, steps=10, num_domains=2, seed=0)
        rec = trace.TraceRecorder()
        ex = rec.attach(Executor(2))
        trace.drive(ex, wl)
        t = rec.finish()
        recorded = sorted((s.step, s.home) for s in t.submissions)
        expected = sorted((a.step, a.home) for a in wl.arrivals)
        assert recorded == expected


class TestTraceRoundTrip:
    def test_jsonl_round_trip_lossless(self):
        t, _ = _recorded_run()
        t2 = trace.loads_lines(trace.dumps_lines(t))
        assert t2.meta == t.meta
        assert t2.submissions == t.submissions
        assert t2.events == t.events
        assert t2.stats == t.stats
        assert t2.total_steps == t.total_steps
        assert t2.event_counts == t.event_counts

    def test_file_round_trip(self, tmp_path):
        t, _ = _recorded_run()
        path = tmp_path / "run.trace.jsonl"
        trace.TraceWriter(path).write(t)
        t2 = trace.TraceReader(path).read()
        assert t2.submissions == t.submissions and t2.stats == t.stats

    def test_unknown_schema_rejected(self):
        t, _ = _recorded_run()
        lines = trace.dumps_lines(t)
        tag = f'"schema": {trace.SCHEMA_VERSION}'
        assert tag in lines[0]
        bad = [lines[0].replace(tag, '"schema": 99')] + lines[1:]
        with pytest.raises(trace.TraceSchemaError):
            trace.loads_lines(bad)

    def test_headerless_trace_rejected(self):
        t, _ = _recorded_run()
        with pytest.raises(trace.TraceSchemaError):
            trace.loads_lines(trace.dumps_lines(t)[1:])

    def test_recorder_single_use(self):
        rec = trace.TraceRecorder()
        rec.attach(Executor(2))
        with pytest.raises(RuntimeError):
            rec.attach(Executor(2))


class TestReplay:
    def test_replay_reproduces_recorded_stats_bit_identical(self):
        # write -> read -> replay, twice: both runs match the recorded
        # stats exactly (the acceptance criterion).
        t, _ = _recorded_run()
        t = trace.loads_lines(trace.dumps_lines(t))
        factory = lambda tr: trace.executor_from_meta(  # noqa: E731
            tr, steal_penalty=_penalty)
        r1 = trace.replay(t, factory, assert_match=True)
        r2 = trace.replay(t, factory, assert_match=True)
        assert r1.stats == r2.stats == {
            k: t.stats[k] for k in r1.stats}

    def test_replay_random_steal_order_deterministic(self):
        t, _ = _recorded_run(steal_order="random", seed=3)
        factory = lambda tr: trace.executor_from_meta(  # noqa: E731
            tr, steal_penalty=_penalty)
        trace.replay(t, factory, assert_match=True)

    def test_replay_policy_ab_same_arrivals(self):
        # same trace, different governor: total work identical, steal
        # behaviour different (the A/B the subsystem exists for).
        t, ex = _recorded_run()
        assert ex.stats.stolen > 0
        res = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, governor=AdaptiveSteal(penalty_hint=4.0),
            steal_penalty=_penalty))
        assert res.executor.stats.executed == t.n_tasks
        assert res.executor.stats.stolen < ex.stats.stolen

    def test_replay_divergence_reported(self):
        t, _ = _recorded_run()
        # replaying without the recorded penalty function diverges on the
        # steal_penalty stat -> assert_match must raise and say which key.
        with pytest.raises(AssertionError, match="steal_penalty"):
            trace.replay(t, lambda tr: trace.executor_from_meta(tr),
                         assert_match=True)

    def test_replay_requires_fresh_executor(self):
        t, _ = _recorded_run()

        def stale(tr):
            ex = trace.executor_from_meta(tr, steal_penalty=_penalty)
            ex.step()
            return ex

        with pytest.raises(ValueError):
            trace.replay(t, stale)

    def test_stencil_sweep_record_and_replay(self):
        pytest.importorskip("jax")
        from repro.stencil.jacobi import run_runtime_sweep

        rng = np.random.default_rng(1)
        f = rng.standard_normal((40, 6, 8)).astype(np.float32)
        rec = trace.TraceRecorder()
        out, stats = run_runtime_sweep(f, di=5, num_domains=4, trace=rec)
        t = rec.finish()
        assert t.n_tasks == 8 and t.stats["executed"] == stats.executed
        trace.replay(t, assert_match=True)   # sweep pays no steal penalty


class TestStorms:
    def _events(self, spec):
        # spec: list of (step, kind, worker) triples
        return [Event(step=s, kind=k, worker=w, domain=w, task_uid=i)
                for i, (s, k, w) in enumerate(spec)]

    def test_windows_fold_counts(self):
        evs = self._events([(0, "run", 0), (1, "steal", 1), (7, "idle", 0),
                            (8, "run", 0)])
        w0, w1 = trace.windows(evs, width=8)
        assert (w0.start, w0.runs, w0.steals, w0.idles) == (0, 1, 1, 1)
        assert (w1.start, w1.runs) == (8, 1)
        assert w0.executed == 2 and w0.steal_fraction == 0.5

    def test_detect_steal_storm_thresholds(self):
        quiet = self._events([(0, "run", 0)] * 6 + [(0, "steal", 1)] * 2)
        storm = self._events([(0, "run", 0)] * 2 + [(0, "steal", 1)] * 6)
        assert trace.detect_steal_storms(quiet, width=8) == []
        hits = trace.detect_steal_storms(storm, width=8)
        assert len(hits) == 1 and hits[0].steal_fraction == 0.75
        # too little evidence -> no storm, whatever the fraction
        tiny = self._events([(0, "steal", 1)] * 2)
        assert trace.detect_steal_storms(tiny, width=8,
                                         min_executed=4) == []

    def test_detect_inline_bursts(self):
        evs = self._events([(0, "inline", 0)] * 3 + [(0, "run", 1)] * 5)
        hits = trace.detect_inline_bursts(evs, width=8, frac=0.25)
        assert len(hits) == 1 and hits[0].inlines == 3

    def test_depth_imbalance_windows(self):
        series = [(0, (4, 0, 0, 0)), (1, (1, 1, 1, 1)), (9, (0, 8, 0, 0))]
        imb = dict(trace.depth_imbalance(series, width=8))
        assert imb[0] == pytest.approx(3.0)     # 4 - mean(1)
        assert imb[8] == pytest.approx(6.0)     # 8 - mean(2)

    def test_render_timeline_marks_storms(self):
        evs = self._events([(s, "steal", 1) for s in range(8)]
                           + [(s, "run", 0) for s in range(8, 16)])
        txt = trace.render_timeline(evs, num_workers=2, width=8)
        lines = txt.splitlines()
        assert any(ln.lstrip().startswith("w0") for ln in lines)
        w1 = next(ln for ln in lines if ln.lstrip().startswith("w1"))
        assert "S" in w1
        assert "^" in lines[-1]                 # storm marker row
        assert trace.render_timeline([], 2) == "(no events)"

    def test_live_executor_storm_detected_under_skew(self):
        t, ex = _recorded_run()
        assert ex.stats.stolen > 0
        assert trace.detect_steal_storms(t.events, width=4) != []


class TestSegmentedTraces:
    def test_one_shot_segmented_round_trip(self, tmp_path):
        t, _ = _recorded_run()
        d = tmp_path / "segments"
        trace.TraceWriter(d, segment_records=20).write(t)
        segs = sorted(d.glob("segment-*.jsonl"))
        assert len(segs) > 1                      # actually rotated
        assert all(sum(1 for _ in s.open()) <= 20 for s in segs)
        t2 = trace.TraceReader(d).read()
        assert t2.meta == t.meta
        assert t2.submissions == t.submissions
        assert t2.events == t.events
        assert t2.stats == t.stats

    def test_streaming_export_writes_submissions_live(self, tmp_path):
        # the long-running-server path: submissions hit disk as they are
        # recorded, finish() only appends events + footer
        d = tmp_path / "stream"
        w = trace.TraceWriter(d, segment_records=8)
        rec = trace.TraceRecorder(stream=w)
        ex = rec.attach(Executor(2, steal_penalty=_penalty))
        for i in range(12):
            ex.submit(ex.make_task(payload=i, home=i % 2))
        mid = sum(1 for s in d.glob("*.jsonl") for _ in s.open())
        assert mid >= 13                          # header + submissions live
        ex.run_until_drained()
        t = rec.finish()
        t2 = trace.TraceReader(d).read()
        assert t2.submissions == t.submissions
        assert t2.stats == t.stats
        assert t2.events == t.events
        trace.replay(t2, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=_penalty), assert_match=True)

    def test_segmented_replayable_same_as_single_file(self, tmp_path):
        t, _ = _recorded_run()
        trace.TraceWriter(tmp_path / "one.jsonl").write(t)
        trace.TraceWriter(tmp_path / "many", segment_records=10).write(t)
        one = trace.TraceReader(tmp_path / "one.jsonl").read()
        many = trace.TraceReader(tmp_path / "many").read()
        assert one.submissions == many.submissions
        assert one.stats == many.stats

    def test_streaming_needs_segments_and_single_begin(self, tmp_path):
        with pytest.raises(RuntimeError):
            trace.TraceWriter(tmp_path / "x.jsonl").begin({})
        w = trace.TraceWriter(tmp_path / "d", segment_records=4)
        w.begin({"num_domains": 2})
        with pytest.raises(RuntimeError):
            w.begin({"num_domains": 2})
        with pytest.raises(ValueError):
            trace.TraceWriter(tmp_path / "d2", segment_records=0)

    def test_empty_segment_dir_rejected(self, tmp_path):
        d = tmp_path / "empty"
        d.mkdir()
        with pytest.raises(trace.TraceSchemaError):
            trace.TraceReader(d).read()


class TestCounterfactualMetrics:
    def test_task_times_cover_all_tasks(self):
        t, _ = _recorded_run()
        res = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=_penalty), assert_match=True)
        times = res.task_times()
        assert len(times) == t.n_tasks
        subs = {s.uid: s.step for s in t.submissions}
        for uid, tt in times.items():
            assert tt.submit_step == subs[uid]
            assert tt.wait >= 0
            assert tt.sojourn == tt.wait + tt.service

    def test_identical_replays_have_zero_deltas(self):
        t, _ = _recorded_run()
        factory = lambda tr: trace.executor_from_meta(  # noqa: E731
            tr, steal_penalty=_penalty)
        cmp = trace.compare_replays(trace.replay(t, factory),
                                    trace.replay(t, factory))
        assert cmp.n_tasks == t.n_tasks
        assert set(cmp.wait_delta.values()) == {0}
        assert cmp.improved == cmp.regressed == 0
        assert cmp.mean_wait[0] == cmp.mean_wait[1]

    def test_governor_ab_reports_per_task_deltas(self):
        t, _ = _recorded_run()
        greedy = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=_penalty))
        throttled = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, governor=AdaptiveSteal(penalty_hint=4.0),
            steal_penalty=_penalty))
        cmp = trace.compare_replays(greedy, throttled)
        assert cmp.n_tasks == t.n_tasks
        # the throttle must actually move individual tasks, both ways
        assert cmp.improved > 0 and cmp.regressed > 0
        # aggregate means are consistent with the per-task deltas
        mean_delta = sum(cmp.sojourn_delta.values()) / cmp.n_tasks
        assert mean_delta == pytest.approx(
            cmp.mean_sojourn[1] - cmp.mean_sojourn[0])

    def test_task_times_on_recorded_trace(self):
        t, _ = _recorded_run()
        times = trace.task_times(t.submissions, t.events)
        assert times and all(v.wait >= 0 for v in times.values())


class TestMeasuredPenalty:
    def test_theta_within_observed_service_range(self):
        # acceptance: MeasuredPenalty-fed AdaptiveSteal reaches a θ within
        # the service-time range observed in the trace.
        t, _ = _recorded_run()
        services = [e.service for e in t.events
                    if e.kind in ("run", "steal", "inline")]
        gov = trace.MeasuredPenalty.from_trace(t)
        assert min(services) <= gov.threshold <= max(services)
        assert gov.observed_steals == t.stats["stolen"]

    def test_from_trace_seeds_match_measured_means(self):
        t, _ = _recorded_run()
        gov = trace.MeasuredPenalty.from_trace(t)
        pens = [e.penalty for e in t.events if e.kind == "steal"]
        costs = [e.cost for e in t.events
                 if e.kind in ("run", "steal", "inline")]
        assert gov.penalty_estimate == pytest.approx(np.mean(pens))
        assert gov.local_cost_estimate == pytest.approx(np.mean(costs))

    def test_backpressure_inline_steals_counted_as_steals(self):
        # a tiny pool forces the submitter to execute inline; with all work
        # homed on the foreign domain those inline runs are steals and pay
        # the penalty.  The penalty must feed θ's numerator, never inflate
        # the local-cost denominator (else the feedback loop turns greedy
        # exactly when stealing is most expensive).
        rec = trace.TraceRecorder()
        ex = rec.attach(Executor(2, pool_cap=1,
                                 steal_penalty=lambda t, w: 10.0 * t.cost))
        for i in range(8):
            ex.submit(ex.make_task(payload=i, home=1))
        ex.run_until_drained()
        t = rec.finish()
        inline_steals = [e for e in t.events
                         if e.kind == "inline" and e.penalty > 0]
        assert inline_steals, "scenario must provoke backpressure steals"
        assert t.service_times()["steal"]        # classified by victim queue
        gov = trace.MeasuredPenalty.from_trace(t)
        assert gov.local_cost_estimate == pytest.approx(1.0)
        assert gov.penalty_estimate == pytest.approx(10.0)
        assert gov.threshold == 10
        assert gov.observed_steals == t.stats["stolen"]

    def test_from_trace_without_steals_defaults_greedy(self):
        wl = trace.poisson(rate=2, steps=12, num_domains=2, seed=0)
        rec = trace.TraceRecorder()
        trace.drive(rec.attach(Executor(2)), wl)
        t = rec.finish()
        if t.stats["stolen"] == 0:
            gov = trace.MeasuredPenalty.from_trace(t)
            assert gov.threshold >= 1

    def test_online_learning_tracks_costs_and_penalties(self):
        gov = trace.MeasuredPenalty(ema=0.5)
        w = Worker(0, 0)
        for _ in range(20):
            gov.on_execute(w, stolen=False, penalty=0.0, cost=2.0)
        assert gov.local_cost_estimate == pytest.approx(2.0, rel=0.01)
        for _ in range(20):
            gov.on_execute(w, stolen=True, penalty=8.0, cost=2.0)
        assert gov.penalty_estimate == pytest.approx(8.0, rel=0.01)
        assert gov.threshold == 4                # 8 / 2

    def test_live_run_with_measured_governor_steals_less_than_greedy(self):
        wl = trace.hot_skew(trace.poisson(rate=4, steps=30, num_domains=4,
                                          seed=2), p_hot=0.85, seed=2)

        def run(gov):
            ex = Executor(4, governor=gov, steal_penalty=_penalty, seed=2)
            trace.drive(ex, wl)
            return ex.stats

        greedy = run(GreedySteal())
        measured = run(trace.MeasuredPenalty())
        assert measured.executed == greedy.executed == wl.n_tasks
        assert measured.stolen < greedy.stolen
        assert measured.steal_penalty < greedy.steal_penalty


class TestArrivalDataclasses:
    def test_workload_frozen_and_replaceable(self):
        wl = trace.poisson(rate=1, steps=4, num_domains=2, seed=0)
        with pytest.raises(dataclasses.FrozenInstanceError):
            wl.name = "x"
        assert dataclasses.replace(wl, name="y").name == "y"


class TestSchemaV2SpecHeaders:
    """Schema v2: spec-built executors embed their full spec in the header;
    v1 traces stay readable and keep their explicit-executor contract."""

    V1_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                              "v1_trace_fixture.jsonl")
    # the fixture was recorded (by the PR-3-era writer) with this penalty:
    V1_PENALTY = staticmethod(lambda task, worker: 2.0)

    def _spec_run(self):
        from repro import spec

        s = spec.RuntimeSpec(
            num_domains=4,
            penalty=spec.PenaltySpec(kind="cost_factor", value=4.0),
            trace=spec.TraceSpec(record=True))
        built = s.build()
        wl = trace.hot_skew(trace.poisson(rate=4, steps=16, num_domains=4,
                                          seed=2), hot_domain=0, seed=2)
        trace.drive(built.executor, wl)
        return s, built.recorder.finish()

    def test_header_embeds_spec_and_survives_jsonl(self):
        from repro import spec

        s, t = self._spec_run()
        t2 = trace.loads_lines(trace.dumps_lines(t))
        assert t2.spec_dict is not None
        assert spec.RuntimeSpec.from_dict(t2.spec_dict) == s
        assert t2.meta == t.meta          # JSON round-trip is lossless

    def test_replay_with_no_executor_is_bit_identical(self):
        _, t = self._spec_run()
        t = trace.loads_lines(trace.dumps_lines(t))
        res = trace.replay(t, assert_match=True)       # no factory at all
        assert res.matches_recorded

    def test_raw_kwarg_executor_writes_no_spec(self):
        t, _ = _recorded_run()                         # Executor(...) direct
        assert t.spec_dict is None
        # and the default replay falls back to executor_from_meta: without
        # the (unserialized) penalty fn the stats must NOT fully match.
        res = trace.replay(t)
        assert not res.matches_recorded
        assert "steal_penalty" in res.mismatches()

    def test_v1_fixture_still_reads_and_replays(self):
        t = trace.TraceReader(self.V1_FIXTURE).read()
        assert t.spec_dict is None
        assert t.n_tasks == 29 and t.total_steps == 12
        # v1 contract unchanged: an explicit executor (with the recorded
        # penalty) reproduces the recorded stats exactly...
        res = trace.replay(t, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=self.V1_PENALTY), assert_match=True)
        assert res.matches_recorded
        # ...while the no-argument default (meta fallback, penalty unknown)
        # replays the schedule but cannot match the penalty account.
        assert not trace.replay(t).matches_recorded

    def test_written_traces_are_v5(self, tmp_path):
        _, t = self._spec_run()
        path = tmp_path / "v5.jsonl"
        trace.TraceWriter(path).write(t)
        import json
        head = json.loads(open(path).readline())
        assert head["schema"] == trace.SCHEMA_VERSION == 5
        assert head["spec"]["spec_version"] == 1


class TestColumnarEventChunks:
    def test_dumps_lines_columnar_round_trip(self):
        t, _ = _recorded_run()
        lines = trace.dumps_lines(t, columnar_events=7)
        t2 = trace.loads_lines(lines)
        assert isinstance(t2.events, trace.ColumnarEvents)
        assert len(t2.events) == len(t.events)
        assert t2.events == list(t.events)       # elementwise, lazy decode
        assert t2.submissions == t.submissions
        assert t2.stats == t.stats
        # far fewer event lines than events: ceil(n/7) chunk records
        n_chunks = sum(1 for ln in lines if '"record": "events"' in ln)
        assert n_chunks == -(-len(t.events) // 7)

    def test_columnar_events_sequence_semantics(self):
        t, _ = _recorded_run()
        t2 = trace.loads_lines(trace.dumps_lines(t, columnar_events=5))
        ev = t2.events
        assert ev[0] == t.events[0] and ev[-1] == t.events[-1]
        assert ev[2:5] == list(t.events)[2:5]
        with pytest.raises(IndexError):
            ev[len(ev)]
        # consumers written against list[Event] run unchanged
        assert t2.service_times() == t.service_times()

    def test_columnar_file_and_replay_round_trip(self, tmp_path):
        t, _ = _recorded_run()
        path = tmp_path / "run.columnar.jsonl"
        trace.TraceWriter(path, columnar_events=16).write(t)
        t2 = trace.TraceReader(path).read()
        assert t2.events == list(t.events)
        factory = lambda tr: trace.executor_from_meta(  # noqa: E731
            tr, steal_penalty=_penalty)
        rep = trace.replay(t2, factory)
        assert rep.matches_recorded, rep.mismatches()

    def test_streaming_segments_chunk_at_boundaries(self, tmp_path):
        t, _ = _recorded_run()
        d = tmp_path / "segs"
        w = trace.TraceWriter(d, segment_records=32, columnar_events=8)
        w.begin(t.meta)
        for s in t.submissions:
            w.add_submission(s)
        w.add_events(t.events)
        w.end(t)
        t2 = trace.TraceReader(d).read()
        assert t2.events == list(t.events)
        assert t2.submissions == t.submissions and t2.stats == t.stats

    def test_malformed_chunks_rejected(self):
        t, _ = _recorded_run()
        lines = trace.dumps_lines(t, columnar_events=4)
        import json
        chunk_at = next(i for i, ln in enumerate(lines)
                        if '"record": "events"' in ln)
        rec = json.loads(lines[chunk_at])
        missing = dict(rec)
        missing["columns"] = {k: v for k, v in rec["columns"].items()
                              if k != "cost"}
        ragged = json.loads(lines[chunk_at])
        ragged["columns"]["step"] = ragged["columns"]["step"][:-1]
        for bad in (missing, ragged):
            mutated = list(lines)
            mutated[chunk_at] = json.dumps(bad)
            with pytest.raises(trace.TraceSchemaError):
                trace.loads_lines(mutated)

    def test_degenerate_chunk_sizes_rejected(self, tmp_path):
        t, _ = _recorded_run()
        with pytest.raises(ValueError):
            trace.TraceWriter(tmp_path / "x.jsonl", columnar_events=0)
