"""Declarative experiments: the policy × workload replay-conformance
matrix, ExperimentSpec round-trips + golden files, governor-state
checkpoints, and the v1/segmented trace back-compat contracts."""
import dataclasses
import json
import os

import pytest

from repro import spec, trace

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
SPECS_DIR = os.path.join(REPO, "specs")
EXPERIMENTS_DIR = os.path.join(SPECS_DIR, "experiments")
V1_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                          "v1_trace_fixture.jsonl")
V1_SEGMENTS = os.path.join(os.path.dirname(__file__), "data", "v1_segments")

MATRIX_WORKLOADS = ("poisson", "bursty", "diurnal", "hot_skew")


def _small(exp, steps=12):
    """A cheap copy of a registry experiment (fewer workload steps)."""
    return dataclasses.replace(
        exp, workload=dataclasses.replace(exp.workload, steps=steps))


class TestConformanceMatrix:
    """Every registry policy (the checked-in ``specs/*.json`` files) ×
    every ``standard_scenarios`` workload: record a trace, then header-only
    ``replay(trace)`` must reproduce the recorded ``RuntimeStats``
    bit-identically.  Parametrized per cell, so a regression names the
    exact (policy, workload) pair that diverged."""

    @pytest.mark.parametrize("workload", MATRIX_WORKLOADS)
    @pytest.mark.parametrize("policy", spec.policy_names())
    def test_cell_replays_bit_identically(self, policy, workload):
        with open(os.path.join(SPECS_DIR, f"{policy}.json"),
                  encoding="utf-8") as fh:
            s = spec.RuntimeSpec.from_json(fh.read())
        assert s == spec.named(policy), \
            f"golden file for {policy} drifted from the registry"
        wl = spec.standard_workloads(num_domains=s.num_domains, steps=16,
                                     seed=9)[workload].build()
        built = s.build()
        rec = built.recorder
        if rec is None:
            rec = trace.TraceRecorder()
            rec.attach(built.executor)
        trace.drive(built.executor, wl)
        t = trace.loads_lines(trace.dumps_lines(rec.finish()))
        res = trace.replay(t, assert_match=True)
        assert res.matches_recorded, (policy, workload)


class TestWorkloadSpec:
    def test_standard_workloads_build_standard_scenarios(self):
        for d, steps, seed in ((4, 16, 0), (2, 12, 3)):
            std = trace.standard_scenarios(d, steps, seed)
            for name, wl in spec.standard_workloads(d, steps, seed).items():
                assert wl.build() == std[name], (d, steps, seed, name)

    def test_runtime_workloads_build_benchmark_waves(self):
        waves = trace.benchmark_waves(96, 4, 1)
        for name, wl in spec.runtime_workloads(n_tasks=96, seed=1).items():
            assert wl.build() == waves[name]

    def test_bursty_waves_keep_trailing_idle_rounds(self):
        wl = spec.WorkloadSpec(kind="bursty_waves", n_tasks=96).build()
        assert wl.tail_steps == 6

    def test_combinator_order_skew_then_costs(self):
        w = spec.WorkloadSpec(kind="poisson", steps=24, rate=4.0,
                              skew=spec.SkewSpec(hot_domain=1, p_hot=0.9,
                                                 seed=2),
                              costs=spec.CostsSpec(median=2.0, seed=3))
        built = w.build()
        by_hand = trace.lognormal_costs(
            trace.hot_skew(trace.poisson(rate=4.0, steps=24, num_domains=4),
                           hot_domain=1, p_hot=0.9, seed=2),
            median=2.0, sigma=0.75, seed=3)
        assert built == by_hand

    def test_unknown_workload_kind_rejected(self):
        with pytest.raises(spec.SpecError, match="workload.kind"):
            spec.WorkloadSpec(kind="sinusoid")
        with pytest.raises(spec.SpecError, match="workload.kind"):
            spec.WorkloadSpec.from_dict({"kind": "warp"})

    @pytest.mark.parametrize("payload,match", [
        ({"rate": "4.0"}, "workload.rate"),
        ({"seed": 2.5}, "workload.seed"),
        ({"steps": "48"}, "workload.steps"),
        ({"n_tasks": True}, "workload.n_tasks"),
        ({"skew": {"p_hot": "0.8"}}, "workload.skew.p_hot"),
        ({"costs": {"seed": 1.5}}, "workload.costs.seed"),
        ({"ratee": 1.0}, "ratee"),
    ])
    def test_wrong_typed_or_unknown_fields_fail_parsing(self, payload, match):
        with pytest.raises(spec.SpecError, match=match):
            spec.WorkloadSpec.from_dict(payload)


class TestExperimentSpec:
    def test_registry_round_trip_exact(self):
        for name in spec.experiment_names():
            e = spec.experiment(name)
            assert spec.ExperimentSpec.from_json(e.to_json()) == e
            assert spec.ExperimentSpec.from_dict(
                json.loads(json.dumps(e.to_dict()))) == e

    def test_unknown_experiment_name_lists_registry(self):
        with pytest.raises(spec.SpecError, match="replay_hot_skew"):
            spec.experiment("nonexistent")

    def test_unknown_experiment_version(self):
        d = spec.experiment("poisson").to_dict()
        d["experiment_version"] = 99
        with pytest.raises(spec.SpecError, match="experiment_version"):
            spec.ExperimentSpec.from_dict(d)

    def test_missing_blocks_rejected(self):
        with pytest.raises(spec.SpecError, match="policy"):
            spec.ExperimentSpec.from_dict({"repeats": 1})

    def test_wrong_typed_run_parameters(self):
        d = spec.experiment("poisson").to_dict()
        d["drain_budget"] = "10"
        with pytest.raises(spec.SpecError, match="drain_budget"):
            spec.ExperimentSpec.from_dict(d)
        d = spec.experiment("poisson").to_dict()
        d["repeats"] = 1.5
        with pytest.raises(spec.SpecError, match="repeats"):
            spec.ExperimentSpec.from_dict(d)

    def test_domain_mismatch_rejected(self):
        with pytest.raises(spec.SpecError, match="domains"):
            spec.ExperimentSpec(
                policy=spec.named("paper_cyclic"),          # 4 domains
                workload=spec.WorkloadSpec(num_domains=2))

    def test_nested_errors_name_the_block(self):
        d = spec.experiment("poisson").to_dict()
        d["policy"]["governor"]["ema"] = "0.5"
        with pytest.raises(spec.SpecError,
                           match=r"experiment.policy.governor.ema"):
            spec.ExperimentSpec.from_dict(d)


class TestExperimentGoldenFiles:
    """specs/experiments/<name>.json pins every registry experiment."""

    @pytest.mark.parametrize("name", spec.experiment_names())
    def test_golden_file_matches_registry(self, name):
        path = os.path.join(EXPERIMENTS_DIR, f"{name}.json")
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        assert text == spec.experiment(name).to_json(), (
            f"{path} is stale: regenerate with "
            f"spec.dump_experiment(spec.experiment({name!r}), {path!r})")

    def test_no_orphan_golden_files(self):
        on_disk = {f[:-5] for f in os.listdir(EXPERIMENTS_DIR)
                   if f.endswith(".json")}
        assert on_disk == set(spec.experiment_names())


class TestPropertyRoundTrip:
    def test_randomized_experiments_round_trip_exactly(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        seeds = st.integers(min_value=0, max_value=2**31 - 1)
        pos = st.floats(min_value=0.05, max_value=64.0, allow_nan=False)
        fracs = st.floats(min_value=0.01, max_value=1.0, allow_nan=False)
        skews = st.one_of(st.none(), st.builds(
            spec.SkewSpec, hot_domain=st.just(0), p_hot=fracs, seed=seeds))
        costs = st.one_of(st.none(), st.builds(
            spec.CostsSpec, median=pos,
            sigma=st.floats(min_value=0.0, max_value=2.0, allow_nan=False),
            seed=seeds))
        workloads = st.builds(
            spec.WorkloadSpec, kind=st.sampled_from(spec.WorkloadSpec.KINDS),
            num_domains=st.integers(1, 8), steps=st.integers(1, 64),
            seed=seeds, rate=pos, rate_quiet=pos, rate_storm=pos,
            p_enter=fracs, p_exit=fracs,
            trough_frac=st.floats(min_value=0.0, max_value=1.0,
                                  allow_nan=False),
            periods=pos, cost=pos, n_tasks=st.integers(1, 2000),
            skew=skews, costs=costs)
        states = st.one_of(st.none(), st.builds(
            spec.GovernorStateSpec, penalty_estimate=pos, task_cost=pos,
            observed_local=st.integers(0, 10**6),
            observed_steals=st.integers(0, 10**6)))

        @st.composite
        def experiments(draw):
            wl = draw(workloads)
            policy = dataclasses.replace(
                spec.named(draw(st.sampled_from(
                    ("paper_cyclic", "adaptive_theta", "controlled_replay",
                     "measured_theta")))),
                num_domains=wl.num_domains, seed=draw(seeds))
            state = draw(states)
            if state is not None and policy.governor.kind in ("adaptive",
                                                              "measured"):
                policy = dataclasses.replace(
                    policy, governor=dataclasses.replace(policy.governor,
                                                         state=state))
            return spec.ExperimentSpec(
                policy=policy, workload=wl, repeats=draw(st.integers(1, 4)),
                drain_budget=draw(st.one_of(st.none(),
                                            st.integers(1, 10**5))))

        @settings(max_examples=50, deadline=None)
        @given(exp=experiments())
        def check(exp):
            assert spec.ExperimentSpec.from_json(exp.to_json()) == exp

        check()


class TestExperimentRun:
    def test_run_executes_declared_workload_and_names_itself(self):
        exp = _small(spec.experiment("replay_poisson"))
        res = exp.run()
        run = res.primary
        assert run.stats["executed"] == res.workload.n_tasks
        t = trace.loads_lines(trace.dumps_lines(run.trace))
        assert spec.RuntimeSpec.from_dict(t.spec_dict) == exp.policy
        assert spec.ExperimentSpec.from_dict(t.experiment_dict) == exp

    def test_repeats_shift_the_policy_seed(self):
        exp = dataclasses.replace(_small(spec.experiment("poisson")),
                                  repeats=3)
        res = exp.run()
        assert [r.seed for r in res.runs] == [0, 1, 2]
        for r, run in enumerate(res.runs):
            embedded = spec.RuntimeSpec.from_dict(run.trace.spec_dict)
            assert embedded.seed == exp.policy.seed + r
            trace.replay(run.trace, assert_match=True)

    def test_drain_budget_guards_undrainable_runs(self):
        # no stealing + a hot domain: the backlog drains one task per round,
        # far beyond a 1-round budget
        exp = dataclasses.replace(_small(spec.experiment("hot_skew")),
                                  policy=spec.named("static_local"),
                                  drain_budget=1)
        with pytest.raises(RuntimeError, match="drain_budget"):
            exp.run()
        # a generous budget is bit-identical to the unbounded default
        free = dataclasses.replace(exp, drain_budget=None).run()
        capped = dataclasses.replace(exp, drain_budget=10_000).run()
        assert free.primary.stats == capped.primary.stats

    def test_validate_experiment_gate(self):
        from repro.spec.validate import validate_experiment

        stats = validate_experiment(_small(spec.experiment("replay_bursty")))
        assert stats["executed"] > 0


class TestGovernorStateCheckpoint:
    """Governor *state* snapshots: the learned θ inputs serialize into the
    spec, so a mid-run checkpoint rebuilds the exact estimator without
    re-reading a trace."""

    def _measured_run(self):
        exp = dataclasses.replace(
            _small(spec.experiment("hot_skew"), steps=16),
            policy=dataclasses.replace(spec.named("measured_theta")))
        return exp.run().primary.executor

    def test_checkpoint_rebuilds_exact_estimator(self):
        ex = self._measured_run()
        ck = spec.checkpoint(ex)
        assert spec.RuntimeSpec.from_json(ck.to_json()) == ck
        rebuilt = ck.build().executor.governor
        live = ex.governor
        assert rebuilt.penalty_estimate == live.penalty_estimate
        assert rebuilt.task_cost == live.task_cost
        assert rebuilt.threshold == live.threshold
        assert rebuilt.observed_local == live.observed_local
        assert rebuilt.observed_steals == live.observed_steals

    def test_state_supersedes_priors_not_hyperparameters(self):
        g = spec.GovernorSpec(kind="adaptive", penalty_hint=4.0, ema=0.5,
                              state=spec.GovernorStateSpec(
                                  penalty_estimate=9.0, task_cost=3.0))
        gov = spec.build_governor(g)
        assert gov.penalty_estimate == 9.0
        assert gov.task_cost == 3.0
        assert gov.ema == 0.5
        assert gov.threshold == 3                 # 9 / 3

    def test_state_matches_from_trace_seeding(self):
        """The declarative path equals ``MeasuredPenalty.from_trace``:
        snapshot the trace-seeded governor once, rebuild from spec data."""
        t = _small(spec.experiment("replay_hot_skew")).run().primary.trace
        seeded = trace.MeasuredPenalty.from_trace(t)
        g = spec.GovernorSpec(
            kind="measured",
            state=spec.GovernorStateSpec.from_governor(seeded))
        rebuilt = spec.build_governor(g)
        assert rebuilt.penalty_estimate == seeded.penalty_estimate
        assert rebuilt.task_cost == seeded.task_cost
        assert rebuilt.threshold == seeded.threshold
        assert rebuilt.observed_steals == seeded.observed_steals

    def test_breaker_wrapped_governor_unwraps(self):
        policy = dataclasses.replace(
            spec.named("measured_spill"))             # adaptive + breaker
        exp = spec.ExperimentSpec(
            policy=policy,
            workload=spec.standard_workloads(steps=12)["hot_skew"])
        res = exp.run()
        built = res.primary.built
        state = spec.GovernorStateSpec.from_governor(
            built.executor.governor)
        assert state.penalty_estimate == \
            built.executor.governor.inner.penalty_estimate
        # the control plane exports the same state (its checkpoint surface)
        assert built.control.governor_state() == state
        ck = spec.checkpoint(built.executor)
        assert ck.governor.state == state

    def test_stateless_governors_refuse_snapshot(self):
        ex = spec.named("paper_cyclic").build().executor
        with pytest.raises(spec.SpecError, match="learned"):
            spec.checkpoint(ex)
        with pytest.raises(spec.SpecError, match="governor.state"):
            spec.GovernorSpec(kind="greedy",
                              state=spec.GovernorStateSpec())


class TestTraceBackCompat:
    """The experiment path inherits both trace back-compat contracts:
    v1 traces keep the explicit-executor replay contract, and rotating
    segment directories read transparently."""

    V1_POLICY = spec.RuntimeSpec(
        num_domains=3, seed=7,
        penalty=spec.PenaltySpec(kind="constant", value=2.0))

    def test_v1_single_file_replays_under_declarative_policy(self):
        t = trace.TraceReader(V1_FIXTURE).read()
        assert t.spec_dict is None and t.experiment_dict is None
        res = trace.replay(t, lambda tr: self.V1_POLICY.build().executor,
                           assert_match=True)
        assert res.matches_recorded

    def test_v1_segmented_fixture_reads_and_replays(self):
        t = trace.TraceReader(V1_SEGMENTS).read()
        assert t.spec_dict is None
        assert t.n_tasks == 26 and t.total_steps == 10
        # the recorded workload is itself declarable: same arrival stream
        wl = spec.WorkloadSpec(kind="poisson", num_domains=3, steps=10,
                               seed=7, rate=3.0).build()
        assert sorted((s.step, s.home) for s in t.submissions) == \
            sorted((a.step, a.home) for a in wl.arrivals)
        res = trace.replay(t, lambda tr: self.V1_POLICY.build().executor,
                           assert_match=True)
        assert res.matches_recorded
        # without the (unserialized, v1) penalty the meta fallback diverges
        assert "steal_penalty" in trace.replay(t).mismatches()

    def test_experiment_streams_rotating_segments(self, tmp_path):
        policy = dataclasses.replace(
            spec.named("replay_baseline"),
            trace=spec.TraceSpec(record=True, segment_records=8))
        exp = dataclasses.replace(
            _small(spec.experiment("replay_bursty")), policy=policy,
            repeats=2)
        exp.run(trace_path=tmp_path)
        for r in range(2):
            seg_dir = tmp_path / f"run-{r}"
            assert len(list(seg_dir.glob("segment-*.jsonl"))) > 1
            t = trace.TraceReader(seg_dir).read()
            assert t.experiment_dict is not None
            res = trace.replay(t, assert_match=True)
            assert res.matches_recorded


class TestBenchmarkCli:
    def test_unknown_policy_lists_registry_names(self):
        from benchmarks.run import _cli_spec

        with pytest.raises(SystemExit, match="paper_cyclic"):
            _cli_spec(["--policy", "nonexistent"])

    def test_unreadable_spec_file_is_a_clean_exit(self):
        from benchmarks.run import _cli_spec

        with pytest.raises(SystemExit, match="no/such"):
            _cli_spec(["--spec", "no/such/policy.json"])

    def test_unknown_experiment_lists_registry_names(self):
        from benchmarks.run import _cli_experiments

        with pytest.raises(SystemExit, match="replay_hot_skew"):
            _cli_experiments(["--experiment", "nonexistent"])

    def test_experiment_resolution_name_and_file(self):
        from benchmarks.run import _cli_experiments

        by_name = _cli_experiments(["--experiment", "poisson"])
        assert by_name == ({"poisson": spec.experiment("poisson")}, False)
        path = os.path.join(EXPERIMENTS_DIR, "poisson.json")
        assert _cli_experiments(["--experiment", path]) == by_name
        assert _cli_experiments([]) is None
        # only the full set may refresh the committed BENCH artifact
        experiments, full_set = _cli_experiments(["--experiment", "all"])
        assert full_set and set(experiments) == set(spec.experiment_names())

    def test_run_experiments_reports_replay_conformance(self, tmp_path):
        from benchmarks.run import run_experiments

        exp = _small(spec.experiment("replay_poisson"))
        json_path = tmp_path / "BENCH_experiments.json"
        lines = run_experiments({"replay_poisson_small": exp},
                                json_path=str(json_path))
        assert lines[0].startswith("experiment,repeat,")
        row = lines[1].split(",")
        assert row[0] == "replay_poisson_small" and row[-1] == "1"
        data = json.loads(json_path.read_text())
        run = data["results"]["replay_poisson_small"]["runs"][0]
        assert run["replay_exact"] is True
        assert data["results"]["replay_poisson_small"]["experiment"] == \
            exp.to_dict()


class TestAggregates:
    """``aggregate_runs``: the Fig. 4 variability ladder over seed-shifted
    repeats (and the sentinel's tolerance input)."""

    def test_exact_moments_over_literal_stats(self):
        agg = spec.aggregate_runs([{"x": 1.0, "y": 4}, {"x": 3.0, "y": 4}])
        assert agg["x"] == {"mean": 2.0, "min": 1.0, "max": 3.0,
                            "stdev": 1.0}
        assert agg["y"]["stdev"] == 0.0

    def test_bools_and_unshared_keys_excluded(self):
        agg = spec.aggregate_runs([{"ok": True, "x": 1, "only_a": 2},
                                   {"ok": False, "x": 2}])
        assert set(agg) == {"x"}

    def test_single_run_is_degenerate_but_defined(self):
        agg = spec.aggregate_runs([{"x": 5.0}])
        assert agg["x"] == {"mean": 5.0, "min": 5.0, "max": 5.0, "stdev": 0.0}
        assert spec.aggregate_runs([]) == {}

    def test_experiment_result_aggregates_runs(self):
        exp = dataclasses.replace(
            _small(spec.experiment("variability_hot_skew")), repeats=3)
        res = exp.run()
        agg = res.aggregates()
        assert agg == spec.aggregate_runs([r.stats for r in res.runs])
        for key, stats in agg.items():
            assert stats["min"] <= stats["mean"] <= stats["max"], key
            assert stats["stdev"] >= 0.0

    def test_run_experiments_emits_aggregates(self, tmp_path):
        from benchmarks.run import run_experiments

        exp = dataclasses.replace(
            _small(spec.experiment("variability_hot_skew")), repeats=2)
        json_path = tmp_path / "BENCH_experiments.json"
        run_experiments({"variability_small": exp},
                        json_path=str(json_path))
        data = json.loads(json_path.read_text())
        agg = data["results"]["variability_small"]["aggregates"]
        assert agg and all(set(v) == set(spec.AGGREGATE_STATS)
                           for v in agg.values())
