"""repro.spec: round-trips, golden files, strictness, build equivalence."""
import dataclasses
import json
import os

import pytest

from repro import spec, trace
from repro.control import BatchGovernor, ControlLoop, CostRouter, StormBreaker
from repro.runtime import (AdaptiveSteal, Executor, GreedySteal, NoSteal,
                           Task, Worker)

SPECS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "specs")


def _workload(num_domains=4, steps=24, seed=5):
    return trace.lognormal_costs(
        trace.hot_skew(trace.poisson(rate=num_domains, steps=steps,
                                     num_domains=num_domains, seed=seed),
                       hot_domain=0, p_hot=0.8, seed=seed),
        median=2.0, sigma=0.75, seed=seed)


class TestRoundTrip:
    @pytest.mark.parametrize("name", spec.policy_names())
    def test_registry_json_round_trip_exact(self, name):
        s = spec.named(name)
        assert spec.RuntimeSpec.from_json(s.to_json()) == s
        # and through a dict round-trip (what trace headers embed)
        assert spec.RuntimeSpec.from_dict(
            json.loads(json.dumps(s.to_dict()))) == s

    def test_worker_domains_tuple_normalization(self):
        s = spec.RuntimeSpec(num_domains=2, worker_domains=[0, 0, 1])
        assert s.worker_domains == (0, 0, 1)
        assert spec.RuntimeSpec.from_json(s.to_json()) == s

    def test_canonical_json_is_stable(self):
        s = spec.named("controlled_replay")
        assert s.to_json() == spec.RuntimeSpec.from_json(s.to_json()).to_json()


class TestGoldenFiles:
    """specs/<name>.json pins the canonical JSON of every registry policy."""

    @pytest.mark.parametrize("name", spec.policy_names())
    def test_golden_file_matches_registry(self, name):
        path = os.path.join(SPECS_DIR, f"{name}.json")
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        assert text == spec.named(name).to_json(), (
            f"{path} is stale: regenerate with "
            f"spec.dump(spec.named({name!r}), {path!r})")

    def test_no_orphan_golden_files(self):
        on_disk = {f[:-5] for f in os.listdir(SPECS_DIR)
                   if f.endswith(".json")}
        assert on_disk == set(spec.policy_names())


class TestStrictness:
    def test_unknown_top_level_field(self):
        d = spec.named("paper_cyclic").to_dict()
        d["pool_capp"] = 7
        with pytest.raises(spec.SpecError, match="pool_capp"):
            spec.RuntimeSpec.from_dict(d)

    def test_unknown_nested_field(self):
        d = spec.named("controlled_replay").to_dict()
        d["governor"]["breaker"]["widht"] = 4
        with pytest.raises(spec.SpecError, match="widht"):
            spec.RuntimeSpec.from_dict(d)

    def test_unknown_spec_version(self):
        d = spec.named("paper_cyclic").to_dict()
        d["spec_version"] = 99
        with pytest.raises(spec.SpecError, match="spec_version"):
            spec.RuntimeSpec.from_dict(d)

    def test_invalid_json_text(self):
        with pytest.raises(spec.SpecError, match="JSON"):
            spec.RuntimeSpec.from_json("{not json")

    @pytest.mark.parametrize("payload,match", [
        ({"governor": {"ema": "0.5"}}, "governor.ema"),
        ({"governor": {"penalty_hint": "4.0"}}, "governor.penalty_hint"),
        ({"governor": {"breaker": {"width": 2.5}}}, "governor.breaker.width"),
        ({"batch": {"size": "8"}}, "batch.size"),
        ({"record_events": "yes"}, "record_events"),
        ({"steal_order": 3}, "steal_order"),
        ({"pool_cap": 2.5}, "pool_cap"),
        ({"worker_domains": [0, "1"]}, "worker_domains"),
        ({"serving": {"policy": 7}}, "serving.policy"),
    ])
    def test_wrong_typed_scalars_fail_parsing(self, payload, match):
        """Strictness covers value *types*, not just field names: a
        wrong-typed scalar must raise SpecError at parse time, never leak
        a TypeError or survive into a built system."""
        with pytest.raises(spec.SpecError, match=match):
            spec.RuntimeSpec.from_dict(payload)

    def test_int_widens_to_float_but_not_vice_versa(self):
        s = spec.RuntimeSpec.from_dict(
            {"penalty": {"kind": "constant", "value": 6}})
        assert s.penalty.value == 6.0 and isinstance(s.penalty.value, float)
        with pytest.raises(spec.SpecError, match="event_maxlen"):
            spec.RuntimeSpec.from_dict({"event_maxlen": 6.5})

    @pytest.mark.parametrize("make,match", [
        (lambda: spec.RuntimeSpec(num_domains=0), "num_domains"),
        (lambda: spec.RuntimeSpec(pool_cap=0), "pool_cap"),
        (lambda: spec.RuntimeSpec(worker_domains=(0, 5)), "worker domain"),
        (lambda: spec.GovernorSpec(kind="psychic"), "governor.kind"),
        (lambda: spec.RouterSpec(kind="warp"), "router.kind"),
        (lambda: spec.RouterSpec(spill="vibes"), "router.spill"),
        (lambda: spec.BatchSpec(kind="vibe"), "batch.kind"),
        (lambda: spec.PenaltySpec(kind="free_lunch"), "penalty.kind"),
        (lambda: spec.ServingSpec(policy="chaos"), "serving.policy"),
    ])
    def test_bad_values_rejected(self, make, match):
        with pytest.raises(spec.SpecError, match=match):
            make()

    def test_bad_steal_order_rejected_at_build(self):
        with pytest.raises(ValueError, match="steal order"):
            spec.RuntimeSpec(steal_order="sideways").build()

    def test_unknown_policy_name(self):
        with pytest.raises(spec.SpecError, match="nonexistent"):
            spec.named("nonexistent")

    def test_streaming_trace_needs_path(self):
        s = spec.RuntimeSpec(trace=spec.TraceSpec(record=True,
                                                  segment_records=8))
        with pytest.raises(spec.SpecError, match="trace_path"):
            s.build()


class TestBuildEquivalence:
    """Spec-built and hand-built systems are bit-identical under load."""

    def _drive(self, ex):
        wl = _workload()
        trace.drive(ex, wl)
        return ex.metrics.snapshot()

    def test_paper_cyclic_matches_hand_built(self):
        s = spec.named("paper_cyclic")
        hand = Executor(4, steal_order="cyclic", governor=GreedySteal(),
                        steal_penalty=lambda t, w: 4.0, seed=0)
        assert self._drive(s.build().executor) == self._drive(hand)

    def test_controlled_replay_matches_hand_built(self):
        s = spec.named("controlled_replay")
        loop = ControlLoop.full(spill_penalty=6.0, width=8)
        hand = loop.attach(Executor(4, steal_order="cost_weighted",
                                    governor=GreedySteal(),
                                    steal_penalty=lambda t, w: 6.0, seed=0))
        assert self._drive(s.build().executor) == self._drive(hand)

    def test_round_robin_router_matches_explicit_routing(self):
        s = spec.named("tasking_round_robin")
        hand = Executor(4, steal_order="cyclic", governor=GreedySteal(),
                        steal_penalty=lambda t, w: 4.0, seed=0)
        wl = _workload()
        by_step = wl.by_step()
        for t in range(wl.horizon):
            for a in by_step.get(t, ()):
                hand.submit(hand.make_task(home=a.home, cost=a.cost),
                            domain=hand.next_round_robin())
            hand.step()
        hand.run_until_drained()
        assert self._drive(s.build().executor) == hand.metrics.snapshot()

    def test_governor_kinds_build_expected_types(self):
        from repro.trace import MeasuredPenalty

        assert isinstance(spec.build_governor(
            spec.GovernorSpec(kind="greedy")), GreedySteal)
        assert isinstance(spec.build_governor(
            spec.GovernorSpec(kind="none")), NoSteal)
        g = spec.build_governor(spec.GovernorSpec(kind="adaptive",
                                                  penalty_hint=9.0))
        assert type(g) is AdaptiveSteal and g.penalty_estimate == 9.0
        assert isinstance(spec.build_governor(
            spec.GovernorSpec(kind="measured")), MeasuredPenalty)

    def test_penalty_kinds(self):
        w = Worker(wid=0, domain=0)
        homed = Task(uid=0, home=1, cost=3.0)
        homeless = Task(uid=1, home=-1, cost=3.0)
        assert spec.build_penalty(spec.PenaltySpec()) is None
        const = spec.build_penalty(spec.PenaltySpec("constant", 5.0))
        assert const(homed, w) == const(homeless, w) == 5.0
        factor = spec.build_penalty(spec.PenaltySpec("cost_factor", 2.0))
        assert factor(homed, w) == 6.0
        if_homed = spec.build_penalty(spec.PenaltySpec("cost_if_homed", 2.0))
        assert if_homed(homed, w) == 6.0 and if_homed(homeless, w) == 0.0

    def test_built_wiring(self):
        built = spec.named("measured_spill").build()
        ex = built.executor
        assert isinstance(ex.governor, StormBreaker)
        assert isinstance(ex.governor.inner, AdaptiveSteal)
        assert isinstance(ex.batch, BatchGovernor)
        assert isinstance(built.control.router, CostRouter)
        assert built.control.router.measured
        assert ex.spec == spec.named("measured_spill")

    def test_overrides_clear_embedded_spec(self):
        s = spec.named("paper_cyclic")
        assert s.build().executor.spec == s
        assert s.build(governor=NoSteal()).executor.spec is None
        assert s.build(steal_penalty=lambda t, w: 1.0).executor.spec is None


class TestSpecReplayAcceptance:
    def test_replay_without_executor_for_every_policy(self):
        """Acceptance: for every registry policy, a recorded run replays
        bit-identically from the v2 trace header alone."""
        for name in spec.policy_names():
            s = spec.named(name)
            built = s.build()
            rec = built.recorder
            if rec is None:
                rec = trace.TraceRecorder()
                rec.attach(built.executor)
            trace.drive(built.executor, _workload(s.num_domains))
            t = trace.loads_lines(trace.dumps_lines(rec.finish()))
            res = trace.replay(t, assert_match=True)
            assert res.matches_recorded, name

    def test_validate_specs_dir_passes(self):
        from repro.spec.validate import iter_spec_files, main

        assert len(iter_spec_files([SPECS_DIR])) == len(spec.policy_names())
        assert main([SPECS_DIR]) == 0

    def test_replay_does_not_reattach_recording(self):
        """Header-only replay rebuilds the scheduler, never the recorded
        run's own recorder (a replay is analysis, not another recording)."""
        s = spec.RuntimeSpec(num_domains=2,
                             trace=spec.TraceSpec(record=True))
        built = s.build()
        trace.drive(built.executor, _workload(2, steps=8))
        t = trace.loads_lines(trace.dumps_lines(built.recorder.finish()))
        res = trace.replay(t, assert_match=True)
        assert res.executor.submit_hook is None

    def test_streamed_segment_trace_replays_from_header(self, tmp_path):
        """A spec that streams rotating segments still yields a trace whose
        header alone reconstructs the run (no trace_path needed at replay)."""
        s = spec.RuntimeSpec(
            num_domains=2,
            penalty=spec.PenaltySpec(kind="constant", value=3.0),
            trace=spec.TraceSpec(record=True, segment_records=16))
        built = s.build(trace_path=tmp_path / "segments")
        trace.drive(built.executor, _workload(2, steps=8))
        built.recorder.finish()
        t = trace.TraceReader(tmp_path / "segments").read()
        res = trace.replay(t, assert_match=True)
        assert res.matches_recorded

    def test_run_with_spec_rejects_domain_mismatch(self):
        from benchmarks.run import run_with_spec

        with pytest.raises(SystemExit, match="num_domains=2"):
            run_with_spec(spec.named("controlled_serving"))

    def test_stencil_sweep_rejects_spec_recording(self):
        pytest.importorskip("jax")
        import numpy as np
        from repro.stencil.jacobi import run_runtime_sweep

        f = np.zeros((20, 4, 4), dtype=np.float32)
        bad = spec.RuntimeSpec(num_domains=4,
                               trace=spec.TraceSpec(record=True))
        with pytest.raises(spec.SpecError, match="trace="):
            run_runtime_sweep(f, di=5, spec=bad)


class TestServingSpec:
    def test_engine_requires_serving_block(self):
        with pytest.raises(spec.SpecError, match="serving"):
            spec.named("paper_cyclic").build_engine(None, None)

    def test_engine_rejects_domain_mismatch(self):
        bad = dataclasses.replace(spec.named("controlled_serving"),
                                  num_domains=3)
        with pytest.raises(spec.SpecError, match="num_domains"):
            bad.build_engine(None, None)

    def test_engine_rejects_router_bypassed_by_policy(self):
        # round_robin/single_queue submit with explicit domains, so a
        # declared router would silently never run — must be rejected.
        s = spec.named("controlled_serving")      # router.kind == "cost"
        bad = dataclasses.replace(
            s, serving=dataclasses.replace(s.serving, policy="round_robin"))
        with pytest.raises(spec.SpecError, match="bypass"):
            bad.build_engine(None, None)

    def test_engine_rejects_conflicting_kwargs(self):
        s = spec.named("controlled_serving")
        with pytest.raises(spec.SpecError, match="batch"):
            s.build_engine(None, None, batch=4)
        # every spec-superseded raw kwarg is rejected, not silently ignored
        with pytest.raises(spec.SpecError, match="num_replicas"):
            s.build_engine(None, None, num_replicas=4)
        with pytest.raises(spec.SpecError, match="max_seq"):
            s.build_engine(None, None, max_seq=256)
        with pytest.raises(spec.SpecError, match="policy"):
            s.build_engine(None, None, policy="round_robin")
        with pytest.raises(spec.SpecError, match="pool_cap"):
            s.build_engine(None, None, pool_cap=16)

    def test_spec_built_engine_schedule_matches_raw(self):
        """The spec path wires the same executor the raw kwargs did: same
        routing/steal schedule on the same submission stream (handlers are
        irrelevant to the schedule, so no model is needed — submit plain
        tasks straight to the inner executor)."""
        def drive_exec(ex):
            for i in range(24):
                home = 0 if i % 4 else 1
                ex.submit(ex.make_task(home=home, cost=float(4 + i % 5)))
                ex.step()
            ex.run_until_drained()
            return ex.metrics.snapshot()

        base = dataclasses.replace(
            spec.named("controlled_serving"),
            governor=spec.GovernorSpec(kind="greedy"),
            router=spec.RouterSpec(kind="none"), batch=spec.BatchSpec())
        raw = Executor(2, [0, 1], steal_order="longest",
                       steal_penalty=spec.build_penalty(base.penalty),
                       pool_cap=256, seed=0)
        assert drive_exec(base.build().executor) == drive_exec(raw)
