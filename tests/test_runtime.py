"""Tests for the online runtime: queue primitives, the event log, and the
executor."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import LocalityQueues
from repro.runtime import (AdaptiveSteal, DomainQueues, Event, EventLog,
                           Executor, NoSteal, ReferenceEventLog,
                           SubmissionPool)


class TestLocalityQueuesEdgeCases:
    def test_steal_scan_wraparound_order(self):
        # caller in LD 2 of 4; work only in LDs 0 and 3.  The cyclic scan
        # starts right after the local domain: 3 -> 0 -> 1, so LD 3 is hit
        # first even though LD 0 was filled first.
        q = LocalityQueues(4)
        q.enqueue(10, 0)
        q.enqueue(30, 3)
        blk, stolen = q.dequeue(2)
        assert (blk, stolen) == (30, True)
        blk, stolen = q.dequeue(2)
        assert (blk, stolen) == (10, True)

    def test_steal_scan_wraps_past_zero(self):
        # caller in LD 1 of 3; scan order is 2 -> 0 (wraps past the end)
        q = LocalityQueues(3)
        q.enqueue(7, 0)
        assert q.dequeue(1) == (7, True)

    def test_dequeue_all_empty(self):
        q = LocalityQueues(3)
        for ld in range(3):
            assert q.dequeue(ld) is None
        assert len(q) == 0
        # drained queues behave the same as never-filled ones
        q.enqueue(1, 0)
        assert q.dequeue(0) == (1, False)
        assert q.dequeue(0) is None
        assert len(q) == 0

    def test_local_pop_preferred_and_fifo(self):
        q = LocalityQueues(2)
        for blk in (1, 2, 3):
            q.enqueue(blk, 1)
        q.enqueue(9, 0)
        assert q.dequeue(1) == (1, False)       # FIFO within the LD
        assert q.dequeue(1) == (2, False)
        assert q.dequeue(0) == (9, False)       # local wins while nonempty
        assert q.dequeue(0) == (3, True)

    def test_sizes_consistent_under_interleaving(self):
        rng = np.random.default_rng(42)
        q = LocalityQueues(4)
        live = 0
        for step in range(500):
            if rng.random() < 0.55:
                q.enqueue(step, int(rng.integers(4)))
                live += 1
            else:
                got = q.dequeue(int(rng.integers(4)))
                if got is not None:
                    live -= 1
                else:
                    assert live == 0
            sizes = q.queue_sizes()
            assert sum(sizes) == len(q) == live
            assert all(s >= 0 for s in sizes)


class TestDomainQueues:
    def test_longest_steal_order_with_tie_break(self):
        q = DomainQueues(4, steal_order="longest")
        q.enqueue("a", 1)
        q.enqueue("b", 3)
        q.enqueue("c", 3)
        got = q.dequeue(0)
        assert (got.item, got.domain, got.stolen) == ("b", 3, True)
        # now 1 and 3 are tied at depth 1: lowest domain id wins
        got = q.dequeue(0)
        assert (got.item, got.domain) == ("a", 1)

    def test_min_victim_threshold(self):
        q = DomainQueues(2)
        q.enqueue("x", 1)
        assert q.dequeue(0, min_victim=2) is None       # too shallow to rob
        assert len(q) == 1
        q.enqueue("y", 1)
        got = q.dequeue(0, min_victim=2)
        assert got.item == "x" and got.stolen

    def test_allow_steal_false(self):
        q = DomainQueues(2)
        q.enqueue("x", 1)
        assert q.dequeue(0, allow_steal=False) is None
        assert q.dequeue(1).stolen is False

    def test_random_steal_needs_rng(self):
        with pytest.raises(ValueError):
            DomainQueues(2, steal_order="random")


class TestSubmissionPool:
    def test_fifo_and_cap_accounting(self):
        p = SubmissionPool(cap=3)
        for i in range(3):
            p.push(i)
        assert p.full and p.free_slots == 0
        assert p.pop() == 0
        assert not p.full and p.free_slots == 1
        assert [p.pop(), p.pop(), p.pop()] == [1, 2, None]


class TestEventLog:
    def test_ring_overflow_counts_vs_window(self):
        # counts() covers the whole run even after the ring buffer drops
        # the oldest events; len() is only the retained window.
        log = EventLog(maxlen=8)
        for i in range(20):
            log.emit(step=i, kind="run", worker=0, domain=0, task_uid=i)
        assert log.counts() == {"run": 20}
        assert log.total == 20
        assert len(log) == 8
        assert log.dropped == 12
        # the window keeps the *newest* events
        assert [e.task_uid for e in log] == list(range(12, 20))

    def test_csv_export_carries_window_marker(self):
        log = EventLog(maxlen=4)
        for i in range(6):
            log.emit(step=i, kind="run", worker=0, domain=0, task_uid=i,
                     cost=2.0)
        lines = log.to_csv_lines()
        assert lines[0].startswith("#")
        assert "total=6" in lines[0] and "retained=4" in lines[0] \
            and "dropped=2" in lines[0]
        assert lines[1].split(",")[:2] == ["step", "kind"]
        assert len(lines) == 2 + 4               # marker + header + window
        assert lines[2].endswith(",2,0")         # cost,penalty columns

    def test_steal_event_src_domain_is_victim_queue(self):
        # worker 1 (domain 1) can only steal from domain 0's queue; the
        # steal event must point at the victim, not the thief's domain.
        ex = Executor(num_domains=2)
        for i in range(4):
            ex.submit(ex.make_task(payload=i, home=0))
        ex.run_until_drained()
        steals = [e for e in ex.events if e.kind == "steal"]
        assert steals and all(e.src_domain == 0 for e in steals)
        assert all(e.domain == 1 and e.worker == 1 for e in steals)
        runs = [e for e in ex.events if e.kind == "run"]
        assert all(e.src_domain == e.domain for e in runs)

    def test_execution_events_carry_cost_and_penalty(self):
        ex = Executor(num_domains=2,
                      steal_penalty=lambda task, worker: 2.0 * task.cost)
        for i in range(4):
            ex.submit(ex.make_task(payload=i, home=0, cost=3.0))
        ex.run_until_drained()
        for e in ex.events:
            if e.kind == "steal":
                assert (e.cost, e.penalty, e.service) == (3.0, 6.0, 9.0)
            elif e.kind == "run":
                assert (e.cost, e.penalty) == (3.0, 0.0)


def _submit_n(ex, n, homes):
    for i in range(n):
        ex.submit(ex.make_task(payload=i, home=int(homes[i % len(homes)])))


class TestExecutor:
    def test_deterministic_per_seed(self):
        def run(seed):
            ex = Executor(num_domains=3, steal_order="random", seed=seed)
            _submit_n(ex, 30, [0, 0, 0, 1, 2])
            ex.run_until_drained()
            return ([(e.kind, e.worker, e.task_uid, e.src_domain)
                     for e in ex.events], ex.metrics.snapshot())
        assert run(7) == run(7)
        assert run(1) == run(1)

    def test_local_steal_stats_under_skew(self):
        # everything homed on domain 0 of 2: worker 1 can only steal
        ex = Executor(num_domains=2)
        _submit_n(ex, 10, [0])
        results = ex.run_until_drained()
        s = ex.stats
        assert len(results) == 10 and s.executed == 10
        assert s.stolen > 0 and s.local > 0
        assert s.local + s.stolen == 10          # nothing is both or neither
        assert ex.pool[1].stats.stolen == s.stolen
        assert abs(s.local_fraction + s.steal_fraction - 1.0) < 1e-9

    def test_all_local_when_balanced(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 10, [0, 1])
        ex.run_until_drained()
        assert ex.stats.local == 10 and ex.stats.stolen == 0

    def test_homeless_tasks_round_robin_and_never_local(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 8, [-1])
        ex.run_until_drained()
        s = ex.stats
        assert s.executed == 8
        assert s.local == 0                      # home -1 matches no domain
        assert s.stolen == 0                     # round-robin spread evenly

    def test_backpressure_bounds_pool_depth(self):
        ex = Executor(num_domains=2, pool_cap=8)
        _submit_n(ex, 100, [0, 1, 0, 0])         # skew so steals happen too
        ex.run_until_drained()
        s = ex.stats
        assert s.executed == 100
        assert s.max_pool_depth <= 8
        assert s.inline_runs > 0                 # the submitter had to help

    def test_steal_penalty_accounting(self):
        ex = Executor(num_domains=2,
                      steal_penalty=lambda task, worker: task.cost)
        for i in range(6):
            ex.submit(ex.make_task(payload=i, home=0, cost=3.0))
        ex.run_until_drained()
        s = ex.stats
        assert s.steal_penalty == pytest.approx(3.0 * s.stolen)

    def test_results_in_completion_order_and_cleared(self):
        ex = Executor(num_domains=2,
                      handler=lambda task, worker: (task.payload, worker.wid))
        _submit_n(ex, 6, [0, 1])
        out = ex.run_until_drained()
        assert sorted(p for p, _ in out) == list(range(6))
        assert ex.run_until_drained() == []      # drained and cleared

    def test_adaptive_steals_fewer_than_greedy(self):
        def drive(governor):
            ex = Executor(num_domains=2, governor=governor,
                          steal_penalty=lambda t, w: 6.0)
            uid = 0
            for _ in range(20):                  # online: 2 arrivals per round
                for _ in range(2):
                    ex.submit(ex.make_task(payload=uid, home=0))
                    uid += 1
                ex.step()
            ex.run_until_drained()
            return ex.stats
        greedy = drive(None)
        adaptive = drive(AdaptiveSteal(penalty_hint=6.0))
        assert greedy.executed == adaptive.executed == 40
        assert adaptive.stolen < greedy.stolen
        assert adaptive.steal_penalty < greedy.steal_penalty

    def test_no_steal_governor_still_drains(self):
        ex = Executor(num_domains=2, governor=NoSteal())
        _submit_n(ex, 12, [0, 1, 0])
        ex.run_until_drained()
        assert ex.stats.executed == 12 and ex.stats.stolen == 0
        assert ex.stats.local == 12

    def test_event_log_counts_match_stats(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 9, [0, 0, 1])
        ex.run_until_drained()
        counts = ex.events.counts()
        s = ex.stats
        assert counts["submit"] == s.submitted == 9
        assert counts.get("steal", 0) == s.stolen
        assert counts.get("run", 0) + counts.get("steal", 0) \
            + counts.get("inline", 0) == s.executed


class TestRuntimeJacobiPath:
    def test_runtime_sweep_matches_ref_any_policy(self):
        jnp = pytest.importorskip("jax.numpy")  # noqa: F841 (jax-backed ref)
        from repro.kernels.jacobi.ref import jacobi_sweep_ref
        from repro.stencil.jacobi import run_runtime_sweep

        rng = np.random.default_rng(3)
        f = rng.standard_normal((40, 8, 8)).astype(np.float32)
        ref = np.asarray(jacobi_sweep_ref(f))
        for gov, order in ((None, "cyclic"), (NoSteal(), "cyclic"),
                           (AdaptiveSteal(), "longest")):
            out, stats = run_runtime_sweep(f, di=5, num_domains=4,
                                           workers_per_domain=2, governor=gov,
                                           steal_order=order)
            assert np.array_equal(out, ref)
            assert stats.executed == 8


# -- queued-cost snapshot accounting and the fast/slow contract --------------

class _MutableTask:
    """Task stand-in whose ``cost`` can be rewritten while queued."""

    def __init__(self, uid: int, cost: float = 1.0):
        self.uid = uid
        self.cost = cost


class TestQueuedCostSnapshot:
    def test_mutating_queued_cost_cannot_drift_account(self):
        # regression: the pre-fix dequeue subtracted the task's *live* cost,
        # so repricing a queued task (MeasuredPenalty-style) drifted the
        # account — and a re-zero-on-empty mask hid the drift whenever the
        # queue happened to drain.  The snapshot accounting needs no mask:
        # the account returns to exactly 0.0 by construction.
        q = DomainQueues(2)
        t = _MutableTask(0, cost=2.5)
        q.enqueue(t, 0)
        t.cost = 1000.0                      # repriced while queued
        assert q.queue_costs() == [2.5, 0.0]  # account holds the snapshot
        got = q.dequeue(0)
        assert got.item is t and not got.stolen
        assert q.queue_costs() == [0.0, 0.0]  # exact zero, no drift residue

    def test_drift_free_even_when_queue_never_drains(self):
        # the old re-zero mask only fired on empty queues; with a second
        # task still queued the drift was permanent.  Snapshots make the
        # remaining account exactly the remaining snapshot.
        q = DomainQueues(1)
        a, b = _MutableTask(0, cost=3.0), _MutableTask(1, cost=4.0)
        q.enqueue(a, 0)
        q.enqueue(b, 0)
        a.cost = 99.0
        q.dequeue(0, False)
        assert q.queue_costs() == [4.0]
        assert q.cost(0) == 4.0

    def test_drain_budget_uses_snapshots(self):
        q = DomainQueues(1)
        tasks = [_MutableTask(uid, cost=c)
                 for uid, c in enumerate((1.0, 1.0, 5.0))]
        for t in tasks:
            q.enqueue(t, 0)
        tasks[2].cost = 0.0             # reprice the expensive tail task
        got = q.dequeue(0, False)
        assert got.item.uid == 0
        # budget consults the enqueue-time snapshot (5.0), not the live 0.0
        rest = q.drain(0, 2, budget=2.0, spent=1.0)
        assert [t.uid for t in rest] == [1]


class TestConstructionValidation:
    @pytest.mark.parametrize("bad", [0, -1, None])
    def test_event_log_rejects_degenerate_maxlen(self, bad):
        with pytest.raises(ValueError, match="maxlen"):
            EventLog(maxlen=bad)
        with pytest.raises(ValueError, match="maxlen"):
            ReferenceEventLog(maxlen=bad)

    @pytest.mark.parametrize("bad", [0, -3, None])
    def test_submission_pool_rejects_degenerate_cap(self, bad):
        with pytest.raises(ValueError, match="cap"):
            SubmissionPool(cap=bad)

    def test_minimal_valid_sizes_accepted(self):
        assert EventLog(maxlen=1).maxlen == 1
        assert SubmissionPool(cap=1).cap == 1


class TestOverflowWarningAttribution:
    @pytest.mark.parametrize("log_cls", [EventLog, ReferenceEventLog])
    def test_overflow_warning_points_at_emit_caller(self, log_cls):
        # stacklevel=2: the warning is attributed to emit's direct caller
        # (Executor._emit in executor-driven logs; this test here), not to
        # events.py internals and not to a frame above the caller.
        log = log_cls(maxlen=2)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            for i in range(3):
                log.emit(i, "run", 0, 0, i)
        overflow = [w for w in caught
                    if issubclass(w.category, RuntimeWarning)]
        assert len(overflow) == 1          # one-shot
        assert overflow[0].filename == __file__
        assert "overflow" in str(overflow[0].message)


class TestColumnarEventLogEquivalence:
    def _emit_mixed(self, log, n=40):
        for i in range(n):
            kind = ("submit", "run", "steal", "idle", "probe")[i % 5]
            log.emit(step=i // 4, kind=kind, worker=i % 3, domain=i % 2,
                     task_uid=i, src_domain=i % 2 - 1, cost=0.5 * i,
                     penalty=float(i % 2))

    def test_matches_reference_log_through_overflow(self):
        fast, ref = EventLog(maxlen=16), ReferenceEventLog(maxlen=16)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            self._emit_mixed(fast)
            self._emit_mixed(ref)
        assert list(fast) == list(ref)
        assert fast.counts() == ref.counts()
        assert (fast.total, fast.dropped) == (ref.total, ref.dropped)
        assert fast.tail(5) == ref.tail(5)
        assert fast.to_csv_lines() == ref.to_csv_lines()

    def test_columns_export_matches_events_and_types(self):
        log = EventLog(maxlen=16)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            self._emit_mixed(log)
        cols = log.columns()
        names = log.kind_names()
        events = list(log)
        assert all(len(v) == len(events) for v in cols.values())
        assert cols["kind"].dtype == np.uint8
        assert cols["step"].dtype == np.int64
        assert cols["cost"].dtype == np.float64
        rebuilt = [Event(step=int(s), kind=names[k], worker=int(w),
                         domain=int(d), task_uid=int(u), src_domain=int(sd),
                         cost=float(c), penalty=float(p))
                   for s, k, w, d, u, sd, c, p in zip(
                       cols["step"], cols["kind"], cols["worker"],
                       cols["domain"], cols["task_uid"], cols["src_domain"],
                       cols["cost"], cols["penalty"])]
        assert rebuilt == events

    def test_empty_log_exports_empty_columns(self):
        cols = EventLog(maxlen=4).columns()
        assert all(len(v) == 0 for v in cols.values())


# -- randomized fast/slow equivalence (always-on seeded + hypothesis) --------

def _run_equivalence_trial(seed: int, topo=None):
    """One randomized interleaving driven through ``fast=True`` and
    ``fast=False`` queues in lockstep: every Popped, the queue sizes, the
    cost accounts (held to the exact shadow snapshot sum), and the RNG
    state must stay identical — including under mid-queue cost mutation."""
    import random

    r = random.Random(seed)
    nd = topo.num_domains if topo is not None else r.choice([1, 2, 3, 4, 8])
    order = r.choice(DomainQueues.STEAL_ORDERS)
    rngs = [np.random.default_rng(seed) for _ in range(2)]
    pair = [DomainQueues(nd, steal_order=order, rng=g, topology=topo,
                         fast=f) for g, f in zip(rngs, (True, False))]
    shadow = [0.0] * nd          # exact replay of the account arithmetic
    snaps = {}                   # uid -> enqueue-time cost snapshot
    live = []
    uid = 0
    for step in range(r.randint(40, 160)):
        op = r.random()
        if op < 0.45:
            d = r.randrange(nd)
            t = _MutableTask(uid, cost=r.choice([0.5, 1.0, 2.0, 3.5]))
            uid += 1
            live.append(t)
            snaps[t.uid] = t.cost
            shadow[d] += t.cost
            for q in pair:
                q.enqueue(t, d)
        elif op < 0.55 and live:
            r.choice(live).cost = r.choice([0.0, 7.7, 1e6])
        else:
            d = r.randrange(nd)
            mv = r.choice([1, 1, 2, 3, None])
            allow = r.random() > 0.1
            outs = [q.dequeue(d, allow) if mv is None
                    else q.dequeue(d, allow, mv) for q in pair]
            a, b = outs
            ta = None if a is None else (a.item.uid, a.domain, a.stolen,
                                         a.level, a.distance)
            tb = None if b is None else (b.item.uid, b.domain, b.stolen,
                                         b.level, b.distance)
            assert ta == tb, (seed, step, ta, tb)
            if a is not None:
                # subtract the enqueue-time snapshot, as the account does —
                # never the (possibly mutated) live cost
                shadow[a.domain] -= snaps[a.item.uid]
        assert pair[0].queue_sizes() == pair[1].queue_sizes(), (seed, step)
        assert pair[0].queue_costs() == pair[1].queue_costs(), (seed, step)
        assert pair[0].queue_costs() == shadow, (seed, step)
        s0, s1 = (g.bit_generator.state for g in rngs)
        assert s0 == s1, (seed, step, "rng draw sequences diverged")


class TestFastSlowEquivalenceRandomized:
    """Always-on seeded sweep of the fast/slow bit-identity contract (the
    hypothesis property below explores further when hypothesis is
    installed; this fallback keeps the contract gated everywhere)."""

    def test_flat_topologies(self):
        for seed in range(60):
            _run_equivalence_trial(seed)

    def test_hierarchical_topologies(self):
        import random

        from repro.topology import grouped
        for seed in range(60):
            r = random.Random(10_000 + seed)
            topo = grouped(r.choice([[2, 2], [4, 4], [2, 2, 2, 2],
                                     [4, 2], [2, 3, 3]]))
            _run_equivalence_trial(10_000 + seed, topo=topo)

    def test_executor_level_equivalence_all_policies(self):
        # whole-executor check: identical stats, event streams, and results
        # across fast/slow for every steal order (events compared through
        # the columnar vs reference log CSV, so this also pins the logs)
        for order in DomainQueues.STEAL_ORDERS:
            snaps = {}
            for fast in (True, False):
                ex = Executor(4, steal_order=order, seed=7, fast=fast,
                              steal_penalty=lambda t, w: 4.0)
                rng = np.random.default_rng(42)
                for i in range(200):
                    home = int(rng.integers(-1, 4))
                    ex.submit(ex.make_task(home=home,
                                           cost=float(rng.choice(
                                               [0.5, 1.0, 2.0]))))
                    if i % 3 == 0:
                        ex.step()
                ex.run_until_drained()
                snaps[fast] = (dataclasses.asdict(ex.stats),
                               ex.events.counts(),
                               tuple(ex.events.to_csv_lines()))
            assert snaps[True] == snaps[False], order


class TestFastSlowEquivalenceHypothesis:
    """Property form of the contract, for machines with hypothesis."""

    def test_property_interleavings(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hyp.given(seed=st.integers(min_value=0, max_value=2**32 - 1),
                   hier=st.booleans())
        @hyp.settings(max_examples=200, deadline=None)
        def prop(seed, hier):
            if hier:
                import random

                from repro.topology import grouped
                r = random.Random(seed)
                topo = grouped(r.choice([[2, 2], [4, 4], [2, 2, 2, 2]]))
                _run_equivalence_trial(seed, topo=topo)
            else:
                _run_equivalence_trial(seed)

        prop()
