"""Tests for the online runtime: queue primitives, the event log, and the
executor."""
import numpy as np
import pytest

from repro.core import LocalityQueues
from repro.runtime import (AdaptiveSteal, DomainQueues, EventLog, Executor,
                           NoSteal, SubmissionPool)


class TestLocalityQueuesEdgeCases:
    def test_steal_scan_wraparound_order(self):
        # caller in LD 2 of 4; work only in LDs 0 and 3.  The cyclic scan
        # starts right after the local domain: 3 -> 0 -> 1, so LD 3 is hit
        # first even though LD 0 was filled first.
        q = LocalityQueues(4)
        q.enqueue(10, 0)
        q.enqueue(30, 3)
        blk, stolen = q.dequeue(2)
        assert (blk, stolen) == (30, True)
        blk, stolen = q.dequeue(2)
        assert (blk, stolen) == (10, True)

    def test_steal_scan_wraps_past_zero(self):
        # caller in LD 1 of 3; scan order is 2 -> 0 (wraps past the end)
        q = LocalityQueues(3)
        q.enqueue(7, 0)
        assert q.dequeue(1) == (7, True)

    def test_dequeue_all_empty(self):
        q = LocalityQueues(3)
        for ld in range(3):
            assert q.dequeue(ld) is None
        assert len(q) == 0
        # drained queues behave the same as never-filled ones
        q.enqueue(1, 0)
        assert q.dequeue(0) == (1, False)
        assert q.dequeue(0) is None
        assert len(q) == 0

    def test_local_pop_preferred_and_fifo(self):
        q = LocalityQueues(2)
        for blk in (1, 2, 3):
            q.enqueue(blk, 1)
        q.enqueue(9, 0)
        assert q.dequeue(1) == (1, False)       # FIFO within the LD
        assert q.dequeue(1) == (2, False)
        assert q.dequeue(0) == (9, False)       # local wins while nonempty
        assert q.dequeue(0) == (3, True)

    def test_sizes_consistent_under_interleaving(self):
        rng = np.random.default_rng(42)
        q = LocalityQueues(4)
        live = 0
        for step in range(500):
            if rng.random() < 0.55:
                q.enqueue(step, int(rng.integers(4)))
                live += 1
            else:
                got = q.dequeue(int(rng.integers(4)))
                if got is not None:
                    live -= 1
                else:
                    assert live == 0
            sizes = q.queue_sizes()
            assert sum(sizes) == len(q) == live
            assert all(s >= 0 for s in sizes)


class TestDomainQueues:
    def test_longest_steal_order_with_tie_break(self):
        q = DomainQueues(4, steal_order="longest")
        q.enqueue("a", 1)
        q.enqueue("b", 3)
        q.enqueue("c", 3)
        got = q.dequeue(0)
        assert (got.item, got.domain, got.stolen) == ("b", 3, True)
        # now 1 and 3 are tied at depth 1: lowest domain id wins
        got = q.dequeue(0)
        assert (got.item, got.domain) == ("a", 1)

    def test_min_victim_threshold(self):
        q = DomainQueues(2)
        q.enqueue("x", 1)
        assert q.dequeue(0, min_victim=2) is None       # too shallow to rob
        assert len(q) == 1
        q.enqueue("y", 1)
        got = q.dequeue(0, min_victim=2)
        assert got.item == "x" and got.stolen

    def test_allow_steal_false(self):
        q = DomainQueues(2)
        q.enqueue("x", 1)
        assert q.dequeue(0, allow_steal=False) is None
        assert q.dequeue(1).stolen is False

    def test_random_steal_needs_rng(self):
        with pytest.raises(ValueError):
            DomainQueues(2, steal_order="random")


class TestSubmissionPool:
    def test_fifo_and_cap_accounting(self):
        p = SubmissionPool(cap=3)
        for i in range(3):
            p.push(i)
        assert p.full and p.free_slots == 0
        assert p.pop() == 0
        assert not p.full and p.free_slots == 1
        assert [p.pop(), p.pop(), p.pop()] == [1, 2, None]


class TestEventLog:
    def test_ring_overflow_counts_vs_window(self):
        # counts() covers the whole run even after the ring buffer drops
        # the oldest events; len() is only the retained window.
        log = EventLog(maxlen=8)
        for i in range(20):
            log.emit(step=i, kind="run", worker=0, domain=0, task_uid=i)
        assert log.counts() == {"run": 20}
        assert log.total == 20
        assert len(log) == 8
        assert log.dropped == 12
        # the window keeps the *newest* events
        assert [e.task_uid for e in log] == list(range(12, 20))

    def test_csv_export_carries_window_marker(self):
        log = EventLog(maxlen=4)
        for i in range(6):
            log.emit(step=i, kind="run", worker=0, domain=0, task_uid=i,
                     cost=2.0)
        lines = log.to_csv_lines()
        assert lines[0].startswith("#")
        assert "total=6" in lines[0] and "retained=4" in lines[0] \
            and "dropped=2" in lines[0]
        assert lines[1].split(",")[:2] == ["step", "kind"]
        assert len(lines) == 2 + 4               # marker + header + window
        assert lines[2].endswith(",2,0")         # cost,penalty columns

    def test_steal_event_src_domain_is_victim_queue(self):
        # worker 1 (domain 1) can only steal from domain 0's queue; the
        # steal event must point at the victim, not the thief's domain.
        ex = Executor(num_domains=2)
        for i in range(4):
            ex.submit(ex.make_task(payload=i, home=0))
        ex.run_until_drained()
        steals = [e for e in ex.events if e.kind == "steal"]
        assert steals and all(e.src_domain == 0 for e in steals)
        assert all(e.domain == 1 and e.worker == 1 for e in steals)
        runs = [e for e in ex.events if e.kind == "run"]
        assert all(e.src_domain == e.domain for e in runs)

    def test_execution_events_carry_cost_and_penalty(self):
        ex = Executor(num_domains=2,
                      steal_penalty=lambda task, worker: 2.0 * task.cost)
        for i in range(4):
            ex.submit(ex.make_task(payload=i, home=0, cost=3.0))
        ex.run_until_drained()
        for e in ex.events:
            if e.kind == "steal":
                assert (e.cost, e.penalty, e.service) == (3.0, 6.0, 9.0)
            elif e.kind == "run":
                assert (e.cost, e.penalty) == (3.0, 0.0)


def _submit_n(ex, n, homes):
    for i in range(n):
        ex.submit(ex.make_task(payload=i, home=int(homes[i % len(homes)])))


class TestExecutor:
    def test_deterministic_per_seed(self):
        def run(seed):
            ex = Executor(num_domains=3, steal_order="random", seed=seed)
            _submit_n(ex, 30, [0, 0, 0, 1, 2])
            ex.run_until_drained()
            return ([(e.kind, e.worker, e.task_uid, e.src_domain)
                     for e in ex.events], ex.metrics.snapshot())
        assert run(7) == run(7)
        assert run(1) == run(1)

    def test_local_steal_stats_under_skew(self):
        # everything homed on domain 0 of 2: worker 1 can only steal
        ex = Executor(num_domains=2)
        _submit_n(ex, 10, [0])
        results = ex.run_until_drained()
        s = ex.stats
        assert len(results) == 10 and s.executed == 10
        assert s.stolen > 0 and s.local > 0
        assert s.local + s.stolen == 10          # nothing is both or neither
        assert ex.pool[1].stats.stolen == s.stolen
        assert abs(s.local_fraction + s.steal_fraction - 1.0) < 1e-9

    def test_all_local_when_balanced(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 10, [0, 1])
        ex.run_until_drained()
        assert ex.stats.local == 10 and ex.stats.stolen == 0

    def test_homeless_tasks_round_robin_and_never_local(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 8, [-1])
        ex.run_until_drained()
        s = ex.stats
        assert s.executed == 8
        assert s.local == 0                      # home -1 matches no domain
        assert s.stolen == 0                     # round-robin spread evenly

    def test_backpressure_bounds_pool_depth(self):
        ex = Executor(num_domains=2, pool_cap=8)
        _submit_n(ex, 100, [0, 1, 0, 0])         # skew so steals happen too
        ex.run_until_drained()
        s = ex.stats
        assert s.executed == 100
        assert s.max_pool_depth <= 8
        assert s.inline_runs > 0                 # the submitter had to help

    def test_steal_penalty_accounting(self):
        ex = Executor(num_domains=2,
                      steal_penalty=lambda task, worker: task.cost)
        for i in range(6):
            ex.submit(ex.make_task(payload=i, home=0, cost=3.0))
        ex.run_until_drained()
        s = ex.stats
        assert s.steal_penalty == pytest.approx(3.0 * s.stolen)

    def test_results_in_completion_order_and_cleared(self):
        ex = Executor(num_domains=2,
                      handler=lambda task, worker: (task.payload, worker.wid))
        _submit_n(ex, 6, [0, 1])
        out = ex.run_until_drained()
        assert sorted(p for p, _ in out) == list(range(6))
        assert ex.run_until_drained() == []      # drained and cleared

    def test_adaptive_steals_fewer_than_greedy(self):
        def drive(governor):
            ex = Executor(num_domains=2, governor=governor,
                          steal_penalty=lambda t, w: 6.0)
            uid = 0
            for _ in range(20):                  # online: 2 arrivals per round
                for _ in range(2):
                    ex.submit(ex.make_task(payload=uid, home=0))
                    uid += 1
                ex.step()
            ex.run_until_drained()
            return ex.stats
        greedy = drive(None)
        adaptive = drive(AdaptiveSteal(penalty_hint=6.0))
        assert greedy.executed == adaptive.executed == 40
        assert adaptive.stolen < greedy.stolen
        assert adaptive.steal_penalty < greedy.steal_penalty

    def test_no_steal_governor_still_drains(self):
        ex = Executor(num_domains=2, governor=NoSteal())
        _submit_n(ex, 12, [0, 1, 0])
        ex.run_until_drained()
        assert ex.stats.executed == 12 and ex.stats.stolen == 0
        assert ex.stats.local == 12

    def test_event_log_counts_match_stats(self):
        ex = Executor(num_domains=2)
        _submit_n(ex, 9, [0, 0, 1])
        ex.run_until_drained()
        counts = ex.events.counts()
        s = ex.stats
        assert counts["submit"] == s.submitted == 9
        assert counts.get("steal", 0) == s.stolen
        assert counts.get("run", 0) + counts.get("steal", 0) \
            + counts.get("inline", 0) == s.executed


class TestRuntimeJacobiPath:
    def test_runtime_sweep_matches_ref_any_policy(self):
        jnp = pytest.importorskip("jax.numpy")  # noqa: F841 (jax-backed ref)
        from repro.kernels.jacobi.ref import jacobi_sweep_ref
        from repro.stencil.jacobi import run_runtime_sweep

        rng = np.random.default_rng(3)
        f = rng.standard_normal((40, 8, 8)).astype(np.float32)
        ref = np.asarray(jacobi_sweep_ref(f))
        for gov, order in ((None, "cyclic"), (NoSteal(), "cyclic"),
                           (AdaptiveSteal(), "longest")):
            out, stats = run_runtime_sweep(f, di=5, num_domains=4,
                                           workers_per_domain=2, governor=gov,
                                           steal_order=order)
            assert np.array_equal(out, ref)
            assert stats.executed == 8
