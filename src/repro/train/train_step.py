"""Train/prefill/decode step builders used by launch, dryrun and tests.

The train step supports gradient accumulation (``cfg.microbatches``): the
global batch is reshaped device-major so the microbatch split is a local
view, and a lax.scan accumulates fp32 gradients sharded like the params.
Gradients flow in the param dtype (bf16 for the full archs), which halves
cross-device gradient-reduction traffic vs fp32 — the "compressed
all-reduce" distributed-optimization trick in its SPMD-native form;
accumulation happens in fp32.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from ..models.model import Model
from .optimizer import AdamWConfig, adamw_update

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _split_microbatches(batch: dict, k: int) -> dict:
    """(B, ...) -> (k, B/k, ...) keeping the data-parallel sharding local:
    reshape device-major (dp, k, ...) then move k in front."""
    def split(x):
        b = x.shape[0]
        xs = x.reshape(b // k, k, *x.shape[1:])
        xs = jnp.swapaxes(xs, 0, 1)
        return xs
    return jax.tree.map(split, batch)


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    cfg = model.cfg
    opt_cfg = opt_cfg or AdamWConfig()
    pdtype = _DTYPES[cfg.dtype]
    k = max(cfg.microbatches, 1)

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    def grads_of(params, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads

        mbs = _split_microbatches(batch, k)

        def body(acc, mb):
            mb = jax.tree.map(
                lambda x: shard(x, "batch", *([None] * (x.ndim - 1))), mb)
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / k, acc_g, grads)
            return (acc_g, acc_l + loss / k), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), ms = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), mbs)
        metrics = jax.tree.map(lambda m: m[-1], ms)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, param_dtype=pdtype)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, caches):
        return model.prefill(params, batch, caches)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, pos, caches):
        return model.decode_step(params, tokens, pos, caches)
    return decode_step
