"""AdamW with fp32 master weights, built from scratch (no optax).

State layout per parameter leaf: fp32 master copy + fp32 first/second
moments.  For ZeRO-1-style sharding the optimizer state gets an extra
"zero" logical axis (mapped to the data mesh axis) on the first shardable
dimension — for scanned stacks that is the layer-stack axis, which spreads
the optimizer memory of replicated (TP-only) weights across data-parallel
peers; XLA inserts the reduce-scatter/all-gather pair this implies.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(c: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_frac + (1 - c.min_lr_frac) * cos)


def init_opt_state(params: Params) -> dict:
    # copy=True: with float32 params, astype would alias the param buffer and
    # break donation (same buffer donated twice in the jitted step)
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(c: AdamWConfig, grads: Params, opt_state: dict,
                 param_dtype=jnp.bfloat16):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(c, step.astype(jnp.float32))

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + c.eps) + c.weight_decay * master
        master = master - lr * delta
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w: w.astype(param_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_shardings(param_shardings_tree, rules, param_tree):
    """Shardings for opt state: like params, plus the "zero" axis on the
    first still-unsharded, divisible dimension (ZeRO-1)."""
    data_size = 1
    zero_axis = rules.rules.get("zero")
    if zero_axis is not None:
        data_size = rules.mesh.shape[zero_axis]

    def zero_shard(sharding, leaf):
        spec = list(sharding.spec) + [None] * (len(leaf.shape) - len(sharding.spec))
        used = {a for s in spec if s is not None
                for a in ((s,) if isinstance(s, str) else s)}
        if zero_axis is not None and zero_axis not in used:
            for i, s in enumerate(spec):
                if s is None and leaf.shape[i] % data_size == 0 and data_size > 1:
                    spec[i] = zero_axis
                    break
        from jax.sharding import NamedSharding, PartitionSpec
        return NamedSharding(rules.mesh, PartitionSpec(*spec))

    like_params = jax.tree.map(zero_shard, param_shardings_tree, param_tree)
    from jax.sharding import NamedSharding, PartitionSpec
    return {
        "master": like_params,
        "m": like_params,
        "v": like_params,
        "step": NamedSharding(rules.mesh, PartitionSpec()),
    }
