"""Training loop: data + step + checkpointing + fault handling.

The loop is deliberately host-driven and restartable: all state lives in
(params, opt_state, step); the data pipeline is deterministic given the
step counter; `run()` resumes from the newest checkpoint if one exists, so
a SIGKILL at any point loses at most `checkpoint_every` steps — the
crash-recovery test kills and resumes mid-run and checks bit-identical
continuation against an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..distributed.fault import StragglerMonitor
from ..models.model import Model
from .optimizer import AdamWConfig, init_opt_state
from .train_step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, model: Model, data: Iterator[dict[str, np.ndarray]],
                 loop_cfg: LoopConfig, opt_cfg: Optional[AdamWConfig] = None,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.data = data
        self.cfg = loop_cfg
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=loop_cfg.total_steps)
        self.log = log_fn
        self.step_fn = jax.jit(make_train_step(model, self.opt_cfg),
                               donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(loop_cfg.checkpoint_dir,
                                       keep=loop_cfg.keep_checkpoints)
                     if loop_cfg.checkpoint_dir else None)
        self.monitor = StragglerMonitor(num_domains=1)

    def init_state(self, seed: int = 0):
        params = self.model.init_params(jax.random.key(seed))
        opt_state = init_opt_state(params)
        return params, opt_state

    def run(self, seed: int = 0) -> dict[str, Any]:
        params, opt_state = self.init_state(seed)
        start = 0
        if self.ckpt is not None:
            latest, restored = self.ckpt.restore_latest(
                {"params": params, "opt": opt_state})
            if latest is not None:
                params, opt_state = restored["params"], restored["opt"]
                start = latest
                self.log(f"[resume] restored checkpoint at step {latest}")

        # deterministic data replay: skip batches consumed before the crash
        it = iter(self.data)
        for _ in range(start):
            next(it)

        losses = []
        for step in range(start, self.cfg.total_steps):
            batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
            t0 = time.time()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            self.monitor.update([time.time() - t0])
            if (step + 1) % self.cfg.log_every == 0:
                self.log(f"[step {step+1:5d}] loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"lr={float(metrics['lr']):.2e} "
                         f"({time.time()-t0:.2f}s/step)")
            if self.ckpt is not None and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if self.ckpt is not None:
            self.ckpt.save(self.cfg.total_steps,
                           {"params": params, "opt": opt_state}, blocking=True)
        return {"params": params, "opt_state": opt_state, "losses": losses}
