"""Locality-constrained static schedule builder for SPMD execution.

This is the paper's locality-queue idea moved to where a TPU system can use
it: XLA's SPMD model fixes the work→device assignment at compile/launch time,
so the "static part between domains / dynamic part within" split (paper §4)
becomes an ahead-of-time assignment problem:

  * start from pure locality: every task goes to its home domain's list
    (= the locality queue);
  * while the load imbalance exceeds a bound, move tasks from the most
    loaded to the least loaded domain (= bounded work stealing), choosing
    the cheapest-to-move tasks first — load balance is given priority over
    strict locality, exactly the paper's §2.2 policy.

The resulting per-domain lists drive: stencil block→device assignment,
host-side data-pipeline shard reading, the serving router's replica lists,
and the elastic re-mesh path (a device loss is just a re-assignment with one
fewer domain).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class Assignment:
    """Per-domain ordered task lists plus quality metrics."""

    lists: list[list[int]]
    loads: np.ndarray            # per-domain total cost
    locality_fraction: float     # fraction of total cost kept in home domain
    imbalance: float             # max_load / mean_load - 1
    moved: int                   # number of tasks stolen from their home

    @property
    def num_domains(self) -> int:
        return len(self.lists)


def build_assignment(home: np.ndarray, cost: np.ndarray, num_domains: int,
                     max_imbalance: float = 0.02,
                     remote_penalty: float = 0.0) -> Assignment:
    """Assign tasks to domains: locality first, bounded stealing for balance.

    Args:
      home: (n,) home domain per task (-1 = no affinity, assign freely).
      cost: (n,) per-task cost (e.g. bytes or FLOPs).
      num_domains: number of locality domains (devices/pods/hosts).
      max_imbalance: stop stealing once max/mean - 1 <= this bound.
      remote_penalty: multiplier added to a task's cost when it executes
        away from home (models the nonlocal-access slowdown); stealing
        accounts for it when picking which task to move.

    Returns an Assignment; every task appears in exactly one list.
    """
    n = len(home)
    home = np.asarray(home, dtype=np.int64)
    cost = np.asarray(cost, dtype=np.float64)
    if len(cost) != n:
        raise ValueError("home and cost must have the same length")
    if (home >= num_domains).any():
        raise ValueError("home domain out of range")

    lists: list[list[int]] = [[] for _ in range(num_domains)]
    loads = np.zeros(num_domains)

    # 1. locality placement (+ greedy least-loaded for unaffiliated tasks)
    free = np.flatnonzero(home < 0)
    for t in np.flatnonzero(home >= 0):
        lists[home[t]].append(int(t))
        loads[home[t]] += cost[t]
    if len(free):
        # largest-first onto least-loaded domain (LPT)
        order = free[np.argsort(-cost[free])]
        heap = [(loads[d], d) for d in range(num_domains)]
        heapq.heapify(heap)
        for t in order:
            load, d = heapq.heappop(heap)
            lists[d].append(int(t))
            loads[d] += cost[t]
            heapq.heappush(heap, (loads[d], d))

    total = float(cost.sum())
    mean = total / num_domains if num_domains else 0.0
    moved = 0

    # 2. bounded stealing: move smallest tasks from max- to min-loaded domain.
    #    Moving small tasks first keeps the locality loss per unit of balance
    #    gained minimal (the steal's remote_penalty is charged to the thief).
    if total > 0:
        # per-domain heaps of (cost, task) for cheap-to-move selection
        heaps = [[(cost[t], t) for t in lst] for lst in lists]
        for h in heaps:
            heapq.heapify(h)
        guard = 0
        while True:
            guard += 1
            if guard > 10 * n + 100:
                break
            src = int(np.argmax(loads))
            dst = int(np.argmin(loads))
            if loads[src] <= mean * (1 + max_imbalance) or src == dst:
                break
            if not heaps[src]:
                break
            c, t = heapq.heappop(heaps[src])
            # don't overshoot: stealing must reduce the max load
            eff = c * (1 + remote_penalty)
            if loads[dst] + eff >= loads[src]:
                heapq.heappush(heaps[src], (c, t))
                break
            lists[src].remove(t)
            lists[dst].append(t)
            loads[src] -= c
            loads[dst] += eff
            heapq.heappush(heaps[dst], (eff, t))
            moved += 1

    kept = sum(cost[t] for d, lst in enumerate(lists) for t in lst
               if home[t] == d or home[t] < 0)
    return Assignment(
        lists=lists,
        loads=loads,
        locality_fraction=min(float(kept / total), 1.0) if total > 0 else 1.0,
        imbalance=float(loads.max() / mean - 1.0) if mean > 0 else 0.0,
        moved=moved,
    )


def round_robin_assignment(n_tasks: int, cost: np.ndarray,
                           num_domains: int) -> Assignment:
    """Locality-oblivious baseline (the paper's dynamic-scheduling stand-in
    for SPMD): task i -> domain i mod D."""
    home = np.arange(n_tasks) % num_domains
    lists = [[int(t) for t in np.flatnonzero(home == d)]
             for d in range(num_domains)]
    loads = np.array([sum(cost[t] for t in lst) for lst in lists])
    mean = loads.mean() if num_domains else 0.0
    return Assignment(lists=lists, loads=loads, locality_fraction=0.0,
                      imbalance=float(loads.max() / mean - 1.0) if mean > 0 else 0.0,
                      moved=0)
