"""Discrete-event execution simulator for the paper's benchmark runs.

Simulates a team of pinned (or unpinned) threads executing the blocked Jacobi
sweep under a scheduling ``Policy`` on a ccNUMA ``MachineTopology``.  Memory
is the only resource that matters (the solver is strictly memory-bound,
paper §1.4); running blocks are fluid flows whose rates are the max-min fair
allocation of ``cost_model.maxmin_rates``, re-evaluated whenever the flow set
changes.

OpenMP tasking semantics (paper §2.1) are modelled faithfully:
  * a single submitter thread feeds a bounded task pool (default cap 256 —
    "the limit is set to roughly 256 tasks with the compiler used");
  * when the pool is full the submitter executes one task itself, then
    resumes submitting ("the submitting thread is used for processing tasks
    for some time");
  * after the last submission the submitter joins the consumers.

Per-task dispatch/steal/submit overheads carry multiplicative jitter, which
is the source of the (small) run-to-run variability of Fig. 4.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .cost_model import maxmin_rates
from .scheduler import Policy
from .tasks import BlockGrid, block_bytes
from .topology import MachineTopology

IDLE, SUBMIT, OVERHEAD, RUN, DONE = range(5)


@dataclasses.dataclass
class SimParams:
    dispatch_overhead_us: float = 1.0   # consumer per-task dispatch cost
    submit_overhead_us: float = 0.5     # submitter per-task cost
    steal_overhead_us: float = 0.5      # extra scan cost on a steal
    jitter_frac: float = 0.03           # multiplicative noise on overheads
    pool_cap: int = 256                 # OpenMP queued-task limit


@dataclasses.dataclass
class SimResult:
    makespan_s: float
    mlups: float                 # mega lattice-site updates per second
    local_fraction: float        # fraction of blocks executed in home LD
    steal_fraction: float        # fraction of blocks obtained by stealing
    policy: str
    topology: str

    @property
    def glups(self) -> float:
        return self.mlups / 1e3


def simulate(grid: BlockGrid, topo: MachineTopology, policy: Policy,
             homes: np.ndarray, params: SimParams | None = None,
             seed: int = 0, pinned: bool = True) -> SimResult:
    params = params or SimParams()
    rng = np.random.default_rng(seed)
    nthreads = topo.num_cores
    nblocks = grid.num_blocks

    if pinned:
        thread_ld = np.array(topo.ld_id_map())
    else:
        # unpinned threads wander; model as a random core assignment that the
        # policy cannot see coming (paper: "n-p" TBB runs).
        thread_ld = np.array(topo.ld_id_map())[rng.permutation(nthreads)]

    policy.reset(grid, homes, topo, thread_ld, rng)

    bpb = float(block_bytes(grid, topo.nt_stores))

    def jit(us: float) -> float:
        return max(us * (1.0 + params.jitter_frac * rng.standard_normal()), 0.01) * 1e-6

    state = np.full(nthreads, IDLE, dtype=np.int64)
    ready = np.zeros(nthreads)          # wake time for SUBMIT/OVERHEAD states
    cur = np.full(nthreads, -1, dtype=np.int64)   # block being dispatched/run
    rem = np.zeros(nthreads)            # remaining bytes for RUN flows
    rate = np.zeros(nthreads)           # bytes/s for RUN flows

    submitter = 0 if policy.uses_submitter else -1
    if submitter >= 0:
        state[submitter] = SUBMIT
        ready[submitter] = 0.0

    t = 0.0
    executed = 0
    local_count = 0
    steal_count = 0
    rates_dirty = False

    def try_dispatch(th: int) -> bool:
        """Idle/finished thread asks the policy for work."""
        nonlocal steal_count
        got = policy.pop(th)
        if got is None:
            state[th] = IDLE
            return False
        cur[th] = got.block
        ov = jit(params.dispatch_overhead_us)
        if got.stolen:
            steal_count += 1
            ov += jit(params.steal_overhead_us)
        state[th] = OVERHEAD
        ready[th] = t + ov
        return True

    def wake_idle() -> None:
        for th in range(nthreads):
            if state[th] == IDLE:
                try_dispatch(th)

    def recompute_rates() -> None:
        running = np.flatnonzero(state == RUN)
        if len(running) == 0:
            return
        h = np.array([homes[cur[th]] for th in running])
        r = maxmin_rates(h, thread_ld[running], topo)
        rate[running] = r * 1e9

    # prime: non-submitter policies have everything available at t=0
    wake_idle()
    recompute_rates()

    guard = 0
    while executed < nblocks:
        guard += 1
        if guard > 40 * nblocks + 10000:
            raise RuntimeError("simulator failed to converge (livelock?)")

        # --- next event time ------------------------------------------------
        tnext = np.inf
        for th in range(nthreads):
            if state[th] in (SUBMIT, OVERHEAD):
                tnext = min(tnext, ready[th])
            elif state[th] == RUN and rate[th] > 0:
                tnext = min(tnext, t + rem[th] / rate[th])
        if not np.isfinite(tnext):
            raise RuntimeError(
                "deadlock: no runnable thread but blocks remain "
                f"(executed={executed}/{nblocks}, policy={policy.name})")

        # --- advance fluid flows -------------------------------------------
        dt = max(tnext - t, 0.0)
        running = state == RUN
        rem[running] -= rate[running] * dt
        t = tnext

        eps = 1e-12
        pool_changed = False

        # --- completions ----------------------------------------------------
        for th in np.flatnonzero(running):
            if rem[th] <= bpb * 1e-12 + eps:
                executed += 1
                if homes[cur[th]] == thread_ld[th]:
                    local_count += 1
                cur[th] = -1
                rates_dirty = True
                if state[th] == RUN:
                    # submitter resumes submitting if work remains
                    if th == submitter and policy.has_unsubmitted():
                        state[th] = SUBMIT
                        ready[th] = t
                    else:
                        try_dispatch(th)
                pool_changed = True

        # --- overhead expiry: start the flow --------------------------------
        for th in range(nthreads):
            if state[th] == OVERHEAD and ready[th] <= t + eps:
                state[th] = RUN
                rem[th] = bpb
                rates_dirty = True

        # --- submitter ------------------------------------------------------
        if submitter >= 0 and state[submitter] == SUBMIT and ready[submitter] <= t + eps:
            capacity = params.pool_cap - policy.pool_size()
            if not policy.has_unsubmitted():
                # join the team (paper: "this thread will join the others")
                if not try_dispatch(submitter):
                    state[submitter] = IDLE
            elif capacity > 0:
                k = 0
                dt_sub = 0.0
                while capacity > 0 and policy.has_unsubmitted():
                    policy.submit_one()
                    dt_sub += jit(params.submit_overhead_us)
                    capacity -= 1
                    k += 1
                ready[submitter] = t + dt_sub
                pool_changed = True
            else:
                # pool full: execute one task, then resume submitting
                try_dispatch(submitter)

        if pool_changed:
            wake_idle()
        if rates_dirty:
            recompute_rates()
            rates_dirty = False

    mlups = grid.total_sites / t / 1e6
    return SimResult(
        makespan_s=t,
        mlups=mlups,
        local_fraction=local_count / nblocks,
        steal_fraction=steal_count / nblocks,
        policy=policy.name,
        topology=topo.name,
    )


def run_samples(grid: BlockGrid, topo: MachineTopology, make_policy,
                homes: np.ndarray, n_samples: int = 15,
                params: SimParams | None = None, pinned: bool = True,
                seed0: int = 0) -> list[SimResult]:
    """n_samples independent runs (fresh policy + RNG each) — Fig. 4 style."""
    out = []
    for s in range(n_samples):
        out.append(simulate(grid, topo, make_policy(), homes, params,
                            seed=seed0 + s, pinned=pinned))
    return out


def summarize(results: list[SimResult]) -> dict[str, float]:
    # percentiles via the shared deterministic helper (repro.obs): exact
    # nearest-rank over the full sample, so every quantile is an observed
    # trial value rather than an interpolation artifact.
    from ..obs.metrics import percentile
    m = [r.mlups for r in results]
    return {
        "median_mlups": float(percentile(m, 50)),
        "q25": float(percentile(m, 25)),
        "q75": float(percentile(m, 75)),
        "q05": float(percentile(m, 5)),
        "q95": float(percentile(m, 95)),
        "local_fraction": float(np.mean([r.local_fraction for r in results])),
        "steal_fraction": float(np.mean([r.steal_fraction for r in results])),
    }
