"""First-touch page-placement policies (paper §1.1, §1.4, §2.1).

Placement decides each block's *home locality domain* — the LD whose memory
holds the block's pages after initialization.  Blocks here are much larger
than a page (600*10*10 sites * 8 B = 480 kB vs 4 kB pages), so modelling
placement at block granularity is exact for every policy except round-robin
page interleaving, where it is a <1% idealization (a block's pages spread over
all LDs; we charge the whole block cyclically, which the bandwidth model makes
equivalent in aggregate).

Policies (labels follow the paper's Fig. 3):
  serial        — sequential init loop: every page lands in LD0.
  static        — parallel first touch, OpenMP ``static`` schedule over the
                  collapsed block loops in a given order ("s").
  static1       — parallel first touch, ``static,1`` round-robin over threads
                  ("s-1").
  round_robin   — ``numactl -i`` page interleaving across LDs.
"""
from __future__ import annotations

import numpy as np

from .tasks import BlockGrid
from .topology import MachineTopology


def serial_placement(grid: BlockGrid, topo: MachineTopology) -> np.ndarray:
    """Sequential initialization: all pages first-touched by thread 0 ⇒ LD0."""
    return np.zeros(grid.num_blocks, dtype=np.int64)


def round_robin_placement(grid: BlockGrid, topo: MachineTopology) -> np.ndarray:
    """``numactl -i 0..L-1``: pages interleaved cyclically across LDs.

    A block (480 kB) spans ~120 pages, so every block's traffic spreads
    uniformly over all LDs; the cost model marks this with home = -1
    ("interleaved flow").
    """
    return np.full(grid.num_blocks, -1, dtype=np.int64)


def _static_chunks(n: int, t: int) -> np.ndarray:
    """OpenMP ``static`` schedule: thread owning each of n iterations
    split into t near-equal contiguous chunks (first n%t chunks one longer)."""
    base = n // t
    rem = n % t
    owner = np.empty(n, dtype=np.int64)
    pos = 0
    for th in range(t):
        size = base + (1 if th < rem else 0)
        owner[pos:pos + size] = th
        pos += size
    return owner


def static_placement(grid: BlockGrid, topo: MachineTopology,
                     order: str = "ijk") -> np.ndarray:
    """Parallel first touch with ``schedule(static)`` over the collapsed block
    loops iterated in ``order``.  Thread t is pinned, so its pages land in
    ``topo.domain_of_core(t)``."""
    seq = grid.submit_order(order)
    owner_thread = _static_chunks(grid.num_blocks, topo.num_cores)
    homes = np.empty(grid.num_blocks, dtype=np.int64)
    for pos, blk in enumerate(seq):
        homes[blk] = topo.domain_of_core(int(owner_thread[pos]))
    return homes


def static1_placement(grid: BlockGrid, topo: MachineTopology,
                      order: str = "ijk") -> np.ndarray:
    """Parallel first touch with ``schedule(static,1)``: iteration p of the
    collapsed loop (in ``order``) goes to thread p mod T."""
    seq = grid.submit_order(order)
    homes = np.empty(grid.num_blocks, dtype=np.int64)
    ncores = topo.num_cores
    for pos, blk in enumerate(seq):
        homes[blk] = topo.domain_of_core(pos % ncores)
    return homes


PLACEMENTS = {
    "serial": serial_placement,
    "round_robin": round_robin_placement,
    "static": static_placement,
    "static1": static1_placement,
}


def place(policy: str, grid: BlockGrid, topo: MachineTopology,
          order: str = "ijk") -> np.ndarray:
    """Return ld_home[num_blocks] for a named policy."""
    if policy in ("serial", "round_robin"):
        return PLACEMENTS[policy](grid, topo)
    if policy in ("static", "static1"):
        return PLACEMENTS[policy](grid, topo, order=order)
    raise ValueError(f"unknown placement policy {policy!r}")
