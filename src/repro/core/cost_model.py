"""Bandwidth cost model: weighted max-min fair rate allocation on the ccNUMA graph.

A running block update is a *flow* pulling the block's bytes from the memory
of the block's home locality domain.  Resources (calibrated to the paper's
Table 1 STREAM numbers):

  * ``bus[l]``      — LD l's memory bus, capacity ``local_bw``; used by every
                      flow homed in l, local or remote.
  * ``ingress[l]``  — the interconnect path out of LD l, capacity
                      ``remote_factor * local_bw``; used by flows homed in l
                      but executing elsewhere.  This is the aggregate "NUMA
                      effect": even perfectly balanced nonlocal traffic cannot
                      exceed it (strongest on Nehalem EP, paper §1.4).
  * per-flow caps   — one core draws at most ``core_bw`` locally and
                      ``remote_factor * core_bw`` remotely.

``home_ld = -1`` marks an *interleaved* flow (``numactl -i`` page placement,
paper §1.1): its traffic spreads uniformly over all LDs, so it loads every
bus with weight 1/L and every foreign ingress with weight 1/L.

Rates are the weighted max-min fair allocation (progressive filling).  The
model reproduces the paper's three reference regimes: serial placement ⇒ all
flows homed in LD0 ⇒ aggregate ≤ one bus; parallel first touch ⇒ all local ⇒
aggregate ≈ full machine; round-robin interleave ⇒ in between, degraded by
the ingress pipes.
"""
from __future__ import annotations

import numpy as np

from .topology import MachineTopology


def maxmin_rates(home_ld: np.ndarray, exec_ld: np.ndarray,
                 topo: MachineTopology) -> np.ndarray:
    """Weighted max-min fair rates (GB/s) for the active flows.

    Args:
      home_ld: (F,) home LD of each flow's block; -1 = page-interleaved.
      exec_ld: (F,) LD of the core executing each flow.
    Returns:
      (F,) rates in GB/s.
    """
    f = len(home_ld)
    if f == 0:
        return np.zeros(0)
    home_ld = np.asarray(home_ld, dtype=np.int64)
    exec_ld = np.asarray(exec_ld, dtype=np.int64)
    ndom = topo.num_domains

    # resources: [bus 0..L-1, ingress 0..L-1]
    nres = 2 * ndom
    cres = np.empty(nres)
    cres[:ndom] = topo.local_bw
    cres[ndom:] = topo.remote_factor * topo.local_bw

    w = np.zeros((f, nres))
    cap = np.empty(f)
    for i in range(f):
        h, e = home_ld[i], exec_ld[i]
        if h < 0:  # interleaved over all LDs
            w[i, :ndom] = 1.0 / ndom
            for l in range(ndom):
                if l != e:
                    w[i, ndom + l] = 1.0 / ndom
            cap[i] = topo.core_bw
        elif h == e:
            w[i, h] = 1.0
            cap[i] = topo.core_bw
        else:
            w[i, h] = 1.0
            w[i, ndom + h] = 1.0
            cap[i] = topo.core_bw * topo.remote_factor

    rate = np.zeros(f)
    frozen = np.zeros(f, dtype=bool)
    eps = 1e-12

    while not frozen.all():
        unfrozen = ~frozen
        growth = w[unfrozen].sum(axis=0)            # per-resource fill speed
        slack = cres - rate @ w
        with np.errstate(divide="ignore", invalid="ignore"):
            d_res = np.where(growth > eps, slack / growth, np.inf)
        d_cap = cap[unfrozen] - rate[unfrozen]
        d = min(d_res.min(), d_cap.min())
        d = max(d, 0.0)
        rate[unfrozen] += d
        # freeze flows at their cap
        at_cap = unfrozen & (rate >= cap - eps)
        # freeze flows touching a saturated resource
        slack = cres - rate @ w
        sat = slack <= eps * np.maximum(cres, 1.0)
        touches_sat = (w[:, sat] > eps).any(axis=1) if sat.any() else np.zeros(f, bool)
        newly = at_cap | (unfrozen & touches_sat)
        if not newly.any():       # numerical guard: freeze the slowest flow
            idx = np.flatnonzero(unfrozen)[0]
            newly = np.zeros(f, bool)
            newly[idx] = True
        frozen |= newly
    return rate


def stream_sanity(topo: MachineTopology) -> dict[str, float]:
    """Aggregate bandwidths for the limiting regimes (vs Table 1)."""
    t = topo.num_cores
    exec_ld = np.array([topo.domain_of_core(c) for c in range(t)])
    local = maxmin_rates(exec_ld.copy(), exec_ld, topo)          # first touch
    serial = maxmin_rates(np.zeros(t, np.int64), exec_ld, topo)  # all in LD0
    inter = maxmin_rates(np.full(t, -1, np.int64), exec_ld, topo)  # numactl -i
    return {
        "full_local_bw": float(local.sum()),
        "serial_ld0_bw": float(serial.sum()),
        "interleaved_bw": float(inter.sum()),
    }
