"""Tasks and the blocked 3D domain decomposition of the paper's Jacobi solver.

One *task* = one lattice block (paper §2.1: "we define one task to be a single
block").  The paper's reference problem is a 600^2 x 2400 grid.  The text
quotes a block size of "600 x 10 x 100 (dk x dj x di)" but its own task
arithmetic ("one ib-jb layer comprises 60 tasks ... 240 layers ... 14400 tasks
in total") requires (dk, dj, di) = (600, 10, 10) with (Nk, Nj, Ni) =
(600, 600, 2400); we follow the task arithmetic, since the 256-task-cap
dynamics the paper analyses depend on it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class Block:
    """One stencil block == one task (the paper's scheduling entity)."""

    idx: int                       # linear submission-independent id
    coord: tuple[int, int, int]    # (ib, jb, kb) block coordinates
    sites: int                     # lattice sites in the block
    ld_home: int = -1              # locality domain of its pages (placement.py)


@dataclasses.dataclass(frozen=True)
class BlockGrid:
    """Blocked decomposition of an (Ni, Nj, Nk) lattice.

    ``k`` is the innermost (fast) index; the k block size equals Nk (paper:
    required for hardware prefetching), so there is a single k block.
    """

    ni: int
    nj: int
    nk: int
    di: int
    dj: int
    dk: int

    def __post_init__(self) -> None:
        for n, d, ax in ((self.ni, self.di, "i"), (self.nj, self.dj, "j"),
                         (self.nk, self.dk, "k")):
            if n % d != 0:
                raise ValueError(f"extent {n} not divisible by block {d} on {ax}")

    @property
    def blocks_i(self) -> int:
        return self.ni // self.di

    @property
    def blocks_j(self) -> int:
        return self.nj // self.dj

    @property
    def blocks_k(self) -> int:
        return self.nk // self.dk

    @property
    def num_blocks(self) -> int:
        return self.blocks_i * self.blocks_j * self.blocks_k

    @property
    def sites_per_block(self) -> int:
        return self.di * self.dj * self.dk

    @property
    def total_sites(self) -> int:
        return self.ni * self.nj * self.nk

    def linear_index(self, ib: int, jb: int, kb: int) -> int:
        """Canonical linear id — ijk order (i outermost), independent of
        submission order so placement and scheduling can be composed."""
        return (ib * self.blocks_j + jb) * self.blocks_k + kb

    def coords(self, idx: int) -> tuple[int, int, int]:
        kb = idx % self.blocks_k
        jb = (idx // self.blocks_k) % self.blocks_j
        ib = idx // (self.blocks_k * self.blocks_j)
        return ib, jb, kb

    # -- submission orders (paper §2.1: "ijk" vs "kji") --------------------
    def submit_order(self, order: str) -> np.ndarray:
        """Linear block ids in the order a single thread submits the tasks.

        ``"ijk"``: i outermost, k innermost (the paper's default loop nest).
        ``"kji"``: reversed nest — consecutive tasks cycle through i, hence
        through locality domains under static first-touch placement.
        """
        ib, jb, kb = np.meshgrid(
            np.arange(self.blocks_i), np.arange(self.blocks_j),
            np.arange(self.blocks_k), indexing="ij")
        lin = (ib * self.blocks_j + jb) * self.blocks_k + kb
        if order == "ijk":
            return lin.transpose(0, 1, 2).ravel()
        if order == "kji":
            return lin.transpose(2, 1, 0).ravel()
        raise ValueError(f"unknown submit order {order!r} (want 'ijk' or 'kji')")

    def make_blocks(self, ld_home: np.ndarray | None = None) -> list[Block]:
        homes = ld_home if ld_home is not None else np.full(self.num_blocks, -1)
        return [
            Block(idx=i, coord=self.coords(i), sites=self.sites_per_block,
                  ld_home=int(homes[i]))
            for i in range(self.num_blocks)
        ]

    def __iter__(self) -> Iterator[tuple[int, int, int]]:
        for i in range(self.blocks_i):
            for j in range(self.blocks_j):
                for k in range(self.blocks_k):
                    yield (i, j, k)


# The paper's reference decomposition (see module docstring).
PAPER_GRID = BlockGrid(ni=2400, nj=600, nk=600, di=10, dj=10, dk=600)

# A scaled-down grid with identical *structure* (60 j-blocks per layer,
# single k block) for fast CI runs of the simulator benchmarks.
SMALL_GRID = BlockGrid(ni=240, nj=600, nk=600, di=10, dj=10, dk=600)


def bytes_per_site(nt_stores: bool) -> int:
    """Main-memory traffic per lattice-site update (paper §1.4).

    One 8-byte load (the streamed source plane miss) + one 8-byte store;
    without nontemporal stores the store miss additionally write-allocates a
    cache line's worth of reads (+8 bytes/site effective).
    """
    return 16 if nt_stores else 24


def block_bytes(grid: BlockGrid, nt_stores: bool) -> int:
    return grid.sites_per_block * bytes_per_site(nt_stores)
