"""Locality queues (paper §2.2).

One FIFO queue per locality domain.  ``enqueue`` sorts a block into the queue
of its home domain; ``dequeue(ld)`` serves the oldest block of the caller's
own domain, falling back to scanning the other queues ("work stealing") —
load balance is deliberately given priority over strict locality.

In the paper each queue is a ``std::queue`` protected by an OpenMP lock (or a
``tbb::concurrent_queue``); here the structure is single-threaded and driven
by the discrete-event simulator, so plain deques suffice.  The *semantics*
(FIFO per domain, cyclic steal scan starting after the local domain) are
preserved exactly.
"""
from __future__ import annotations

from collections import deque
from typing import Optional


class LocalityQueues:
    """Per-LD FIFO queues with a cyclic steal scan."""

    def __init__(self, num_domains: int):
        self.num_domains = num_domains
        self._queues: list[deque[int]] = [deque() for _ in range(num_domains)]
        self._size = 0

    def enqueue(self, block_idx: int, ld_home: int) -> None:
        self._queues[ld_home].append(block_idx)
        self._size += 1

    def dequeue(self, ld: int) -> Optional[tuple[int, bool]]:
        """Pop the oldest block for domain ``ld``; steal cyclically otherwise.

        Returns ``(block_idx, stolen)`` or ``None`` if every queue is empty.
        ``stolen`` is True when the block came from a foreign queue.
        """
        if self._queues[ld]:
            self._size -= 1
            return self._queues[ld].popleft(), False
        for off in range(1, self.num_domains):
            victim = (ld + off) % self.num_domains
            if self._queues[victim]:
                self._size -= 1
                return self._queues[victim].popleft(), True
        return None

    def __len__(self) -> int:
        return self._size

    def queue_sizes(self) -> list[int]:
        return [len(q) for q in self._queues]
