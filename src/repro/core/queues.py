"""Locality queues (paper §2.2) — simulator-facing shim.

The canonical implementation of the per-domain FIFO queues and the cyclic
steal scan lives in ``repro.runtime.queues.DomainQueues`` (the online
runtime); this class only preserves the simulator's historical interface,
where items are integer block ids and ``dequeue`` returns a plain
``(block_idx, stolen)`` pair.

The *semantics* are the paper's exactly: FIFO per locality domain, local
queue served first, cyclic steal scan starting right after the caller's
own domain — load balance is deliberately given priority over strict
locality.
"""
from __future__ import annotations

from typing import Optional

from ..runtime.queues import DomainQueues


class LocalityQueues(DomainQueues):
    """Per-LD FIFO queues with a cyclic steal scan (thin runtime shim)."""

    def __init__(self, num_domains: int):
        super().__init__(num_domains, steal_order="cyclic")

    def dequeue(self, ld: int) -> Optional[tuple[int, bool]]:  # type: ignore[override]
        """Pop the oldest block for domain ``ld``; steal cyclically otherwise.

        Returns ``(block_idx, stolen)`` or ``None`` if every queue is empty.
        ``stolen`` is True when the block came from a foreign queue.
        """
        got = super().dequeue(ld)
        return None if got is None else (got.item, got.stolen)
