"""repro.core — the paper's contribution: locality-queue task scheduling.

Faithful layer (drives the discrete-event simulator, reproduces Fig. 3/4):
  topology, tasks, placement, queues, scheduler, cost_model, simulator.

SPMD layer (the technique adapted to ahead-of-time TPU scheduling):
  assignment.
"""
from .assignment import Assignment, build_assignment, round_robin_assignment
from .cost_model import maxmin_rates, stream_sanity
from .placement import place
from .queues import LocalityQueues
from .scheduler import (
    OpenMPLocalityQueues,
    OpenMPTasking,
    Policy,
    StaticWorksharing,
    TBBLocalityQueues,
    TBBParallelFor,
    tbb_first_touch,
)
from .simulator import SimParams, SimResult, run_samples, simulate, summarize
from .tasks import PAPER_GRID, SMALL_GRID, Block, BlockGrid, block_bytes, bytes_per_site
from .topology import (
    ISTANBUL,
    NEHALEM_EP,
    NEHALEM_EX,
    TESTBED,
    LocalityDomain,
    MachineTopology,
    tpu_topology,
)

__all__ = [
    "Assignment", "build_assignment", "round_robin_assignment",
    "maxmin_rates", "stream_sanity", "place", "LocalityQueues",
    "OpenMPLocalityQueues", "OpenMPTasking", "Policy", "StaticWorksharing",
    "TBBLocalityQueues", "TBBParallelFor", "tbb_first_touch",
    "SimParams", "SimResult", "run_samples", "simulate", "summarize",
    "PAPER_GRID", "SMALL_GRID", "Block", "BlockGrid", "block_bytes",
    "bytes_per_site", "ISTANBUL", "NEHALEM_EP", "NEHALEM_EX", "TESTBED",
    "LocalityDomain", "MachineTopology", "tpu_topology",
]
