"""Machine topologies: ccNUMA locality-domain layouts (paper Table 1) and TPU tiers.

The paper's test bed consists of three ccNUMA systems.  Each system is a set of
*locality domains* (LDs); every LD owns a memory bus with a STREAM-derived
bandwidth, cores are pinned to LDs, and nonlocal traffic crosses an inter-domain
link (HyperTransport / QPI) at reduced effective bandwidth.

The TPU topology expresses the same idea one tier up: a "locality domain" is a
pod (fast ICI inside, slow DCN between pods); chips play the role of cores.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class LocalityDomain:
    """One NUMA locality domain: a memory bus plus the cores attached to it."""

    ld_id: int
    cores: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class MachineTopology:
    """A ccNUMA machine as a bandwidth graph.

    Bandwidths are GB/s and are calibrated from the paper's Table 1 STREAM-copy
    measurements.  ``local_bw`` is the per-LD memory-bus bandwidth (the "socket"
    STREAM number); ``remote_factor`` scales the bandwidth a core achieves on
    *nonlocal* accesses (the "NUMA effect" — strongest on Nehalem EP);
    ``core_bw`` bounds what a single core can draw (a single core cannot
    saturate its socket's bus).
    """

    name: str
    num_domains: int
    cores_per_domain: int
    local_bw: float           # GB/s, one LD's memory bus (Table 1 "socket")
    remote_factor: float      # effective-bandwidth factor for nonlocal access
    core_bw: float            # GB/s, max per-core achievable bandwidth
    nt_stores: bool           # nontemporal stores used (affects bytes/site)
    frequency_ghz: float = 0.0
    interconnect: str = ""

    # -- derived helpers ---------------------------------------------------
    @property
    def num_cores(self) -> int:
        return self.num_domains * self.cores_per_domain

    @property
    def full_bw(self) -> float:
        """Aggregate machine bandwidth with perfect locality (≈ Table 1 full)."""
        return self.num_domains * self.local_bw

    def domain_of_core(self, core: int) -> int:
        if not 0 <= core < self.num_cores:
            raise ValueError(f"core {core} out of range for {self.name}")
        return core // self.cores_per_domain

    def domains(self) -> Iterable[LocalityDomain]:
        for ld in range(self.num_domains):
            base = ld * self.cores_per_domain
            yield LocalityDomain(ld, tuple(range(base, base + self.cores_per_domain)))

    def ld_id_map(self) -> list[int]:
        """The paper's global ``ld_ID`` vector: thread/core index -> LD."""
        return [self.domain_of_core(c) for c in range(self.num_cores)]


# ---------------------------------------------------------------------------
# The paper's test bed (Table 1).  ``local_bw`` is the socket STREAM copy
# number; ``remote_factor`` is calibrated so that the three horizontal
# reference lines of Fig. 3 (serial-init / round-robin / first-touch) are
# reproduced by the cost model; see tests/test_simulator.py.
# ---------------------------------------------------------------------------

ISTANBUL = MachineTopology(
    name="istanbul",
    num_domains=4,
    cores_per_domain=6,
    local_bw=9.9,
    remote_factor=0.60,      # HT-mediated access, moderate NUMA penalty
    core_bw=4.5,
    nt_stores=True,
    frequency_ghz=2.41,
    interconnect="HyperTransport",
)

NEHALEM_EP = MachineTopology(
    name="nehalem_ep",
    num_domains=2,
    cores_per_domain=4,
    local_bw=18.9,
    remote_factor=0.40,      # strongest NUMA effect in the test bed (paper §1.4)
    core_bw=8.0,
    nt_stores=True,
    frequency_ghz=2.66,
    interconnect="QPI",
)

NEHALEM_EX = MachineTopology(
    name="nehalem_ex",
    num_domains=4,
    cores_per_domain=8,
    local_bw=8.15,           # EA system with half the memory boards (paper §1.3)
    remote_factor=0.70,      # fully-connected QPI
    core_bw=4.0,
    nt_stores=False,         # Table 1: EX ran without NT stores
    frequency_ghz=2.27,
    interconnect="QPI",
)

TESTBED: dict[str, MachineTopology] = {
    t.name: t for t in (ISTANBUL, NEHALEM_EP, NEHALEM_EX)
}


# ---------------------------------------------------------------------------
# TPU tier model: one "locality domain" = one pod.  Used by the SPMD schedule
# builder (assignment.py) and the serving router; bandwidths from the v5e
# hardware constants used throughout the roofline analysis.
# ---------------------------------------------------------------------------

def tpu_topology(num_pods: int, chips_per_pod: int = 256) -> MachineTopology:
    """A multi-pod TPU fleet viewed as a ccNUMA machine (pods = LDs).

    ``local_bw`` is per-chip HBM feed aggregated per pod is irrelevant here —
    what matters for scheduling is the *relative* cost of crossing the
    inter-pod tier, so we use ICI vs DCN effective bandwidths.
    """
    return MachineTopology(
        name=f"tpu_{num_pods}x{chips_per_pod}",
        num_domains=num_pods,
        cores_per_domain=chips_per_pod,
        local_bw=50.0 * chips_per_pod,   # ICI bisection proxy inside a pod
        remote_factor=0.05,              # DCN ≪ ICI: crossing pods is expensive
        core_bw=819.0,                   # HBM bandwidth per chip
        nt_stores=True,
        interconnect="ICI/DCN",
    )
