"""Scheduling policies of the paper, as pluggable simulator drivers.

The queue and pool machinery (per-LD FIFO queues, the cyclic steal scan,
the bounded submission pool) lives in ``repro.runtime``; these policies
are thin offline drivers that feed those primitives from the
discrete-event simulator.

Every policy answers two questions for the discrete-event simulator:
  * submitter side — does a single thread feed a bounded task pool
    (OpenMP tasking semantics, §2.1), and what does one ``submit_one`` do?
  * consumer side — given an idle thread (and its locality domain), which
    block does it execute next?

Policies implemented (Fig. 3 columns, left to right):
  StaticWorksharing          — OpenMP ``parallel for`` with static chunks
                               (the three reference lines, combined with the
                               placement policies).
  OpenMPTasking              — plain tasking: single submitter, bounded pool
                               (~256 tasks, §2.1), FIFO consumption.
  OpenMPLocalityQueues       — the paper's contribution (§2.2): submitter
                               enqueues blocks into per-LD locality queues and
                               submits one generic pool task per block;
                               consumers serve their own LD's queue first and
                               steal otherwise.
  TBBParallelFor             — TBB baseline: fully dynamic (random-steal)
                               consumption, no pool cap; with
                               ``affinity_partitioner`` each thread replays
                               the ranges it first-touched.
  TBBLocalityQueues          — §3.2: locality queues on top of TBB; block
                               availability is uncontrolled (no submission
                               order), queues are served local-first.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from ..runtime.queues import DomainQueues, SubmissionPool
from .tasks import BlockGrid
from .topology import MachineTopology


@dataclasses.dataclass
class PopResult:
    block: int
    stolen: bool = False


class Policy:
    """Base class; see module docstring for the contract."""

    uses_submitter: bool = False
    name: str = "policy"

    def reset(self, grid: BlockGrid, homes: np.ndarray, topo: MachineTopology,
              thread_ld: np.ndarray, rng: np.random.Generator) -> None:
        raise NotImplementedError

    # -- submitter side ----------------------------------------------------
    def has_unsubmitted(self) -> bool:
        return False

    def pool_size(self) -> int:
        return 0

    def submit_one(self) -> None:
        raise NotImplementedError

    # -- consumer side -----------------------------------------------------
    def pop(self, thread: int) -> Optional[PopResult]:
        raise NotImplementedError


class StaticWorksharing(Policy):
    """OpenMP ``parallel for schedule(static)`` over the collapsed block loops.

    Thread t executes its contiguous chunk in order; no stealing (OpenMP
    static has no balancing), threads idle at the implicit barrier.
    """

    name = "static_workshare"

    def reset(self, grid, homes, topo, thread_ld, rng):
        seq = grid.submit_order("ijk")
        n, t = grid.num_blocks, topo.num_cores
        base, rem = divmod(n, t)
        self._lists: list[deque[int]] = []
        pos = 0
        for th in range(t):
            size = base + (1 if th < rem else 0)
            self._lists.append(deque(int(b) for b in seq[pos:pos + size]))
            pos += size

    def pop(self, thread):
        if self._lists[thread]:
            return PopResult(self._lists[thread].popleft())
        return None


class OpenMPTasking(Policy):
    """Plain OpenMP tasking: one submitter, bounded FIFO pool (§2.1)."""

    uses_submitter = True

    def __init__(self, submit_order: str = "ijk", pool_cap: int = 256):
        self.submit_order = submit_order
        self.pool_cap = pool_cap
        self.name = f"omp_task_{submit_order}"

    def reset(self, grid, homes, topo, thread_ld, rng):
        self._pending = deque(int(b) for b in grid.submit_order(self.submit_order))
        self._pool = SubmissionPool(self.pool_cap)

    def has_unsubmitted(self):
        return bool(self._pending)

    def pool_size(self):
        return len(self._pool)

    def submit_one(self):
        self._pool.push(self._pending.popleft())

    def pop(self, thread):
        blk = self._pool.pop()
        return None if blk is None else PopResult(blk)


class OpenMPLocalityQueues(Policy):
    """The paper's locality-queue layer on OpenMP tasking (§2.2)."""

    uses_submitter = True

    def __init__(self, submit_order: str = "ijk", pool_cap: int = 256):
        self.submit_order = submit_order
        self.pool_cap = pool_cap
        self.name = f"omp_lq_{submit_order}"

    def reset(self, grid, homes, topo, thread_ld, rng):
        self._pending = deque(int(b) for b in grid.submit_order(self.submit_order))
        self._homes = homes
        self._queues = DomainQueues(topo.num_domains, steal_order="cyclic")
        self._thread_ld = thread_ld

    def has_unsubmitted(self):
        return bool(self._pending)

    def pool_size(self):
        # One generic pool task per enqueued block (a task may run "ahead" of
        # its own submission, which the paper notes is harmless), so the pool
        # occupancy equals the queued-block count.
        return len(self._queues)

    def submit_one(self):
        blk = self._pending.popleft()
        self._queues.enqueue(blk, int(self._homes[blk]))

    def pop(self, thread):
        got = self._queues.dequeue(int(self._thread_ld[thread]))
        if got is None:
            return None
        return PopResult(got.item, stolen=got.stolen)


class TBBParallelFor(Policy):
    """TBB ``parallel_for`` (§3.1).

    Without the affinity partitioner, consumption is modelled as uniform
    random work stealing over the remaining blocks.  With it, each thread
    replays the blocks it first-touched (``replay`` = block→thread map from
    TBB-style dynamic initialization) and steals randomly when it runs dry.
    """

    def __init__(self, affinity: bool, replay: np.ndarray | None = None):
        self.affinity = affinity
        self.replay = replay
        self.name = f"tbb_{'a' if affinity else 'na'}"

    def reset(self, grid, homes, topo, thread_ld, rng):
        self._rng = rng
        n = grid.num_blocks
        if self.affinity:
            if self.replay is None:
                raise ValueError("affinity partitioner needs a replay map")
            self._lists = [deque() for _ in range(topo.num_cores)]
            for blk in range(n):
                self._lists[int(self.replay[blk])].append(blk)
        else:
            order = rng.permutation(n)
            self._shared = deque(int(b) for b in order)

    def pop(self, thread):
        if self.affinity:
            if self._lists[thread]:
                return PopResult(self._lists[thread].popleft())
            victims = [i for i, l in enumerate(self._lists) if l]
            if not victims:
                return None
            v = victims[int(self._rng.integers(len(victims)))]
            return PopResult(self._lists[v].popleft(), stolen=True)
        if self._shared:
            return PopResult(self._shared.popleft())
        return None


class TBBLocalityQueues(Policy):
    """Locality queues on top of TBB (§3.2): no submission-order control —
    all blocks are available from the start, sorted into per-LD queues."""

    name = "tbb_lq"

    def reset(self, grid, homes, topo, thread_ld, rng):
        self._queues = DomainQueues(topo.num_domains, steal_order="cyclic")
        order = rng.permutation(grid.num_blocks)   # uncontrolled availability
        for blk in order:
            self._queues.enqueue(int(blk), int(homes[blk]))
        self._thread_ld = thread_ld

    def pop(self, thread):
        got = self._queues.dequeue(int(self._thread_ld[thread]))
        if got is None:
            return None
        return PopResult(got.item, stolen=got.stolen)


def tbb_first_touch(grid: BlockGrid, topo: MachineTopology,
                    rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """TBB-style dynamic first touch: blocks are initialized by whichever
    thread stole the range — balanced but effectively random (§3.1: "page
    mapping is dynamic").  Returns (ld_home, init_thread)."""
    n, t = grid.num_blocks, topo.num_cores
    threads = np.repeat(np.arange(t), -(-n // t))[:n]
    rng.shuffle(threads)
    homes = np.array([topo.domain_of_core(int(th)) for th in threads])
    return homes, threads
