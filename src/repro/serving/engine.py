"""Serving engine: continuous batching on the locality-aware runtime.

This is the substrate where the paper's scheduler survives as a genuinely
*on-line* component on TPU: requests arrive dynamically, and replicas (model
instances on device slices) race to serve them — exactly the OpenMP
consumer-thread picture.  The router is a ``repro.runtime.Executor`` with
replicas as locality domains:

  * each request carries a locality tag = the replica holding its KV/prefix
    cache (requests in a multi-turn session are "first-touched" by the
    replica that prefilled them) — the runtime ``Task.home``;
  * one FIFO queue per replica; a free replica serves its own queue first
    and steals from the longest foreign queue otherwise (balance over
    locality, §2.2) — ``DomainQueues(steal_order="longest")``;
  * a stolen request pays a "page migration": its prefix must be re-prefilled
    on the stealing replica (the nonlocal-access penalty) — the runtime's
    ``steal_penalty`` account.

Routing policies:
  ``locality``     — route to the home replica's queue (homeless requests
                     round-robin); the paper's layer.
  ``round_robin``  — ignore homes on submit; queues + stealing still apply.
  ``single_queue`` — one shared FIFO (a single locality domain): replicas
                     take work in arrival order, locality is accidental.

The engine runs the real model (prefill + decode steps) for every request;
tests/test_serving.py checks the outputs are identical under every routing
policy while the steal/local statistics differ as the paper predicts.

Pass ``trace=repro.trace.TraceRecorder()`` to record the router's behaviour
as a replayable trace (steal-storm analysis / offline policy A/B without
re-running the model).

Continuous batching (``batch=``): a free replica drains up to ``batch``
queued requests from one queue per scheduling round and serves them as one
grab (``Executor(batch=...)`` + ``Replica.run_batch``) — pass an int or an
adaptive ``repro.control.BatchGovernor``.  Each request in the grab still
runs its own prefill + decode on its own cache, so batched serving is
token-identical to unbatched under every routing policy (the bit-identity
contract; a fused padded-batch decode is a later kernel-level step).  Pass
``control=repro.control.ControlLoop(...)`` to attach the full control
plane (cost routing, adaptive batching, the steal circuit-breaker) to the
engine's router.

Spec construction (the preferred path): pass
``spec=repro.spec.RuntimeSpec`` with a ``serving`` block —
``spec.named("controlled_serving")`` is the canonical example — and the
engine builds its whole router from the spec: queues, steal order,
governor (+ breaker), penalty rule, batch policy, and control plane all
come from the declared configuration, and traces recorded off the engine
embed the spec (schema v2), so ``repro.trace.replay(trace)`` reconstructs
the exact router with no hand-written factory.  The raw kwargs
(``policy``/``num_replicas``/``max_seq``/``pool_cap``/``batch``/
``control``) remain as a thin deprecated path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from ..runtime import Executor, Task, Worker
from ..trace import TraceRecorder

POLICIES = ("locality", "round_robin", "single_queue")


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray              # prompt tokens (1D)
    max_new: int
    home_replica: int = -1          # -1: no cached prefix anywhere
    out_tokens: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    served: int = 0
    local: int = 0
    stolen: int = 0
    prefill_tokens: int = 0         # includes re-prefills caused by steals

    @property
    def locality_fraction(self) -> float:
        return self.local / max(self.served, 1)


class Replica:
    """One model replica with its own KV-cache arena."""

    def __init__(self, model: Model, params: Any, max_seq: int,
                 batch_size: int = 1):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_size
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def run(self, req: Request) -> Request:
        model = self.model
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        caches = model.init_cache(1, self.max_seq)
        logits, caches = self._prefill(self.params, {"tokens": toks}, caches)
        pos = toks.shape[1]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for _ in range(req.max_new):
            req.out_tokens.append(int(cur[0, 0]))
            logits, caches = self._decode(self.params, cur, pos, caches)
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            pos += 1
        return req

    def run_batch(self, reqs: list[Request]) -> list[Request]:
        """Serve one coalesced grab of requests on this replica.

        Requests are decoded per-request on their own caches (the compiled
        prefill/decode functions are shared), so the batch is token-identical
        to serving each request alone — the batching win lives in the
        scheduler (one routing round, one queue grab, one cache arena touch
        per batch), not in fused device math yet.
        """
        return [self.run(r) for r in reqs]


class ServingEngine:
    """Replicas as locality domains over a ``runtime.Executor``."""

    def __init__(self, model: Model, params: Any, num_replicas: int = 2,
                 max_seq: int = 128, policy: str = "locality",
                 pool_cap: Optional[int] = 256,
                 trace: Optional[TraceRecorder] = None,
                 batch: Any = 1,
                 control: Optional[Any] = None,
                 spec: Optional[Any] = None):
        if spec is not None:
            conflicts = [name for name, val, default in (
                ("num_replicas", num_replicas, 2), ("max_seq", max_seq, 128),
                ("policy", policy, "locality"), ("pool_cap", pool_cap, 256),
                ("batch", batch, 1), ("control", control, None))
                if val != default]
            if conflicts:
                from ..spec import SpecError
                raise SpecError(
                    f"spec-built engine: {conflicts} come from the spec "
                    f"(serving/runtime blocks); drop the kwargs")
            self._init_from_spec(model, params, spec, trace)
            return
        if policy not in POLICIES:
            raise ValueError(policy)
        self.policy = policy
        self.replicas = [Replica(model, params, max_seq)
                         for _ in range(num_replicas)]
        # single_queue = one shared locality domain every replica serves;
        # otherwise one domain per replica (worker wid == replica index).
        num_domains = 1 if policy == "single_queue" else num_replicas
        worker_domains = ([0] * num_replicas if policy == "single_queue"
                          else list(range(num_replicas)))
        # every grab (batched or size 1) goes through the batch handler, so
        # there is exactly one accounting/migration path
        self._exec = Executor(
            num_domains, worker_domains,
            batch=batch,
            batch_handler=self._run_grab,
            steal_order="longest",
            steal_penalty=self._steal_penalty,
            pool_cap=pool_cap,
        )
        # optional control plane (repro.control.ControlLoop): cost routing,
        # adaptive batch sizing, storm circuit-breaking on this router.
        # Attached before the trace recorder so a recorded header names the
        # effective (possibly breaker-wrapped) governor.
        self.control = control
        if control is not None:
            control.attach(self._exec)
        # optional trace hook: record this engine's routing/steal behaviour
        # as a replayable repro.trace trace (request payloads stay opaque;
        # the submission stream carries home replica + prompt-length cost).
        self.trace = trace
        if trace is not None:
            trace.attach(self._exec)
        self._prefill_base = 0      # first-prefill tokens of served requests
        self._accidental_local = 0  # served by home replica, any routing

    def _init_from_spec(self, model: Model, params: Any, spec: Any,
                        trace: Optional[TraceRecorder]) -> None:
        """Build the whole router from a ``repro.spec.RuntimeSpec``."""
        from ..spec import SpecError
        if spec.serving is None:
            raise SpecError("ServingEngine needs a spec with a serving "
                            "block (see spec.named('controlled_serving'))")
        sv = spec.serving
        expected = 1 if sv.policy == "single_queue" else sv.num_replicas
        if spec.num_domains != expected:
            raise SpecError(
                f"serving policy {sv.policy!r} with {sv.num_replicas} "
                f"replicas needs num_domains == {expected}, "
                f"spec says {spec.num_domains}")
        wd = spec.worker_domains
        if wd is not None and len(wd) != sv.num_replicas:
            raise SpecError(f"worker_domains pins {len(wd)} workers but "
                            f"serving declares {sv.num_replicas} replicas")
        if sv.policy != "locality" and spec.router.kind != "none":
            # round_robin/single_queue submit with an explicit domain, so a
            # declared router would never be consulted — and the recorded
            # header would then name a policy that never ran.
            raise SpecError(
                f"serving policy {sv.policy!r} routes explicitly and would "
                f"silently bypass router.kind={spec.router.kind!r}; use "
                "policy 'locality' with a router, or router.kind 'none'")
        if sv.policy == "single_queue" and wd is None:
            # default one-worker-per-domain would under-staff the single
            # shared queue; every replica serves domain 0.
            spec = dataclasses.replace(spec,
                                       worker_domains=(0,) * sv.num_replicas)
        if trace is not None and spec.trace.record:
            raise SpecError("spec already declares trace recording; drop "
                            "the trace= kwarg (use Built.recorder instead)")
        self.policy = sv.policy
        self.replicas = [Replica(model, params, sv.max_seq)
                         for _ in range(sv.num_replicas)]
        built = spec.build(batch_handler=self._run_grab)
        self._exec = built.executor
        self.control = built.control
        self.trace = built.recorder
        if trace is not None:
            trace.attach(self._exec)
            self.trace = trace
        self._prefill_base = 0
        self._accidental_local = 0

    # -- runtime callbacks ---------------------------------------------------
    def _steal_penalty(self, task: Task, worker: Worker) -> float:
        # nonlocal access: a cached prefix must be re-prefilled on the thief
        req: Request = task.payload
        return float(len(req.tokens)) if req.home_replica >= 0 else 0.0

    def _touch(self, req: Request, worker: Worker) -> Request:
        self._prefill_base += len(req.tokens)
        if req.home_replica == worker.wid:
            self._accidental_local += 1
        req.home_replica = worker.wid          # first touch / migration
        return req

    def _run_grab(self, tasks: list[Task], worker: Worker) -> list[Request]:
        reqs = [self._touch(task.payload, worker) for task in tasks]
        return self.replicas[worker.wid].run_batch(reqs)

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        task = self._exec.make_task(payload=req, home=req.home_replica,
                                    cost=float(len(req.tokens)))
        if self.policy == "single_queue":
            domain = 0
        elif self.policy == "round_robin":
            domain = self._exec.next_round_robin()
        else:
            domain = None        # Executor routes: home queue, else round-robin
        self._exec.submit(task, domain=domain)

    def run_until_drained(self) -> list[Request]:
        """Round-robin replica stepping (a discrete stand-in for parallel
        replica workers — ordering, not timing, is what's under test)."""
        return self._exec.run_until_drained()

    @property
    def runtime(self) -> Executor:
        return self._exec

    @property
    def stats(self) -> ServeStats:
        s = self._exec.stats
        # single_queue collapses all replicas onto one domain, so the
        # runtime's domain-based local counter can't see which replica
        # served a request; accidental home hits are counted in the handler
        # instead (there are no steals with a single domain to exclude).
        local = (self._accidental_local if self.policy == "single_queue"
                 else s.local)
        return ServeStats(
            served=s.executed,
            local=local,
            stolen=s.stolen,
            prefill_tokens=self._prefill_base + int(s.steal_penalty),
        )
