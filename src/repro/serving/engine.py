"""Serving engine: continuous batching with a locality-queue request router.

This is the substrate where the paper's scheduler survives as a genuinely
*on-line* component on TPU: requests arrive dynamically, and replicas (model
instances on device slices) race to serve them — exactly the OpenMP
consumer-thread picture.  The router is the paper's §2.2 layer verbatim:

  * each request carries a locality tag = the replica holding its KV/prefix
    cache (requests in a multi-turn session are "first-touched" by the
    replica that prefilled them);
  * one FIFO queue per replica; a free replica serves its own queue first
    and steals from the longest foreign queue otherwise (balance over
    locality, §2.2);
  * a stolen request pays a "page migration": its prefix must be re-prefilled
    on the stealing replica (the nonlocal-access penalty).

The engine runs the real model (prefill + decode steps) for every request;
tests/test_serving.py checks the outputs are identical under every routing
policy while the steal/local statistics differ as the paper predicts.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray              # prompt tokens (1D)
    max_new: int
    home_replica: int = -1          # -1: no cached prefix anywhere
    out_tokens: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    served: int = 0
    local: int = 0
    stolen: int = 0
    prefill_tokens: int = 0         # includes re-prefills caused by steals

    @property
    def locality_fraction(self) -> float:
        return self.local / max(self.served, 1)


class Replica:
    """One model replica with its own KV-cache arena."""

    def __init__(self, model: Model, params: Any, max_seq: int,
                 batch_size: int = 1):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch = batch_size
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def run(self, req: Request) -> Request:
        model = self.model
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        caches = model.init_cache(1, self.max_seq)
        logits, caches = self._prefill(self.params, {"tokens": toks}, caches)
        pos = toks.shape[1]
        cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for _ in range(req.max_new):
            req.out_tokens.append(int(cur[0, 0]))
            logits, caches = self._decode(self.params, cur, pos, caches)
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            pos += 1
        return req


class LocalityRouter:
    """Per-replica queues + steal — the paper's locality queues, on-line."""

    def __init__(self, num_replicas: int, policy: str = "locality"):
        if policy not in ("locality", "round_robin", "single_queue"):
            raise ValueError(policy)
        self.n = num_replicas
        self.policy = policy
        self.queues: list[deque[Request]] = [deque() for _ in range(num_replicas)]
        self._rr = 0

    def submit(self, req: Request) -> None:
        if self.policy == "single_queue":
            self.queues[0].append(req)
        elif self.policy == "round_robin" or req.home_replica < 0:
            self.queues[self._rr % self.n].append(req)
            self._rr += 1
        else:
            self.queues[req.home_replica].append(req)

    def next_for(self, replica: int) -> Optional[tuple[Request, bool]]:
        """(request, stolen) for a free replica; local queue first, then the
        longest foreign queue (balance over locality, paper §2.2)."""
        if self.policy == "single_queue":
            return (self.queues[0].popleft(), False) if self.queues[0] else None
        if self.queues[replica]:
            return self.queues[replica].popleft(), False
        victims = sorted(range(self.n), key=lambda i: -len(self.queues[i]))
        for v in victims:
            if v != replica and self.queues[v]:
                return self.queues[v].popleft(), True
        return None

    def pending(self) -> int:
        return sum(len(q) for q in self.queues)


class ServingEngine:
    def __init__(self, model: Model, params: Any, num_replicas: int = 2,
                 max_seq: int = 128, policy: str = "locality"):
        self.replicas = [Replica(model, params, max_seq)
                         for _ in range(num_replicas)]
        self.router = LocalityRouter(num_replicas, policy)
        self.stats = ServeStats()

    def submit(self, req: Request) -> None:
        self.router.submit(req)

    def run_until_drained(self) -> list[Request]:
        """Round-robin replica stepping (a discrete stand-in for parallel
        replica workers — ordering, not timing, is what's under test)."""
        done: list[Request] = []
        while self.router.pending():
            for ridx, rep in enumerate(self.replicas):
                got = self.router.next_for(ridx)
                if got is None:
                    continue
                req, stolen = got
                if stolen and req.home_replica >= 0:
                    # nonlocal access: prefix must be re-prefilled here
                    self.stats.prefill_tokens += len(req.tokens)
                self.stats.prefill_tokens += len(req.tokens)
                self.stats.served += 1
                if not stolen and req.home_replica == ridx:
                    self.stats.local += 1
                if stolen:
                    self.stats.stolen += 1
                req.home_replica = ridx          # first touch / migration
                done.append(rep.run(req))
        return done
