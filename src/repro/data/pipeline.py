"""Deterministic synthetic data pipeline with locality-aware host sharding.

The corpus is a seeded synthetic token stream (Zipfian unigram mixture with
injected n-gram structure so a ~100M model has something learnable); it is
split into SHARDS, and shard→host assignment goes through the paper's
schedule builder (repro.core.assignment): each host preferentially reads
the shards that feed the device slice it hosts ("first touch"), and a host
that runs dry steals the next shard from the most-loaded peer — the
locality-queue policy applied to input pipelines.  A prefetch thread keeps
`prefetch` batches staged.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from ..core.assignment import build_assignment


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 64
    num_hosts: int = 1
    seed: int = 1234
    ngram_order: int = 3


class SyntheticCorpus:
    """Deterministic shard generator: shard i is reproducible in isolation
    (seeded by (seed, shard)), so restarts and elastic re-shards replay
    identically regardless of which host reads the shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # shared Zipf unigram table + a small deterministic bigram kick
        ranks = np.arange(1, v + 1)
        self.unigram = 1.0 / ranks ** 1.1
        self.unigram /= self.unigram.sum()
        self.bigram_shift = base.integers(1, v, size=257)

    def shard_tokens(self, shard: int, n_tokens: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, shard))
        toks = rng.choice(cfg.vocab_size, size=n_tokens, p=self.unigram)
        # inject learnable structure: token t+1 depends on t (mod table)
        mask = rng.random(n_tokens) < 0.5
        prev = np.roll(toks, 1)
        deterministic = (prev + self.bigram_shift[prev % 257]) % cfg.vocab_size
        return np.where(mask, deterministic, toks).astype(np.int32)


class ShardedLoader:
    """Locality-scheduled shard reader + prefetching batch iterator."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.host_id = host_id
        self.corpus = SyntheticCorpus(cfg)
        # shard homes: shard s "lives" near host s % num_hosts (e.g. a
        # co-located storage volume); the schedule builder balances with
        # bounded stealing — the paper's technique at the pipeline layer.
        homes = np.arange(cfg.num_shards) % cfg.num_hosts
        cost = np.ones(cfg.num_shards)
        self.assignment = build_assignment(homes, cost, cfg.num_hosts,
                                           max_imbalance=0.05)
        self.my_shards = list(self.assignment.lists[host_id])
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # host-local slice of the global batch
    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.num_hosts

    def _producer(self) -> None:
        cfg = self.cfg
        need = self.host_batch * (cfg.seq_len + 1)
        step = 0
        while not self._stop.is_set():
            shard = self.my_shards[step % len(self.my_shards)]
            epoch = step // len(self.my_shards)
            toks = self.corpus.shard_tokens(shard * 100003 + epoch, need)
            chunk = toks.reshape(self.host_batch, cfg.seq_len + 1)
            batch = {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._producer, daemon=True)
            self._thread.start()
        while True:
            _, batch = self._q.get()
            yield batch

    def close(self) -> None:
        self._stop.set()


def make_batch_iterator(vocab_size: int, seq_len: int, global_batch: int,
                        seed: int = 0) -> Iterator[dict[str, np.ndarray]]:
    """Simple non-threaded iterator for tests and the quickstart example."""
    cfg = DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                     global_batch=global_batch, seed=seed)
    loader = ShardedLoader(cfg)
    corpus = loader.corpus
    step = 0
    while True:
        toks = corpus.shard_tokens(step, global_batch * (seq_len + 1))
        chunk = toks.reshape(global_batch, seq_len + 1)
        yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
        step += 1
