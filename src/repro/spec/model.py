"""The declarative policy spec: one frozen dataclass tree per system.

A ``RuntimeSpec`` is the *complete*, serializable name of a scheduling
configuration — domains, worker pinning, steal order, pool cap, seed,
governor (with optional breaker decoration), router, batch policy, steal
penalty, trace recording, and (optionally) a serving topology.  Three PRs
of constructor kwargs (``Executor``, ``ControlLoop``, ``TraceRecorder``,
``ServingEngine``) collapse into one value that can be

  * built      — ``spec.build()`` returns a fully wired executor plus any
                 control loop / trace recorder it declares (``build.py``);
  * serialized — ``to_json``/``from_json`` with strict unknown-field and
                 unknown-version errors, so a policy is a reviewable JSON
                 file, not constructor folklore;
  * recorded   — the trace header embeds the spec (schema v2), so
                 ``repro.trace.replay(trace)`` with *no executor argument*
                 reconstructs the exact recorded system;
  * named      — ``repro.spec.named("paper_cyclic")`` etc. (``registry.py``).

Every spec class is frozen and compares by value, so
``from_json(to_json(s)) == s`` holds exactly — the round-trip property the
golden files in ``specs/`` pin down.

Design rule: specs hold only JSON-representable values.  Callables
(handlers, custom governors, live model replicas) are *build-time*
arguments to ``spec.build(...)``; anything that must survive a trace
round-trip belongs in the spec itself (which is why the steal penalty is a
``PenaltySpec``, not a lambda).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional

SPEC_VERSION = 1


class SpecError(ValueError):
    """Raised for malformed, unknown-field, or unknown-version specs."""


def _reject_unknown(cls, data: dict, where: str) -> None:
    if not isinstance(data, dict):
        raise SpecError(f"{where}: expected an object, got {type(data).__name__}")
    allowed = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - allowed)
    if unknown:
        raise SpecError(f"{where}: unknown field(s) {unknown} "
                        f"(known: {sorted(allowed)})")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise SpecError(msg)


# Scalar field types each spec class declares (annotations are strings under
# ``from __future__ import annotations``); Optional scalars accept null.
_SCALARS = {"int": int, "float": float, "bool": bool, "str": str,
            "Optional[int]": int, "Optional[float]": float}


def _coerce_scalars(cls, data: dict, where: str) -> dict:
    """Type-check (and int→float widen) the scalar fields of ``data``.

    The strictness contract: a wrong-typed JSON value (``"ema": "0.5"``)
    must fail parsing with a ``SpecError`` naming the field, never leak a
    raw ``TypeError`` from a validator or — worse — survive into a built
    system and blow up mid-run.
    """
    kw = dict(data)
    for f in dataclasses.fields(cls):
        want = _SCALARS.get(str(f.type))
        v = kw.get(f.name)
        if want is None or v is None or f.name not in kw:
            continue
        bad = SpecError(f"{where}.{f.name}: expected {want.__name__}, "
                        f"got {type(v).__name__} ({v!r})")
        if want is bool or want is str:
            if not isinstance(v, want):
                raise bad
        elif isinstance(v, bool) or not isinstance(
                v, (int, float) if want is float else int):
            raise bad
        else:
            kw[f.name] = want(v)
    return kw


def _construct(cls, kw: dict, where: str):
    try:
        return cls(**kw)
    except TypeError as e:                       # wrong shapes the coercion
        raise SpecError(f"{where}: {e}") from e  # table doesn't cover


@dataclasses.dataclass(frozen=True)
class PenaltySpec:
    """Serializable steal-penalty rule (``Executor(steal_penalty=...)``).

    kind:
      ``none``          — steals are free (penalty callback is ``None``).
      ``constant``      — every steal costs ``value`` (the benchmarks'
                          fixed re-prefill).
      ``cost_factor``   — penalty = ``value * task.cost``.
      ``cost_if_homed`` — penalty = ``value * task.cost`` for tasks with a
                          home, 0 for homeless ones (the serving engine's
                          re-prefill rule: only a cached prefix costs
                          anything to migrate).
    """

    KINDS = ("none", "constant", "cost_factor", "cost_if_homed")

    kind: str = "none"
    value: float = 0.0

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"penalty.kind {self.kind!r} not in {self.KINDS}")
        _require(self.value >= 0.0, "penalty.value must be >= 0")

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "value": self.value}

    @classmethod
    def from_dict(cls, d: dict, where: str = "penalty") -> "PenaltySpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


def _int_float_pairs(value, where: str):
    """Parse/normalize a ``[[int, float], ...]`` pair list (JSON form of the
    small int-keyed estimate maps the state specs carry); returns a tuple of
    ``(int, float)`` pairs, or None for None/empty."""
    if value is None:
        return None
    if not isinstance(value, (list, tuple)):
        raise SpecError(f"{where}: expected a list of [int, number] pairs, "
                        f"got {type(value).__name__}")
    out = []
    for item in value:
        ok = (isinstance(item, (list, tuple)) and len(item) == 2
              and not isinstance(item[0], bool) and isinstance(item[0], int)
              and not isinstance(item[1], bool)
              and isinstance(item[1], (int, float)))
        if not ok:
            raise SpecError(f"{where}: expected [int, number] pairs, "
                            f"got {item!r}")
        out.append((int(item[0]), float(item[1])))
    return tuple(out) if out else None


@dataclasses.dataclass(frozen=True)
class BreakerStateSpec:
    """Warm ``control.StormBreaker`` state: remaining cooldown windows and
    episode counters, so ``spec.checkpoint()`` restores a breaker
    mid-cooldown instead of silently re-arming it."""

    cooldown_left: int = 0
    remote_cooldown_left: int = 0
    trips: int = 0
    remote_trips: int = 0

    def __post_init__(self):
        for f in dataclasses.fields(self):
            _require(getattr(self, f.name) >= 0,
                     f"breaker.state.{f.name} must be >= 0")

    @classmethod
    def from_breaker(cls, breaker) -> "BreakerStateSpec":
        """Snapshot a live ``control.StormBreaker``'s warm state."""
        state = getattr(breaker, "breaker_state", None)
        if state is None:
            raise SpecError(
                f"{type(breaker).__name__} is no StormBreaker "
                "(no breaker_state to snapshot)")
        return cls(**state())

    def to_dict(self) -> dict[str, Any]:
        return {"cooldown_left": self.cooldown_left,
                "remote_cooldown_left": self.remote_cooldown_left,
                "trips": self.trips, "remote_trips": self.remote_trips}

    @classmethod
    def from_dict(cls, d: dict,
                  where: str = "breaker.state") -> "BreakerStateSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class BreakerSpec:
    """``repro.control.StormBreaker`` parameters (governor decoration).

    ``remote_frac`` is the cross-tier steal fraction that trips the
    breaker's remote-only state under a hierarchical topology (flat
    machines never produce remote steals, so it is inert there).
    ``state`` restores a checkpointed breaker's cooldowns warm.
    """

    width: int = 8
    steal_frac: float = 0.5
    inline_frac: float = 0.25
    remote_frac: float = 0.25
    min_executed: int = 4
    cooldown: int = 3
    mode: str = "raise"
    boost: int = 8
    state: Optional[BreakerStateSpec] = None

    def __post_init__(self):
        _require(self.width >= 1, "breaker.width must be >= 1")
        _require(self.mode in ("raise", "block"),
                 f"breaker.mode {self.mode!r} not in ('raise', 'block')")
        _require(self.remote_frac > 0, "breaker.remote_frac must be > 0")

    def to_dict(self) -> dict[str, Any]:
        return {"width": self.width, "steal_frac": self.steal_frac,
                "inline_frac": self.inline_frac,
                "remote_frac": self.remote_frac,
                "min_executed": self.min_executed, "cooldown": self.cooldown,
                "mode": self.mode, "boost": self.boost,
                "state": None if self.state is None else self.state.to_dict()}

    @classmethod
    def from_dict(cls, d: dict, where: str = "breaker") -> "BreakerSpec":
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        st = kw.pop("state", None)
        kw["state"] = (None if st is None
                       else BreakerStateSpec.from_dict(st, f"{where}.state"))
        return _construct(cls, kw, where)


@dataclasses.dataclass(frozen=True)
class GovernorStateSpec:
    """A snapshot of an adaptive governor's *learned* θ inputs.

    ``runtime.AdaptiveSteal`` / ``trace.MeasuredPenalty`` learn the steal
    penalty (θ's numerator) and — for the measured governor — the local
    service time (θ's denominator) while the system runs.  This block
    serializes that learned state, so a mid-run checkpoint rebuilds the
    exact estimator declaratively: ``GovernorSpec(state=...)`` constructs
    the governor at the snapshotted estimates instead of the static priors,
    and no trace has to be re-read (``MeasuredPenalty.from_trace``'s job,
    done once and then persisted as spec data).

    Capture with ``GovernorStateSpec.from_governor(gov)`` (a breaker
    decoration is unwrapped) or ``repro.spec.checkpoint(executor)``.
    Per-worker idle-decay counters are transient scheduling state, not
    estimator state, and are deliberately not snapshotted.
    """

    penalty_estimate: float = 0.0
    task_cost: float = 1.0
    observed_local: int = 0
    observed_steals: int = 0
    level_penalties: Optional[tuple[tuple[int, float], ...]] = None

    def __post_init__(self):
        _require(self.penalty_estimate >= 0.0,
                 "governor.state.penalty_estimate must be >= 0")
        _require(self.task_cost > 0,
                 "governor.state.task_cost must be positive")
        _require(self.observed_local >= 0 and self.observed_steals >= 0,
                 "governor.state observation counts must be >= 0")
        if self.level_penalties is not None:
            lp = _int_float_pairs(self.level_penalties,
                                  "governor.state.level_penalties")
            if lp is not None:
                for lv, est in lp:
                    _require(lv >= 1 and est >= 0.0,
                             "governor.state.level_penalties entries need "
                             "level >= 1 and estimate >= 0")
            object.__setattr__(self, "level_penalties", lp)

    @classmethod
    def from_governor(cls, governor) -> "GovernorStateSpec":
        """Snapshot a live governor's learned estimates (unwrapping a
        ``control.StormBreaker`` decoration to its inner governor),
        including any per-topology-tier penalty EMAs a hierarchical run
        taught it."""
        inner = getattr(governor, "inner", None)
        if inner is not None:
            governor = inner
        if not hasattr(governor, "penalty_estimate"):
            raise SpecError(
                f"governor {type(governor).__name__} carries no learned "
                "state to snapshot (only adaptive/measured governors do)")
        levels = getattr(governor, "level_penalty_estimates", None)
        by_level = sorted(levels().items()) if levels is not None else []
        return cls(penalty_estimate=float(governor.penalty_estimate),
                   task_cost=float(governor.task_cost),
                   observed_local=int(getattr(governor, "observed_local", 0)),
                   observed_steals=int(getattr(governor,
                                               "observed_steals", 0)),
                   level_penalties=tuple(by_level) or None)

    def to_dict(self) -> dict[str, Any]:
        return {"penalty_estimate": self.penalty_estimate,
                "task_cost": self.task_cost,
                "observed_local": self.observed_local,
                "observed_steals": self.observed_steals,
                "level_penalties": (None if self.level_penalties is None
                                    else [list(p)
                                          for p in self.level_penalties])}

    @classmethod
    def from_dict(cls, d: dict,
                  where: str = "governor.state") -> "GovernorStateSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class GovernorSpec:
    """Steal-governor choice + hyper-parameters, plus breaker decoration.

    kind:
      ``greedy``   — ``runtime.GreedySteal`` (the paper's §2.2 rule).
      ``none``     — ``runtime.NoSteal`` (pure locality).
      ``adaptive`` — ``runtime.AdaptiveSteal(penalty_hint, task_cost, ema,
                      max_threshold)``.
      ``measured`` — ``trace.MeasuredPenalty`` (both θ inputs learned
                      online; same hyper-parameters as ``adaptive``).

    ``breaker`` wraps the built governor in a ``control.StormBreaker``
    (installed via ``ControlLoop``, so the storm detector runs on the
    executor's step hook).

    ``state`` (adaptive/measured only) seeds the governor's learned θ
    inputs from a ``GovernorStateSpec`` snapshot; it supersedes the
    ``penalty_hint``/``task_cost`` priors, which remain purely declarative
    configuration.
    """

    KINDS = ("greedy", "none", "adaptive", "measured")

    kind: str = "greedy"
    penalty_hint: float = 4.0
    task_cost: float = 1.0
    ema: float = 0.2
    max_threshold: int = 64
    breaker: Optional[BreakerSpec] = None
    state: Optional[GovernorStateSpec] = None

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"governor.kind {self.kind!r} not in {self.KINDS}")
        _require(0.0 < self.ema <= 1.0, "governor.ema must be in (0, 1]")
        _require(self.task_cost > 0, "governor.task_cost must be positive")
        _require(self.state is None or self.kind in ("adaptive", "measured"),
                 f"governor.state requires an adaptive/measured kind, "
                 f"not {self.kind!r} (nothing to restore)")

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "penalty_hint": self.penalty_hint,
                "task_cost": self.task_cost, "ema": self.ema,
                "max_threshold": self.max_threshold,
                "breaker": None if self.breaker is None
                else self.breaker.to_dict(),
                "state": None if self.state is None
                else self.state.to_dict()}

    @classmethod
    def from_dict(cls, d: dict, where: str = "governor") -> "GovernorSpec":
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        br = kw.pop("breaker", None)
        kw["breaker"] = (None if br is None
                         else BreakerSpec.from_dict(br, f"{where}.breaker"))
        st = kw.pop("state", None)
        kw["state"] = (None if st is None
                       else GovernorStateSpec.from_dict(st, f"{where}.state"))
        return _construct(cls, kw, where)


@dataclasses.dataclass(frozen=True)
class RouterSpec:
    """Submit-side routing policy (``Executor(router=...)``).

    kind:
      ``none``        — the executor's default: home queue, else
                        round-robin for homeless tasks.
      ``round_robin`` — ignore homes, round-robin every submission (the
                        "plain tasking" arm of the benchmarks).
      ``cost``        — ``control.CostRouter``: least-estimated-backlog
                        routing, home-sticky up to a spill threshold.

    ``spill`` (kind ``cost`` only):
      ``static``   — the threshold is the fixed ``spill_penalty`` hint.
      ``measured`` — the threshold is read live from the governor's
                      ``penalty_estimate`` (``AdaptiveSteal`` /
                      ``MeasuredPenalty``), falling back to
                      ``spill_penalty`` until one exists — the ROADMAP's
                      "price the spill threshold from measurements".

    ``breaker_aware`` (kind ``cost`` only) makes the router consult the
    executor's ``StormBreaker``: while the breaker is tripped, homed tasks
    are never spilled (remote-only trips only suspend cross-tier spills) —
    routing must not re-feed the storm the breaker is quenching.
    """

    KINDS = ("none", "round_robin", "cost")

    kind: str = "none"
    spill_penalty: Optional[float] = 4.0
    spill: str = "static"
    breaker_aware: bool = False

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"router.kind {self.kind!r} not in {self.KINDS}")
        _require(self.spill in ("static", "measured"),
                 f"router.spill {self.spill!r} not in ('static', 'measured')")
        _require(not (self.breaker_aware and self.kind != "cost"),
                 "router.breaker_aware requires kind 'cost'")

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "spill_penalty": self.spill_penalty,
                "spill": self.spill, "breaker_aware": self.breaker_aware}

    @classmethod
    def from_dict(cls, d: dict, where: str = "router") -> "RouterSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class BatchStateSpec:
    """Warm ``control.BatchGovernor`` state: the learned service EMAs (the
    global one and, under ``per_domain``, each queue's own) plus the
    current size, so a checkpointed governor resumes sizing from its
    measurements instead of re-warming from ``init_size``."""

    service_estimate: Optional[float] = None
    size: Optional[int] = None
    domain_estimates: Optional[tuple[tuple[int, float], ...]] = None

    def __post_init__(self):
        _require(self.service_estimate is None or self.service_estimate > 0,
                 "batch.state.service_estimate must be > 0 (or null)")
        _require(self.size is None or self.size >= 1,
                 "batch.state.size must be >= 1 (or null)")
        if self.domain_estimates is not None:
            de = _int_float_pairs(self.domain_estimates,
                                  "batch.state.domain_estimates")
            if de is not None:
                for dom, est in de:
                    _require(dom >= 0 and est > 0,
                             "batch.state.domain_estimates entries need "
                             "domain >= 0 and estimate > 0")
            object.__setattr__(self, "domain_estimates", de)

    @classmethod
    def from_governor(cls, batcher) -> "BatchStateSpec":
        """Snapshot a live ``control.BatchGovernor``'s learned state."""
        if not hasattr(batcher, "service_estimate"):
            raise SpecError(
                f"{type(batcher).__name__} is no BatchGovernor "
                "(no service_estimate to snapshot)")
        domains = sorted(batcher.domain_service_estimates().items())
        return cls(service_estimate=batcher.service_estimate,
                   size=int(batcher.size),
                   domain_estimates=tuple(domains) or None)

    def to_dict(self) -> dict[str, Any]:
        return {"service_estimate": self.service_estimate,
                "size": self.size,
                "domain_estimates": (None if self.domain_estimates is None
                                     else [list(p)
                                           for p in self.domain_estimates])}

    @classmethod
    def from_dict(cls, d: dict,
                  where: str = "batch.state") -> "BatchStateSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """Batch-grab policy (``Executor(batch=...)``).

    ``fixed``    — a static grab limit of ``size`` tasks (1 = the paper's
                   one-task grabs).
    ``governed`` — ``control.BatchGovernor(target_service, batch_min,
                   batch_cap, ema, init_size)``: budgeted continuous
                   batching adapted from measured per-batch service.

    ``per_domain`` (governed only) keeps one service EMA per source queue
    under the same global ``target_service`` budget, so each queue's grab
    width tracks its own cost mix.  ``state`` restores a checkpointed
    governor's EMAs warm.
    """

    KINDS = ("fixed", "governed")

    kind: str = "fixed"
    size: int = 1
    target_service: float = 8.0
    batch_min: int = 1
    batch_cap: int = 8
    ema: float = 0.25
    init_size: int = 1
    per_domain: bool = False
    state: Optional[BatchStateSpec] = None

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"batch.kind {self.kind!r} not in {self.KINDS}")
        _require(self.size >= 1, "batch.size must be >= 1")
        _require(self.target_service > 0, "batch.target_service must be > 0")
        _require(1 <= self.batch_min <= self.batch_cap,
                 "need 1 <= batch.batch_min <= batch.batch_cap")
        _require(not (self.per_domain and self.kind != "governed"),
                 "batch.per_domain requires kind 'governed'")
        _require(self.state is None or self.kind == "governed",
                 "batch.state requires kind 'governed' (nothing to restore)")

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "size": self.size,
                "target_service": self.target_service,
                "batch_min": self.batch_min, "batch_cap": self.batch_cap,
                "ema": self.ema, "init_size": self.init_size,
                "per_domain": self.per_domain,
                "state": None if self.state is None else self.state.to_dict()}

    @classmethod
    def from_dict(cls, d: dict, where: str = "batch") -> "BatchSpec":
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        st = kw.pop("state", None)
        kw["state"] = (None if st is None
                       else BatchStateSpec.from_dict(st, f"{where}.state"))
        return _construct(cls, kw, where)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Trace recording declared in the spec (``trace.TraceRecorder``).

    ``record=True`` attaches a recorder at build time (``Built.recorder``);
    ``segment_records=N`` additionally streams rotating JSONL segments to
    the ``trace_path`` passed to ``build`` (long-running-server export).
    """

    record: bool = False
    segment_records: Optional[int] = None

    def __post_init__(self):
        _require(self.segment_records is None or self.segment_records >= 1,
                 "trace.segment_records must be >= 1 (or null)")

    def to_dict(self) -> dict[str, Any]:
        return {"record": self.record,
                "segment_records": self.segment_records}

    @classmethod
    def from_dict(cls, d: dict, where: str = "trace") -> "TraceSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Serving topology for ``serving.ServingEngine(spec=...)``.

    The runtime half (queues, governor, penalty, batching, control) lives
    in the owning ``RuntimeSpec``; this block only adds what serving
    itself needs: replica count, cache arena length, and the routing
    policy name.  Consistency rule (checked by the engine): ``single_queue``
    needs ``num_domains == 1`` with every worker pinned to domain 0, any
    other policy needs ``num_domains == num_replicas``.
    """

    POLICIES = ("locality", "round_robin", "single_queue")

    num_replicas: int = 2
    max_seq: int = 128
    policy: str = "locality"

    def __post_init__(self):
        _require(self.num_replicas >= 1, "serving.num_replicas must be >= 1")
        _require(self.max_seq >= 1, "serving.max_seq must be >= 1")
        _require(self.policy in self.POLICIES,
                 f"serving.policy {self.policy!r} not in {self.POLICIES}")

    def to_dict(self) -> dict[str, Any]:
        return {"num_replicas": self.num_replicas, "max_seq": self.max_seq,
                "policy": self.policy}

    @classmethod
    def from_dict(cls, d: dict, where: str = "serving") -> "ServingSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Locality-domain distance tree (``repro.topology.DistanceMatrix``).

    ``flat``    — every pair of domains one ``near`` hop apart: the seed
                  repo's implicit machine, kept bit-identical (no topology
                  block and a flat block build the same executor).
    ``grouped`` — a two-level socket/domain tree: ``groups`` lists the
                  domain count per socket; intra-socket links cost ``near``,
                  cross-socket links ``far``.
    ``pods``    — ``num_pods`` pods of ``domains_per_pod`` domains with the
                  cross-pod distance derived from
                  ``core.topology.tpu_topology``'s ``remote_factor``
                  (``far = near / remote_factor``); ``far`` is ignored.
    """

    KINDS = ("flat", "grouped", "pods")

    kind: str = "flat"
    groups: Optional[tuple[int, ...]] = None
    num_pods: int = 2
    domains_per_pod: int = 2
    near: float = 1.0
    far: float = 4.0

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"topology.kind {self.kind!r} not in {self.KINDS}")
        _require(self.near > 0, "topology.near must be > 0")
        _require(self.far >= self.near,
                 "topology.far must be >= topology.near")
        _require(self.num_pods >= 1, "topology.num_pods must be >= 1")
        _require(self.domains_per_pod >= 1,
                 "topology.domains_per_pod must be >= 1")
        if self.kind == "grouped":
            gs = self.groups
            if (not isinstance(gs, (list, tuple)) or not gs
                    or any(isinstance(g, bool) or not isinstance(g, int)
                           or g < 1 for g in gs)):
                raise SpecError("topology.groups must be a non-empty list of "
                                f"positive ints for kind 'grouped', got {gs!r}")
            object.__setattr__(self, "groups", tuple(int(g) for g in gs))
        else:
            _require(self.groups is None,
                     f"topology.groups only applies to kind 'grouped'")

    def declared_domains(self) -> Optional[int]:
        """Domain count this topology pins (None for flat, which adapts to
        the owning spec's ``num_domains``)."""
        if self.kind == "grouped":
            return sum(self.groups)
        if self.kind == "pods":
            return self.num_pods * self.domains_per_pod
        return None

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind,
                "groups": None if self.groups is None else list(self.groups),
                "num_pods": self.num_pods,
                "domains_per_pod": self.domains_per_pod,
                "near": self.near, "far": self.far}

    @classmethod
    def from_dict(cls, d: dict, where: str = "topology") -> "TopologySpec":
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        if kw.get("groups") is not None:
            gs = kw["groups"]
            if not isinstance(gs, (list, tuple)):
                raise SpecError(f"{where}.groups: expected a list of ints, "
                                f"got {gs!r}")
            kw["groups"] = tuple(gs)
        return _construct(cls, kw, where)


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability declared in the spec (``repro.obs``).

    ``enabled=True`` attaches a live ``repro.obs.Observation`` at build
    time (``Built.obs``): a deterministic metrics registry (sized by the
    ``hist_*`` bucket ladder) plus ``Observation.report(trace)`` for the
    post-hoc span/histogram/percentile pipeline.  ``profile=True``
    additionally hands the executor a ``HotPathProfiler`` — opt-in
    ``perf_counter_ns`` timers around the four scheduling hot paths
    (submit-route, steal-scan, batch-grab, event-append), the substrate of
    ``benchmarks/scheduler_overhead.py``.

    Observation is passive by contract: an obs-enabled build produces
    bit-identical ``RuntimeStats`` and replays to an obs-disabled one
    (gated in ``tests/test_obs.py``).  Trace headers record this block as
    schema v4 so an observed run names how it was observed.
    """

    enabled: bool = False
    profile: bool = False
    hist_lo: float = 0.5
    hist_growth: float = 2.0
    hist_buckets: int = 24

    def __post_init__(self):
        _require(not (self.profile and not self.enabled),
                 "obs.profile requires obs.enabled (timers need a live "
                 "observation to report into)")
        _require(self.hist_lo > 0, "obs.hist_lo must be > 0")
        _require(self.hist_growth > 1.0, "obs.hist_growth must be > 1")
        _require(self.hist_buckets >= 1, "obs.hist_buckets must be >= 1")

    def to_dict(self) -> dict[str, Any]:
        return {"enabled": self.enabled, "profile": self.profile,
                "hist_lo": self.hist_lo, "hist_growth": self.hist_growth,
                "hist_buckets": self.hist_buckets}

    @classmethod
    def from_dict(cls, d: dict, where: str = "obs") -> "ObsSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """The top of the tree: one value that names a whole runtime system."""

    num_domains: int = 4
    worker_domains: Optional[tuple[int, ...]] = None
    steal_order: str = "cyclic"
    pool_cap: Optional[int] = 256
    seed: int = 0
    record_events: bool = True
    event_maxlen: int = 65536
    penalty: PenaltySpec = PenaltySpec()
    governor: GovernorSpec = GovernorSpec()
    router: RouterSpec = RouterSpec()
    batch: BatchSpec = BatchSpec()
    trace: TraceSpec = TraceSpec()
    obs: ObsSpec = ObsSpec()
    serving: Optional[ServingSpec] = None
    topology: Optional[TopologySpec] = None

    def __post_init__(self):
        _require(self.num_domains >= 1, "num_domains must be >= 1")
        if self.topology is not None:
            declared = self.topology.declared_domains()
            _require(declared is None or declared == self.num_domains,
                     f"topology declares {declared} domains but spec has "
                     f"num_domains={self.num_domains}")
        _require(self.pool_cap is None or self.pool_cap >= 1,
                 "pool_cap must be >= 1 (or null)")
        if self.worker_domains is not None:
            wd = tuple(int(d) for d in self.worker_domains)
            object.__setattr__(self, "worker_domains", wd)
            for d in wd:
                _require(0 <= d < self.num_domains,
                         f"worker domain {d} outside {self.num_domains} "
                         "domains")
        # steal_order is validated against DomainQueues.STEAL_ORDERS at
        # build time; keep the model layer import-free of the runtime.
        _require(isinstance(self.steal_order, str) and bool(self.steal_order),
                 "steal_order must be a non-empty string")

    # -- construction (implemented in repro.spec.build) ----------------------
    def build(self, **overrides):
        """Build the declared system: returns a ``Built`` bundle with the
        wired ``executor`` plus any ``control`` loop / trace ``recorder``.
        See ``repro.spec.build.build`` for the build-time overrides
        (``handler``, ``batch_handler``, ``steal_penalty``, ``governor``,
        ``trace_path``)."""
        from .build import build
        return build(self, **overrides)

    def build_engine(self, model, params, **kwargs):
        """Build the declared ``serving.ServingEngine`` over ``model`` —
        requires a ``serving`` block."""
        from ..serving.engine import ServingEngine
        return ServingEngine(model, params, spec=self, **kwargs)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {
            "spec_version": SPEC_VERSION,
            "num_domains": self.num_domains,
            "worker_domains": (None if self.worker_domains is None
                               else list(self.worker_domains)),
            "steal_order": self.steal_order,
            "pool_cap": self.pool_cap,
            "seed": self.seed,
            "record_events": self.record_events,
            "event_maxlen": self.event_maxlen,
            "penalty": self.penalty.to_dict(),
            "governor": self.governor.to_dict(),
            "router": self.router.to_dict(),
            "batch": self.batch.to_dict(),
            "trace": self.trace.to_dict(),
            "obs": self.obs.to_dict(),
            "serving": (None if self.serving is None
                        else self.serving.to_dict()),
            "topology": (None if self.topology is None
                         else self.topology.to_dict()),
        }

    @classmethod
    def from_dict(cls, d: dict, where: str = "spec") -> "RuntimeSpec":
        if not isinstance(d, dict):
            raise SpecError(f"{where}: expected an object, "
                            f"got {type(d).__name__}")
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise SpecError(f"{where}: spec_version {version!r} != "
                            f"supported {SPEC_VERSION}")
        _reject_unknown(cls, d, where)
        kw: dict[str, Any] = _coerce_scalars(cls, d, where)
        if kw.get("worker_domains") is not None:
            wd = kw["worker_domains"]
            if (not isinstance(wd, (list, tuple))
                    or any(isinstance(x, bool) or not isinstance(x, int)
                           for x in wd)):
                raise SpecError(f"{where}.worker_domains: expected a list "
                                f"of ints, got {wd!r}")
            kw["worker_domains"] = tuple(int(x) for x in wd)
        for name, sub in (("penalty", PenaltySpec), ("governor", GovernorSpec),
                          ("router", RouterSpec), ("batch", BatchSpec),
                          ("trace", TraceSpec), ("obs", ObsSpec)):
            if name in kw:
                kw[name] = sub.from_dict(kw[name], f"{where}.{name}")
        if kw.get("serving") is not None:
            kw["serving"] = ServingSpec.from_dict(kw["serving"],
                                                  f"{where}.serving")
        if kw.get("topology") is not None:
            kw["topology"] = TopologySpec.from_dict(kw["topology"],
                                                    f"{where}.topology")
        return _construct(cls, kw, where)

    def to_json(self) -> str:
        """Canonical JSON form (stable key order — golden-file friendly)."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "RuntimeSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"spec is not valid JSON: {e}") from e
        return cls.from_dict(data)


def load(path) -> RuntimeSpec:
    """Read a ``RuntimeSpec`` from a JSON file."""
    with open(path, "r", encoding="utf-8") as fh:
        return RuntimeSpec.from_json(fh.read())


def dump(spec: RuntimeSpec, path) -> str:
    """Write ``spec`` to ``path`` in canonical JSON form; returns the path."""
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(spec.to_json())
    return path
