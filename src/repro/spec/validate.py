"""Validate checked-in spec files: parse, build, drive, replay.

    PYTHONPATH=src python -m repro.spec.validate specs [specs/experiments ...]

For every ``*.json`` under the given paths (directories are globbed,
files taken as-is) this:

  1. parses it strictly (``RuntimeSpec.from_json`` — unknown fields or an
     unknown ``spec_version`` fail the run);
  2. proves the canonical round-trip: ``from_json(to_json(s)) == s``;
  3. builds the declared system (executor + control loop + recorder);
  4. drives a small seeded hot-skew workload through it while recording;
  5. serializes the trace and replays it *from the embedded header spec
     alone* (``replay(trace)``, no executor argument), asserting the
     replayed ``RuntimeStats`` are bit-identical to the recorded ones;
  6. model-checks the trace (``repro.check``): the recorded schedule must
     be structurally legal — FIFO per domain queue, steal edges the header
     permits, monotone steps, exact-once submit/exec — not just
     stats-identical under replay.

*Experiment* files (``repro.spec.ExperimentSpec``: a ``workload`` block
next to the ``policy``, e.g. ``specs/experiments/*.json``) are detected by
shape and validated end to end instead: parse strictly, round-trip
exactly, then ``run()`` the *declared* workload (all repeats) and assert
every recorded trace replays bit-identically from its own header.

Exit code 0 means every file names a buildable, exactly-reproducible
system — the CI gate behind ``make spec`` / ``make experiments``.
"""
from __future__ import annotations

import glob
import json
import os
import sys

from .experiments import ExperimentSpec
from .model import RuntimeSpec, SpecError


def probe_trace(spec: RuntimeSpec):
    """Build ``spec``'s system, drive the standard seeded hot-skew probe
    workload through it while recording, and return the trace after a
    JSONL round-trip — the shared raw material for header-only replay
    validation here and for model-checking in ``benchmarks.sentinel``."""
    from ..trace import (TraceRecorder, drive, hot_skew, loads_lines,
                         dumps_lines, poisson)

    built = spec.build()
    ex = built.executor
    recorder = built.recorder
    if recorder is None:
        recorder = TraceRecorder()
        recorder.attach(ex)
    wl = hot_skew(poisson(rate=spec.num_domains, steps=12,
                          num_domains=spec.num_domains, seed=spec.seed + 1),
                  hot_domain=0, p_hot=0.75, seed=spec.seed + 1)
    drive(ex, wl)
    return loads_lines(dumps_lines(recorder.finish()))


def model_check(trace, label: str) -> None:
    """Run ``repro.check``'s trace model checker; raise ``SpecError`` on
    any structural-legality violation (named rule included)."""
    from ..check import check_trace

    result = check_trace(trace, path=label)
    if not result.ok:
        raise SpecError(
            "trace model checker found an illegal schedule: "
            + "; ".join(str(v) for v in result.violations[:5]))


def validate_spec(spec: RuntimeSpec) -> dict[str, float]:
    """Round-trip + probe-drive + header-only replay + model check for one
    spec.

    Returns the recorded stats snapshot.  Raises (``SpecError`` /
    ``AssertionError``) on any fidelity failure.
    """
    from ..trace import replay

    if spec.from_json(spec.to_json()) != spec:
        raise SpecError("canonical round-trip changed the spec")

    trace = probe_trace(spec)
    if trace.meta.get("spec") is None:
        raise SpecError("built executor did not embed its spec in the "
                        "trace header")
    replay(trace, assert_match=True)             # header-only reconstruction
    model_check(trace, "<probe>")                # structural legality
    return trace.stats


def validate_experiment(exp: ExperimentSpec) -> dict[str, float]:
    """Round-trip + run + header-only replay for one experiment spec.

    Unlike ``validate_spec`` (which drives a synthetic probe workload),
    this runs the experiment's *declared* workload — the whole point of an
    experiment file — and checks every repeat's trace replays
    bit-identically through the JSONL wire format.  Returns the first
    repeat's recorded stats.
    """
    from ..trace import dumps_lines, loads_lines, replay

    if exp.from_json(exp.to_json()) != exp:
        raise SpecError("canonical round-trip changed the experiment")
    result = exp.run()
    for r, run in enumerate(result.runs):
        trace = loads_lines(dumps_lines(run.trace))
        if trace.meta.get("spec") is None:
            raise SpecError("experiment executor did not embed its spec in "
                            "the trace header")
        if trace.meta.get("experiment") is None:
            raise SpecError("experiment executor did not embed the "
                            "experiment in the trace header")
        replay(trace, assert_match=True)         # header-only reconstruction
        model_check(trace, f"<repeat {r}>")      # structural legality
    return result.primary.trace.stats


def validate_file(path) -> tuple[str, dict[str, float]]:
    """Validate one JSON file, dispatching on shape: a ``workload`` block
    marks an ``ExperimentSpec``, anything else is parsed as a bare policy
    ``RuntimeSpec`` (whose strict parser also reports malformed JSON).
    Returns ``(kind_label, recorded_stats)``."""
    with open(path, "r", encoding="utf-8") as fh:
        text = fh.read()
    try:
        data = json.loads(text)
    except json.JSONDecodeError:
        data = None                    # let the strict spec parser report it
    if isinstance(data, dict) and "workload" in data:
        return "experiment ", validate_experiment(
            ExperimentSpec.from_dict(data))
    return "", validate_spec(RuntimeSpec.from_json(text))


def iter_spec_files(paths) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(sorted(glob.glob(os.path.join(p, "*.json"))))
        else:
            out.append(p)
    return out


def main(argv: list[str]) -> int:
    paths = iter_spec_files(argv or ["specs"])
    if not paths:
        print("no spec files found", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        try:
            kind, stats = validate_file(path)
            print(f"{path}: {kind}OK (executed={stats['executed']:.0f}, "
                  f"local={stats['local_fraction']:.2f}, "
                  f"steal={stats['steal_fraction']:.2f})")
        except Exception as e:                    # report all files, then fail
            failures += 1
            print(f"{path}: FAIL — {e}", file=sys.stderr)
    if failures:
        print(f"{failures}/{len(paths)} spec file(s) failed", file=sys.stderr)
        return 1
    print(f"all {len(paths)} spec file(s) parse, build, and replay "
          "bit-identically")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
