"""Declarative experiments: policy + workload + seeds in one JSON file.

``RuntimeSpec`` (PR 4) made the *policy* declarative, but an experiment —
the unit behind every figure-style run — is policy **and** workload **and**
run parameters, and those were still glued together ad hoc inside each
benchmark script.  ``ExperimentSpec`` closes that gap: a frozen block that
names the arrival process next to the scheduling policy, with the same
strict/exact ``to_json``/``from_json`` contract, so one reviewable JSON
file is a complete, bit-reproducible experiment runnable by
``benchmarks.run --experiment`` alone.

  experiment ingredient                   spec object
  --------------------------------------  --------------------------------
  scheduling policy (who steals, when)    ``RuntimeSpec`` (PR 4)
  arrival process + shape combinators     ``WorkloadSpec`` (+ ``SkewSpec``
  (``trace.workloads`` generators)        / ``CostsSpec``)
  run parameters                          ``repeats`` (seed-shifted
                                          re-runs), ``drain_budget``

``WorkloadSpec.build()`` constructs the ``trace.workloads`` value it names;
``ExperimentSpec.run()`` builds the policy, wires the declared workload
through ``trace.workloads.drive`` while recording, and returns per-repeat
stats + traces.  The recorded trace header embeds the experiment (on top of
schema v2's policy spec), so a trace file names not just the system but the
whole experiment that produced it.

A registry of named experiments (``experiment("replay_hot_skew")`` …)
mirrors ``trace.workloads.standard_scenarios`` and pins the exact workload
constructions the benchmarks historically inlined — the benchmarks are now
thin drivers over these definitions, and ``specs/experiments/*.json``
golden-pins each one.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

from .model import (RuntimeSpec, SpecError, _coerce_scalars, _construct,
                    _reject_unknown, _require)
from .registry import named

EXPERIMENT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class SkewSpec:
    """``trace.workloads.hot_skew`` combinator: re-home a ``p_hot``
    fraction of arrivals onto ``hot_domain`` (the paper's "one socket owns
    the data" pathology)."""

    hot_domain: int = 0
    p_hot: float = 0.8
    seed: int = 0

    def __post_init__(self):
        _require(self.hot_domain >= 0, "skew.hot_domain must be >= 0")
        _require(0.0 <= self.p_hot <= 1.0, "skew.p_hot must be in [0, 1]")

    def to_dict(self) -> dict[str, Any]:
        return {"hot_domain": self.hot_domain, "p_hot": self.p_hot,
                "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict, where: str = "skew") -> "SkewSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class CostsSpec:
    """``trace.workloads.lognormal_costs`` combinator: heavy-tailed service
    costs ~ LogNormal(ln ``median``, ``sigma``) (long prefills)."""

    median: float = 1.0
    sigma: float = 0.75
    seed: int = 0

    def __post_init__(self):
        _require(self.median > 0, "costs.median must be positive")
        _require(self.sigma >= 0, "costs.sigma must be >= 0")

    def to_dict(self) -> dict[str, Any]:
        return {"median": self.median, "sigma": self.sigma, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict, where: str = "costs") -> "CostsSpec":
        _reject_unknown(cls, d, where)
        return _construct(cls, _coerce_scalars(cls, d, where), where)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A serializable name for one ``trace.workloads`` arrival stream.

    kind:
      ``poisson``       — steady traffic at ``rate`` arrivals/step.
      ``bursty``        — two-state MMPP (``rate_quiet``/``rate_storm``,
                          ``p_enter``/``p_exit`` sticky transitions).
      ``diurnal``       — sinusoidal day/night profile peaking at ``rate``
                          (``trough_frac``, ``periods``).
      ``uniform_waves`` / ``bursty_waves`` / ``skewed_waves``
                        — the online-runtime benchmark's historical wave
                          scenarios over ``n_tasks`` tasks
                          (``trace.workloads.benchmark_waves``).

    ``skew``/``costs`` apply the ``hot_skew``/``lognormal_costs``
    combinators, in that order (the order every benchmark uses).  Fields
    irrelevant to the chosen kind are ignored by ``build()`` but still
    serialized, so the canonical JSON form is shape-stable across kinds.
    """

    KINDS = ("poisson", "bursty", "diurnal",
             "uniform_waves", "bursty_waves", "skewed_waves")

    kind: str = "poisson"
    num_domains: int = 4
    steps: int = 48
    seed: int = 0
    rate: float = 4.0            # poisson rate / diurnal peak rate
    rate_quiet: float = 1.0      # bursty (MMPP) quiet-state rate
    rate_storm: float = 12.0     # bursty (MMPP) storm-state rate
    p_enter: float = 0.08
    p_exit: float = 0.25
    trough_frac: float = 0.1     # diurnal trough as a fraction of peak
    periods: float = 1.0
    cost: float = 1.0
    n_tasks: int = 400           # *_waves kinds
    skew: Optional[SkewSpec] = None
    costs: Optional[CostsSpec] = None

    def __post_init__(self):
        _require(self.kind in self.KINDS,
                 f"workload.kind {self.kind!r} not in {self.KINDS}")
        _require(self.num_domains >= 1, "workload.num_domains must be >= 1")
        _require(self.steps >= 1, "workload.steps must be >= 1")
        _require(self.n_tasks >= 1, "workload.n_tasks must be >= 1")
        _require(self.rate > 0, "workload.rate must be positive")
        _require(self.rate_quiet > 0 and self.rate_storm > 0,
                 "workload.rate_quiet/rate_storm must be positive")
        _require(0.0 < self.p_enter <= 1.0 and 0.0 < self.p_exit <= 1.0,
                 "workload.p_enter/p_exit must be in (0, 1]")
        _require(0.0 <= self.trough_frac <= 1.0,
                 "workload.trough_frac must be in [0, 1]")
        _require(self.periods > 0, "workload.periods must be positive")
        _require(self.cost > 0, "workload.cost must be positive")
        _require(self.skew is None or self.skew.hot_domain < self.num_domains,
                 f"workload.skew.hot_domain outside {self.num_domains} "
                 "domains")

    def build(self):
        """The ``trace.workloads.Workload`` this spec names."""
        from ..trace import workloads as W  # lazy: trace imports runtime
        k = self.kind
        if k == "poisson":
            wl = W.poisson(rate=self.rate, steps=self.steps,
                           num_domains=self.num_domains, seed=self.seed,
                           cost=self.cost)
        elif k == "bursty":
            wl = W.bursty(rate_quiet=self.rate_quiet,
                          rate_storm=self.rate_storm, steps=self.steps,
                          num_domains=self.num_domains, seed=self.seed,
                          p_enter=self.p_enter, p_exit=self.p_exit,
                          cost=self.cost)
        elif k == "diurnal":
            wl = W.diurnal(peak_rate=self.rate, steps=self.steps,
                           num_domains=self.num_domains, seed=self.seed,
                           trough_frac=self.trough_frac,
                           periods=self.periods, cost=self.cost)
        else:
            wl = W.benchmark_waves(self.n_tasks, self.num_domains,
                                   self.seed)[k[:-len("_waves")]]
        if self.skew is not None:
            wl = W.hot_skew(wl, hot_domain=self.skew.hot_domain,
                            p_hot=self.skew.p_hot, seed=self.skew.seed)
        if self.costs is not None:
            wl = W.lognormal_costs(wl, median=self.costs.median,
                                   sigma=self.costs.sigma,
                                   seed=self.costs.seed)
        return wl

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "num_domains": self.num_domains,
                "steps": self.steps, "seed": self.seed, "rate": self.rate,
                "rate_quiet": self.rate_quiet, "rate_storm": self.rate_storm,
                "p_enter": self.p_enter, "p_exit": self.p_exit,
                "trough_frac": self.trough_frac, "periods": self.periods,
                "cost": self.cost, "n_tasks": self.n_tasks,
                "skew": None if self.skew is None else self.skew.to_dict(),
                "costs": None if self.costs is None else self.costs.to_dict()}

    @classmethod
    def from_dict(cls, d: dict, where: str = "workload") -> "WorkloadSpec":
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        sk = kw.pop("skew", None)
        kw["skew"] = (None if sk is None
                      else SkewSpec.from_dict(sk, f"{where}.skew"))
        co = kw.pop("costs", None)
        kw["costs"] = (None if co is None
                       else CostsSpec.from_dict(co, f"{where}.costs"))
        return _construct(cls, kw, where)


@dataclasses.dataclass
class RunResult:
    """One repeat of an experiment: the live system plus its record."""

    seed: int                    # the policy seed this repeat ran under
    built: Any                   # repro.spec.Built
    trace: Any                   # repro.trace.Trace
    stats: dict[str, float]

    @property
    def executor(self):
        return self.built.executor


AGGREGATE_STATS = ("mean", "min", "max", "stdev")


def aggregate_runs(stats: list[dict]) -> dict[str, dict[str, float]]:
    """Fig. 4-style run-to-run aggregates across seed-shifted repeats.

    For every numeric key shared by all the per-run stats dicts, the exact
    mean / min / max / population stdev over the repeats (stdev 0 for a
    single run — a degenerate ladder is still well-defined).  Booleans are
    excluded (``replay_exact`` is a gate, not a measurement); key order is
    sorted, so the output is deterministic and golden-file friendly.
    """
    if not stats:
        return {}
    keys = set(stats[0])
    for s in stats[1:]:
        keys &= set(s)
    out: dict[str, dict[str, float]] = {}
    for key in sorted(keys):
        vals = [s[key] for s in stats]
        if not all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in vals):
            continue
        n = len(vals)
        mean = sum(vals) / n
        out[key] = {"mean": mean, "min": min(vals), "max": max(vals),
                    "stdev": (sum((v - mean) ** 2 for v in vals) / n) ** 0.5}
    return out


@dataclasses.dataclass
class ExperimentResult:
    """All repeats of one ``ExperimentSpec.run()``."""

    experiment: "ExperimentSpec"
    workload: Any                # the built trace.workloads.Workload
    runs: list[RunResult]

    @property
    def primary(self) -> RunResult:
        """The first (un-shifted-seed) repeat."""
        return self.runs[0]

    def aggregates(self) -> dict[str, dict[str, float]]:
        """``aggregate_runs`` over this result's per-repeat stats — the
        variability ladder the repeated experiments feed into
        ``BENCH_experiments.json`` (and the sentinel's tolerance choices).
        """
        return aggregate_runs([r.stats for r in self.runs])


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Policy × workload × run parameters: one serializable experiment.

    ``repeats`` re-runs the same workload under seed-shifted copies of the
    policy (repeat *r* uses ``policy.seed + r`` — the run-to-run
    variability axis of the paper's Fig. 4); ``drain_budget`` bounds the
    post-arrival drain (``trace.workloads.drive``), failing loudly when a
    policy cannot drain the declared workload.
    """

    policy: RuntimeSpec
    workload: WorkloadSpec
    repeats: int = 1
    drain_budget: Optional[int] = None

    def __post_init__(self):
        _require(self.repeats >= 1, "experiment.repeats must be >= 1")
        _require(self.drain_budget is None or self.drain_budget >= 1,
                 "experiment.drain_budget must be >= 1 (or null)")
        _require(isinstance(self.policy, RuntimeSpec),
                 "experiment.policy must be a RuntimeSpec")
        _require(isinstance(self.workload, WorkloadSpec),
                 "experiment.workload must be a WorkloadSpec")
        _require(self.policy.num_domains == self.workload.num_domains,
                 f"experiment.workload declares "
                 f"{self.workload.num_domains} domains but the policy "
                 f"declares {self.policy.num_domains}")

    # -- execution -----------------------------------------------------------
    def build(self, repeat: int = 0, **overrides):
        """Build repeat ``repeat``'s system (a ``Built`` bundle; the policy
        seed is shifted by ``repeat``).  The experiment is stamped onto the
        executor so recorded trace headers name it."""
        policy = (self.policy if repeat == 0 else dataclasses.replace(
            self.policy, seed=self.policy.seed + repeat))
        return policy.build(experiment=self, **overrides)

    def run(self, *, trace_path=None, payload=None) -> ExperimentResult:
        """Execute the experiment: build each repeat's declared system,
        drive the declared workload through it (``trace.workloads.drive``)
        while recording, and return per-repeat stats + traces.

        ``trace_path`` is forwarded to ``build`` for policies that stream
        rotating trace segments (repeat *r* streams into
        ``<trace_path>/run-<r>`` when ``repeats > 1``).
        """
        from ..trace import TraceRecorder, drive  # lazy: avoid import cycle
        wl = self.workload.build()
        runs: list[RunResult] = []
        for r in range(self.repeats):
            path = trace_path
            if path is not None and self.repeats > 1:
                path = os.path.join(str(path), f"run-{r}")
            built = self.build(repeat=r, trace_path=path)
            recorder = built.recorder
            if recorder is None:
                recorder = TraceRecorder()
                recorder.attach(built.executor)
            drive(built.executor, wl, payload=payload,
                  drain_budget=self.drain_budget)
            runs.append(RunResult(seed=self.policy.seed + r, built=built,
                                  trace=recorder.finish(),
                                  stats=built.executor.metrics.snapshot()))
        return ExperimentResult(experiment=self, workload=wl, runs=runs)

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {"experiment_version": EXPERIMENT_VERSION,
                "policy": self.policy.to_dict(),
                "workload": self.workload.to_dict(),
                "repeats": self.repeats,
                "drain_budget": self.drain_budget}

    @classmethod
    def from_dict(cls, d: dict, where: str = "experiment") -> "ExperimentSpec":
        if not isinstance(d, dict):
            raise SpecError(f"{where}: expected an object, "
                            f"got {type(d).__name__}")
        d = dict(d)
        version = d.pop("experiment_version", EXPERIMENT_VERSION)
        if version != EXPERIMENT_VERSION:
            raise SpecError(f"{where}: experiment_version {version!r} != "
                            f"supported {EXPERIMENT_VERSION}")
        _reject_unknown(cls, d, where)
        kw = _coerce_scalars(cls, d, where)
        if "policy" not in kw or "workload" not in kw:
            raise SpecError(f"{where}: needs both 'policy' and 'workload'")
        kw["policy"] = RuntimeSpec.from_dict(kw["policy"], f"{where}.policy")
        kw["workload"] = WorkloadSpec.from_dict(kw["workload"],
                                                f"{where}.workload")
        return _construct(cls, kw, where)

    def to_json(self) -> str:
        """Canonical JSON form (stable key order — golden-file friendly)."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"experiment is not valid JSON: {e}") from e
        return cls.from_dict(data)


def load_experiment(path) -> ExperimentSpec:
    """Read an ``ExperimentSpec`` from a JSON file."""
    with open(path, "r", encoding="utf-8") as fh:
        return ExperimentSpec.from_json(fh.read())


def dump_experiment(exp: ExperimentSpec, path) -> str:
    """Write ``exp`` to ``path`` in canonical JSON form; returns the path."""
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(exp.to_json())
    return path


# -- workload families (the benchmarks' historical constructions) ------------

def standard_workloads(num_domains: int = 4, steps: int = 48,
                       seed: int = 0) -> dict[str, WorkloadSpec]:
    """``trace.workloads.standard_scenarios`` as declarative specs —
    ``standard_workloads(d, s, k)[n].build()`` equals
    ``standard_scenarios(d, s, k)[n]`` arrival-for-arrival."""
    d = num_domains
    return {
        "poisson": WorkloadSpec(kind="poisson", num_domains=d, steps=steps,
                                seed=seed, rate=float(d)),
        "bursty": WorkloadSpec(kind="bursty", num_domains=d, steps=steps,
                               seed=seed + 1, rate_quiet=d * 0.25,
                               rate_storm=d * 3.0),
        "diurnal": WorkloadSpec(kind="diurnal", num_domains=d, steps=steps,
                                seed=seed + 2, rate=d * 2.0),
        "hot_skew": WorkloadSpec(kind="poisson", num_domains=d, steps=steps,
                                 seed=seed + 3, rate=float(d),
                                 skew=SkewSpec(hot_domain=0, p_hot=0.8,
                                               seed=seed + 3)),
    }


def runtime_workloads(n_tasks: int = 400, num_domains: int = 4,
                      seed: int = 0) -> dict[str, WorkloadSpec]:
    """``benchmarks.runtime_throughput``'s wave scenarios as specs."""
    return {scen: WorkloadSpec(kind=f"{scen}_waves", num_domains=num_domains,
                               seed=seed, n_tasks=n_tasks)
            for scen in ("uniform", "bursty", "skewed")}


def replay_workloads(steps: int = 48, seed: int = 0,
                     num_domains: int = 4) -> dict[str, WorkloadSpec]:
    """``benchmarks.trace_replay``'s scenarios: every standard scenario
    with heavy-tailed lognormal costs (median 2), cost seeds by scenario
    position — the exact historical construction."""
    std = standard_workloads(num_domains, steps, seed)
    return {name: dataclasses.replace(
        wl, costs=CostsSpec(median=2.0, sigma=0.75, seed=seed + i))
        for i, (name, wl) in enumerate(std.items())}


def control_workloads(steps: int = 48, seed: int = 0,
                      num_domains: int = 4) -> dict[str, WorkloadSpec]:
    """``benchmarks.control_plane``'s scenarios: the storm-prone subset of
    the standard set, heavy-tailed costs, cost seeds by subset position."""
    std = standard_workloads(num_domains, steps, seed)
    return {name: dataclasses.replace(
        std[name], costs=CostsSpec(median=2.0, sigma=0.75, seed=seed + i))
        for i, name in enumerate(("bursty", "diurnal", "hot_skew"))}


# -- named experiment registry ------------------------------------------------

def runtime_experiments(n_tasks: int = 400,
                        seed: int = 0) -> dict[str, ExperimentSpec]:
    """One experiment per online-runtime wave scenario (the benchmark's
    "locality" arm, ``paper_cyclic``, as the canonical policy)."""
    policy = dataclasses.replace(named("paper_cyclic"), seed=seed)
    return {name: ExperimentSpec(policy=policy, workload=wl)
            for name, wl in runtime_workloads(n_tasks=n_tasks,
                                              seed=seed).items()}


def replay_experiments(steps: int = 48,
                       seed: int = 0) -> dict[str, ExperimentSpec]:
    """One experiment per trace-replay scenario under the shared recording
    baseline (``replay_baseline``: greedy + constant re-prefill penalty +
    trace recording on)."""
    policy = dataclasses.replace(named("replay_baseline"), seed=seed)
    return {name: ExperimentSpec(policy=policy, workload=wl)
            for name, wl in replay_workloads(steps=steps, seed=seed).items()}


def control_experiments(steps: int = 48,
                        seed: int = 0) -> dict[str, ExperimentSpec]:
    """One experiment per control-plane scenario under the full controlled
    policy (``controlled_replay``)."""
    policy = dataclasses.replace(named("controlled_replay"), seed=seed)
    return {name: ExperimentSpec(policy=policy, workload=wl)
            for name, wl in control_workloads(steps=steps, seed=seed).items()}


def topology_workloads(steps: int = 48, seed: int = 0,
                       num_domains: int = 8) -> dict[str, WorkloadSpec]:
    """``benchmarks.topology_locality``'s scenarios: the storm-prone
    hot-skew and bursty arrivals scaled up to the 8-domain two-socket/pod
    machine the topology policies declare."""
    std = standard_workloads(num_domains, steps, seed)
    return {name: std[name] for name in ("hot_skew", "bursty")}


def topology_experiments(steps: int = 48,
                         seed: int = 0) -> dict[str, ExperimentSpec]:
    """The flat-vs-hierarchical matrix: each topology policy (flat
    baseline, two-level sockets, adaptive pods) on each topology workload
    — the declarative arms of ``benchmarks.topology_locality``."""
    reg: dict[str, ExperimentSpec] = {}
    for pol in ("topology_flat", "topology_two_level",
                "topology_pods_adaptive"):
        policy = dataclasses.replace(named(pol), seed=seed)
        for name, wl in topology_workloads(steps=steps, seed=seed).items():
            reg[f"{pol}_{name}"] = ExperimentSpec(policy=policy, workload=wl)
    return reg


def variability_experiments(steps: int = 48, seed: int = 0,
                            repeats: int = 5) -> dict[str, ExperimentSpec]:
    """The run-to-run variability axis (paper Fig. 4): the storm-prone
    hot-skew workload under the canonical locality policy, re-run under
    ``repeats`` seed-shifted copies so ``ExperimentResult.aggregates()``
    yields a real mean/min/max/stdev ladder instead of a single point."""
    policy = dataclasses.replace(named("paper_cyclic"), seed=seed)
    wl = standard_workloads(4, steps, seed)["hot_skew"]
    return {"variability_hot_skew": ExperimentSpec(
        policy=policy, workload=wl, repeats=repeats)}


def _build_registry() -> dict[str, ExperimentSpec]:
    reg: dict[str, ExperimentSpec] = {}
    for name, wl in standard_workloads().items():
        reg[name] = ExperimentSpec(policy=named("paper_cyclic"), workload=wl)
    for name, exp in runtime_experiments().items():
        reg[f"runtime_{name}"] = exp
    for name, exp in replay_experiments().items():
        reg[f"replay_{name}"] = exp
    for name, exp in control_experiments().items():
        reg[f"control_{name}"] = exp
    reg.update(topology_experiments())
    reg.update(variability_experiments())
    return reg


_EXPERIMENTS: dict[str, ExperimentSpec] = _build_registry()


def experiment_names() -> tuple[str, ...]:
    """The registered experiment names, in registration order."""
    return tuple(_EXPERIMENTS)


def experiment(name: str) -> ExperimentSpec:
    """The registered ``ExperimentSpec`` for ``name`` (frozen — use
    ``dataclasses.replace`` to derive variants)."""
    try:
        return _EXPERIMENTS[name]
    except KeyError:
        raise SpecError(f"unknown experiment {name!r} "
                        f"(registered: {list(_EXPERIMENTS)})") from None
