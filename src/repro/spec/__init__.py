"""repro.spec — one declarative, serializable policy spec for the system.

Three PRs grew three construction surfaces: a 14-kwarg ``Executor``,
hand-spliced ``ControlLoop``/``TraceRecorder`` attachment, and a
``ServingEngine`` with its own private executor wiring.  The *policy
configuration* — queue topology, steal order, throttle — is the experiment
(Wittmann & Hager's locality-queue layer is exactly such a policy), so it
deserves a first-class representation.  This package is that
representation: a frozen dataclass tree that fully names a runtime system
and is the single construction API for runtime + trace + control + serving.

  paper concept (§)                      spec object
  -------------------------------------  ---------------------------------
  the experiment = the policy            ``RuntimeSpec``: domains, worker
  (queue topology + steal rule, §2)      map, steal order, pool cap, seed
  steal governor choice (§2.2 vs §3.1)   ``GovernorSpec`` (+ ``BreakerSpec``
                                         decoration)
  nonlocal-access penalty (§1.4)         ``PenaltySpec`` — serializable, so
                                         a trace names its own cost model
  routing / batching policy knobs        ``RouterSpec`` / ``BatchSpec``
  record the run (trace schema v2)       ``TraceSpec``; the trace header
                                         embeds the whole spec, so
                                         ``replay(trace)`` needs no code
  replicas as domains                    ``ServingSpec`` +
                                         ``ServingEngine(spec=...)``

Usage::

    from repro import spec

    s = spec.named("controlled_replay")        # or spec.load("my.json")
    built = s.build()                          # executor + control + recorder
    ...  # drive built.executor; record via built.recorder
    print(s.to_json())                         # the policy as a JSON file

Raw constructor kwargs on ``Executor``/``ServingEngine`` remain as a thin
deprecated path for callables and tests; new configurations should be
specs (a JSON file, not a code change).
"""
from .build import (Built, build, build_governor, build_penalty,
                    build_topology, checkpoint)
from .experiments import (AGGREGATE_STATS, EXPERIMENT_VERSION, CostsSpec,
                          ExperimentResult, ExperimentSpec, RunResult,
                          SkewSpec, WorkloadSpec, aggregate_runs,
                          control_experiments, control_workloads,
                          dump_experiment, experiment, experiment_names,
                          load_experiment, replay_experiments,
                          replay_workloads, runtime_experiments,
                          runtime_workloads, standard_workloads,
                          topology_experiments, topology_workloads,
                          variability_experiments)
from .model import (SPEC_VERSION, BatchSpec, BatchStateSpec, BreakerSpec,
                    BreakerStateSpec, GovernorSpec, GovernorStateSpec,
                    ObsSpec, PenaltySpec, RouterSpec, RuntimeSpec,
                    ServingSpec, SpecError, TopologySpec, TraceSpec, dump,
                    load)
from .registry import named, policy_names

__all__ = [
    "Built", "build", "build_governor", "build_penalty", "build_topology",
    "checkpoint",
    "AGGREGATE_STATS", "EXPERIMENT_VERSION", "CostsSpec", "ExperimentResult",
    "ExperimentSpec", "RunResult", "SkewSpec", "WorkloadSpec",
    "aggregate_runs",
    "control_experiments", "control_workloads", "dump_experiment",
    "experiment", "experiment_names", "load_experiment",
    "replay_experiments", "replay_workloads", "runtime_experiments",
    "runtime_workloads", "standard_workloads",
    "topology_experiments", "topology_workloads", "variability_experiments",
    "SPEC_VERSION", "BatchSpec", "BatchStateSpec", "BreakerSpec",
    "BreakerStateSpec", "GovernorSpec", "GovernorStateSpec", "ObsSpec",
    "PenaltySpec", "RouterSpec", "RuntimeSpec", "ServingSpec", "SpecError",
    "TopologySpec", "TraceSpec", "dump", "load",
    "named", "policy_names",
]
