"""Build a live, fully wired runtime system from a ``RuntimeSpec``.

``build(spec)`` is the single construction path the spec API promises: it
creates the ``Executor``, installs the declared router / batch policy /
breaker through a ``repro.control.ControlLoop`` (the same splice points a
hand-wired control plane uses), stamps the spec onto the executor
(``Executor.spec`` — what the trace header embeds, making every recorded
run self-describing), and attaches a ``TraceRecorder`` last so a streamed
header names the effective, breaker-wrapped governor.

Build-time overrides carry the values a spec deliberately cannot hold —
callables and live objects:

    handler / batch_handler   task execution callbacks
    steal_penalty             a custom penalty fn (replaces ``PenaltySpec``;
                              the built executor then no longer embeds the
                              spec, since the spec would misname the run)
    governor                  a pre-built governor instance (e.g. a
                              ``MeasuredPenalty`` seeded from a trace) —
                              same embedding caveat
    trace_path                directory for streamed trace segments when
                              ``TraceSpec.segment_records`` is set

Everything a ``Built`` executor does is deterministic for the spec's seed,
so two builds of the same spec driven identically produce bit-identical
``RuntimeStats`` — the property that makes ``replay(trace)`` from an
embedded spec an exact reconstruction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from ..runtime import (AdaptiveSteal, Executor, GreedySteal, NoSteal,
                       StealGovernor, Task, Worker)
from .model import (BatchSpec, GovernorSpec, PenaltySpec, RouterSpec,
                    RuntimeSpec, SpecError)


@dataclasses.dataclass
class Built:
    """The live system a ``RuntimeSpec`` declares.

    ``executor`` is always present; ``control`` (a wired
    ``repro.control.ControlLoop``) exists when the spec declares a cost
    router, a governed batch, or a breaker; ``recorder`` (an attached
    ``repro.trace.TraceRecorder``) when ``TraceSpec.record`` is set.
    """

    spec: RuntimeSpec
    executor: Executor
    control: Optional[Any] = None      # repro.control.ControlLoop
    recorder: Optional[Any] = None     # repro.trace.TraceRecorder


def build_penalty(spec: PenaltySpec) -> Optional[Callable[[Task, Worker], float]]:
    """The ``Executor(steal_penalty=...)`` callable a ``PenaltySpec`` names."""
    if spec.kind == "none":
        return None
    value = spec.value
    if spec.kind == "constant":
        return lambda task, worker: value
    if spec.kind == "cost_factor":
        return lambda task, worker: value * task.cost
    # cost_if_homed: only a homed task (a cached prefix somewhere) pays to
    # migrate — the serving engine's re-prefill rule.
    return lambda task, worker: value * task.cost if task.home >= 0 else 0.0


def build_governor(spec: GovernorSpec) -> StealGovernor:
    """The *inner* governor (breaker decoration is applied by ``build``).

    A declared ``GovernorStateSpec`` supersedes the ``penalty_hint``/
    ``task_cost`` priors: the governor starts at the snapshotted learned
    estimates (checkpoint/restore), with the spec's ``ema``/
    ``max_threshold`` hyper-parameters unchanged.
    """
    if spec.kind == "greedy":
        return GreedySteal()
    if spec.kind == "none":
        return NoSteal()
    if spec.kind == "measured":
        from ..trace import MeasuredPenalty      # lazy: trace imports runtime
        cls = MeasuredPenalty
    else:
        cls = AdaptiveSteal
    st = spec.state
    gov = cls(penalty_hint=spec.penalty_hint if st is None
              else st.penalty_estimate,
              task_cost=spec.task_cost if st is None else st.task_cost,
              ema=spec.ema, max_threshold=spec.max_threshold)
    if st is not None and spec.kind == "measured":
        gov.observed_local = st.observed_local
        gov.observed_steals = st.observed_steals
    return gov


def checkpoint(executor: Executor) -> RuntimeSpec:
    """Snapshot a running spec-built system back into a ``RuntimeSpec``.

    Returns the executor's own spec with the governor's learned θ state
    folded in as a ``GovernorStateSpec`` — the declarative mid-run
    checkpoint: serialize it, and ``build()`` elsewhere reconstructs the
    exact estimator without re-reading any trace.  Requires a spec-built
    executor (``executor.spec`` set) whose governor carries learned state
    (adaptive/measured kinds).
    """
    from .model import GovernorStateSpec
    spec = getattr(executor, "spec", None)
    if spec is None:
        raise SpecError(
            "checkpoint needs a spec-built executor (executor.spec is None: "
            "raw-kwarg construction or a build-time override)")
    state = GovernorStateSpec.from_governor(executor.governor)
    return dataclasses.replace(
        spec, governor=dataclasses.replace(spec.governor, state=state))


def _needs_control(spec: RuntimeSpec) -> bool:
    return (spec.router.kind == "cost"
            or spec.batch.kind == "governed"
            or spec.governor.breaker is not None)


def build(spec: RuntimeSpec, *,
          handler=None, batch_handler=None,
          steal_penalty=None, governor: StealGovernor | None = None,
          trace_path=None, experiment=None) -> Built:
    """Construct the system ``spec`` declares (see module docstring).

    ``experiment`` (an ``ExperimentSpec``, when built through
    ``repro.spec.experiments``) is stamped onto the executor alongside the
    spec, so recorded trace headers name the whole experiment, not just the
    policy.
    """
    overridden = steal_penalty is not None or governor is not None
    if steal_penalty is None:
        steal_penalty = build_penalty(spec.penalty)
    if governor is None:
        governor = build_governor(spec.governor)

    batch: Any = spec.batch.size if spec.batch.kind == "fixed" else 1
    ex = Executor(
        spec.num_domains,
        None if spec.worker_domains is None else list(spec.worker_domains),
        handler=handler,
        pool_cap=spec.pool_cap,
        steal_order=spec.steal_order,
        governor=governor,
        steal_penalty=steal_penalty,
        seed=spec.seed,
        record_events=spec.record_events,
        event_maxlen=spec.event_maxlen,
        batch=batch,
        batch_handler=batch_handler,
    )

    control = None
    if _needs_control(spec):
        from ..control import (BatchGovernor, ControlLoop, CostRouter,
                               StormBreaker)
        router = None
        if spec.router.kind == "cost":
            router = CostRouter(spill_penalty=spec.router.spill_penalty,
                                measured=spec.router.spill == "measured")
        batcher = None
        if spec.batch.kind == "governed":
            b = spec.batch
            batcher = BatchGovernor(target_service=b.target_service,
                                    batch_min=b.batch_min,
                                    batch_cap=b.batch_cap, ema=b.ema,
                                    init_size=b.init_size)
        breaker = None
        if spec.governor.breaker is not None:
            k = spec.governor.breaker
            breaker = StormBreaker(width=k.width, steal_frac=k.steal_frac,
                                   inline_frac=k.inline_frac,
                                   min_executed=k.min_executed,
                                   cooldown=k.cooldown, mode=k.mode,
                                   boost=k.boost)
        control = ControlLoop(router=router, batcher=batcher, breaker=breaker)
        control.attach(ex)
    if spec.router.kind == "round_robin":
        ex.router = lambda task: ex.next_round_robin()

    # Stamp the spec (and any owning experiment) so trace headers fully
    # name this system — unless a build-time override made the spec an
    # incomplete description.
    ex.spec = None if overridden else spec
    ex.experiment = None if overridden else experiment

    recorder = None
    if spec.trace.record:
        from ..trace import TraceRecorder, TraceWriter   # lazy: avoid cycle
        stream = None
        if spec.trace.segment_records is not None:
            if trace_path is None:
                raise SpecError("trace.segment_records is set: build needs "
                                "trace_path= (segment directory) to stream")
            stream = TraceWriter(trace_path,
                                 segment_records=spec.trace.segment_records)
        recorder = TraceRecorder(stream=stream)
        recorder.attach(ex)          # last: header sees the wired governor

    return Built(spec=spec, executor=ex, control=control, recorder=recorder)
