"""Build a live, fully wired runtime system from a ``RuntimeSpec``.

``build(spec)`` is the single construction path the spec API promises: it
creates the ``Executor``, installs the declared router / batch policy /
breaker through a ``repro.control.ControlLoop`` (the same splice points a
hand-wired control plane uses), stamps the spec onto the executor
(``Executor.spec`` — what the trace header embeds, making every recorded
run self-describing), and attaches a ``TraceRecorder`` last so a streamed
header names the effective, breaker-wrapped governor.

Build-time overrides carry the values a spec deliberately cannot hold —
callables and live objects:

    handler / batch_handler   task execution callbacks
    steal_penalty             a custom penalty fn (replaces ``PenaltySpec``;
                              the built executor then no longer embeds the
                              spec, since the spec would misname the run)
    governor                  a pre-built governor instance (e.g. a
                              ``MeasuredPenalty`` seeded from a trace) —
                              same embedding caveat
    trace_path                directory for streamed trace segments when
                              ``TraceSpec.segment_records`` is set

Everything a ``Built`` executor does is deterministic for the spec's seed,
so two builds of the same spec driven identically produce bit-identical
``RuntimeStats`` — the property that makes ``replay(trace)`` from an
embedded spec an exact reconstruction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from ..runtime import (AdaptiveSteal, Executor, GreedySteal, NoSteal,
                       StealGovernor, Task, Worker)
from ..topology import DistanceMatrix, flat as flat_topology
from ..topology import grouped as grouped_topology
from ..topology import pods as pods_topology
from .model import (BatchSpec, GovernorSpec, PenaltySpec, RouterSpec,
                    RuntimeSpec, SpecError, TopologySpec)


@dataclasses.dataclass
class Built:
    """The live system a ``RuntimeSpec`` declares.

    ``executor`` is always present; ``control`` (a wired
    ``repro.control.ControlLoop``) exists when the spec declares a cost
    router, a governed batch, or a breaker; ``recorder`` (an attached
    ``repro.trace.TraceRecorder``) when ``TraceSpec.record`` is set;
    ``obs`` (a live ``repro.obs.Observation`` — registry plus, under
    ``ObsSpec.profile``, the executor's hot-path profiler) when
    ``ObsSpec.enabled`` is set.
    """

    spec: RuntimeSpec
    executor: Executor
    control: Optional[Any] = None      # repro.control.ControlLoop
    recorder: Optional[Any] = None     # repro.trace.TraceRecorder
    obs: Optional[Any] = None          # repro.obs.Observation


def build_penalty(spec: PenaltySpec) -> Optional[Callable[[Task, Worker], float]]:
    """The ``Executor(steal_penalty=...)`` callable a ``PenaltySpec`` names."""
    if spec.kind == "none":
        return None
    value = spec.value
    if spec.kind == "constant":
        return lambda task, worker: value
    if spec.kind == "cost_factor":
        return lambda task, worker: value * task.cost
    # cost_if_homed: only a homed task (a cached prefix somewhere) pays to
    # migrate — the serving engine's re-prefill rule.
    return lambda task, worker: value * task.cost if task.home >= 0 else 0.0


def build_governor(spec: GovernorSpec) -> StealGovernor:
    """The *inner* governor (breaker decoration is applied by ``build``).

    A declared ``GovernorStateSpec`` supersedes the ``penalty_hint``/
    ``task_cost`` priors: the governor starts at the snapshotted learned
    estimates (checkpoint/restore), with the spec's ``ema``/
    ``max_threshold`` hyper-parameters unchanged.
    """
    if spec.kind == "greedy":
        return GreedySteal()
    if spec.kind == "none":
        return NoSteal()
    if spec.kind == "measured":
        from ..trace import MeasuredPenalty      # lazy: trace imports runtime
        cls = MeasuredPenalty
    else:
        cls = AdaptiveSteal
    st = spec.state
    gov = cls(penalty_hint=spec.penalty_hint if st is None
              else st.penalty_estimate,
              task_cost=spec.task_cost if st is None else st.task_cost,
              ema=spec.ema, max_threshold=spec.max_threshold)
    if st is not None and spec.kind == "measured":
        gov.observed_local = st.observed_local
        gov.observed_steals = st.observed_steals
    if st is not None and st.level_penalties is not None:
        gov.seed_level_penalties(dict(st.level_penalties))
    return gov


def build_topology(spec: Optional[TopologySpec],
                   num_domains: int) -> Optional[DistanceMatrix]:
    """The ``DistanceMatrix`` a ``TopologySpec`` names (None when the spec
    declares none — the executor then runs the original flat steal scan)."""
    if spec is None:
        return None
    declared = spec.declared_domains()
    if declared is not None and declared != num_domains:
        raise SpecError(f"topology declares {declared} domains but the "
                        f"runtime has {num_domains}")
    if spec.kind == "flat":
        return flat_topology(num_domains, distance=spec.near)
    if spec.kind == "grouped":
        return grouped_topology(list(spec.groups), near=spec.near,
                                far=spec.far)
    return pods_topology(spec.num_pods, spec.domains_per_pod, near=spec.near)


def checkpoint(executor: Executor) -> RuntimeSpec:
    """Snapshot a running spec-built system back into a ``RuntimeSpec``.

    Returns the executor's own spec with every learned/warm block folded
    back in declaratively — the mid-run checkpoint: serialize it, and
    ``build()`` elsewhere resumes the exact estimators without re-reading
    any trace.  Captured when present:

      * governor θ state (``GovernorStateSpec``, incl. per-level penalty
        EMAs) for adaptive/measured kinds;
      * breaker cool-downs and trip counters (``BreakerStateSpec``) when
        the spec declares a breaker;
      * batch-governor service EMAs — global and per-domain — and current
        size (``BatchStateSpec``) when the batch is governed.

    Requires a spec-built executor (``executor.spec`` set) with at least
    one stateful block; a fully static system (greedy/none governor, fixed
    batch, no breaker) has nothing learned to snapshot and raises.
    """
    from .model import BatchStateSpec, BreakerStateSpec, GovernorStateSpec
    spec = getattr(executor, "spec", None)
    if spec is None:
        raise SpecError(
            "checkpoint needs a spec-built executor (executor.spec is None: "
            "raw-kwarg construction or a build-time override)")
    has_breaker = spec.governor.breaker is not None
    has_batch = spec.batch.kind == "governed"
    if not has_breaker and not has_batch:
        # governor state is the only candidate; let its snapshot raise the
        # canonical "no learned state" error for fully static systems
        state = GovernorStateSpec.from_governor(executor.governor)
        return dataclasses.replace(
            spec, governor=dataclasses.replace(spec.governor, state=state))
    try:
        gov_state = GovernorStateSpec.from_governor(executor.governor)
    except SpecError:
        gov_state = None           # greedy/none inner: nothing learned
    new_gov = spec.governor
    if gov_state is not None:
        new_gov = dataclasses.replace(new_gov, state=gov_state)
    if has_breaker:
        b_state = BreakerStateSpec.from_breaker(executor.governor)
        new_gov = dataclasses.replace(
            new_gov, breaker=dataclasses.replace(new_gov.breaker,
                                                 state=b_state))
    new_batch = spec.batch
    if has_batch:
        new_batch = dataclasses.replace(
            new_batch, state=BatchStateSpec.from_governor(executor.batch))
    return dataclasses.replace(spec, governor=new_gov, batch=new_batch)


def _needs_control(spec: RuntimeSpec) -> bool:
    return (spec.router.kind == "cost"
            or spec.batch.kind == "governed"
            or spec.governor.breaker is not None)


def build(spec: RuntimeSpec, *,
          handler=None, batch_handler=None,
          steal_penalty=None, governor: StealGovernor | None = None,
          trace_path=None, experiment=None) -> Built:
    """Construct the system ``spec`` declares (see module docstring).

    ``experiment`` (an ``ExperimentSpec``, when built through
    ``repro.spec.experiments``) is stamped onto the executor alongside the
    spec, so recorded trace headers name the whole experiment, not just the
    policy.
    """
    overridden = steal_penalty is not None or governor is not None
    if steal_penalty is None:
        steal_penalty = build_penalty(spec.penalty)
    if governor is None:
        governor = build_governor(spec.governor)

    obs = None
    if spec.obs.enabled:
        from ..obs import Observation           # lazy: obs imports runtime
        obs = Observation(spec.obs)

    batch: Any = spec.batch.size if spec.batch.kind == "fixed" else 1
    ex = Executor(
        spec.num_domains,
        None if spec.worker_domains is None else list(spec.worker_domains),
        handler=handler,
        pool_cap=spec.pool_cap,
        steal_order=spec.steal_order,
        governor=governor,
        steal_penalty=steal_penalty,
        seed=spec.seed,
        record_events=spec.record_events,
        event_maxlen=spec.event_maxlen,
        batch=batch,
        batch_handler=batch_handler,
        topology=build_topology(spec.topology, spec.num_domains),
        profiler=None if obs is None else obs.profiler,
    )
    # the live observation rides on the executor so trace headers can name
    # it (schema v4's "obs" block); None for unobserved builds.
    ex.obs = obs

    control = None
    if _needs_control(spec):
        from ..control import (BatchGovernor, ControlLoop, CostRouter,
                               StormBreaker)
        router = None
        if spec.router.kind == "cost":
            router = CostRouter(spill_penalty=spec.router.spill_penalty,
                                measured=spec.router.spill == "measured",
                                breaker_aware=spec.router.breaker_aware)
        batcher = None
        if spec.batch.kind == "governed":
            b = spec.batch
            batcher = BatchGovernor(target_service=b.target_service,
                                    batch_min=b.batch_min,
                                    batch_cap=b.batch_cap, ema=b.ema,
                                    init_size=b.init_size,
                                    per_domain=b.per_domain)
            if b.state is not None:
                batcher.seed_state(
                    service_estimate=b.state.service_estimate,
                    size=b.state.size,
                    domain_estimates=(None if b.state.domain_estimates is None
                                      else dict(b.state.domain_estimates)))
        breaker = None
        if spec.governor.breaker is not None:
            k = spec.governor.breaker
            breaker = StormBreaker(width=k.width, steal_frac=k.steal_frac,
                                   inline_frac=k.inline_frac,
                                   remote_frac=k.remote_frac,
                                   min_executed=k.min_executed,
                                   cooldown=k.cooldown, mode=k.mode,
                                   boost=k.boost)
            if k.state is not None:
                breaker.seed_state(**k.state.to_dict())
        control = ControlLoop(router=router, batcher=batcher, breaker=breaker)
        control.attach(ex)
    if spec.router.kind == "round_robin":
        ex.router = lambda task: ex.next_round_robin()

    # Stamp the spec (and any owning experiment) so trace headers fully
    # name this system — unless a build-time override made the spec an
    # incomplete description.
    ex.spec = None if overridden else spec
    ex.experiment = None if overridden else experiment

    recorder = None
    if spec.trace.record:
        from ..trace import TraceRecorder, TraceWriter   # lazy: avoid cycle
        stream = None
        if spec.trace.segment_records is not None:
            if trace_path is None:
                raise SpecError("trace.segment_records is set: build needs "
                                "trace_path= (segment directory) to stream")
            stream = TraceWriter(trace_path,
                                 segment_records=spec.trace.segment_records)
        recorder = TraceRecorder(stream=stream)
        recorder.attach(ex)          # last: header sees the wired governor

    return Built(spec=spec, executor=ex, control=control, recorder=recorder,
                 obs=obs)
