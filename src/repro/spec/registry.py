"""Named policy registry: the canonical spec per scheduling experiment.

Every entry is a complete ``RuntimeSpec`` value — ``named("paper_cyclic")``
is the declarative form of the configuration the benchmarks previously
wired by hand, and ``specs/<name>.json`` pins its canonical JSON form as a
golden file (``tests/test_spec.py`` keeps them in lockstep;
``python -m repro.spec.validate specs`` proves each one still parses,
builds, and replays).

  paper_cyclic         the paper's §2.2 layer: home routing, cyclic greedy
                       stealing, one-task grabs (``benchmarks``' "locality")
  static_local         pure locality, never steal (OpenMP
                       ``schedule(static)`` — the "static" arm)
  tasking_round_robin  round-robin routing, greedy stealing (plain OpenMP
                       tasking — the "tasking" arm)
  adaptive_theta       home routing + depth-thresholded stealing with a
                       static penalty hint (the "adaptive" arm)
  measured_theta       ``MeasuredPenalty``: both θ inputs learned from
                       measurements (the "measured" arm)
  replay_baseline      the greedy recording baseline of ``benchmarks/
                       {trace_replay,control_plane}.py``: home routing,
                       cyclic greedy stealing, constant re-prefill
                       penalty, trace recording on — the single
                       definition both benchmarks record under
  controlled_replay    the full control plane of ``benchmarks/
                       control_plane.py``'s controlled arm: cost routing
                       with spill, governed budgeted batching, storm
                       breaker, cost-weighted victim selection
  measured_spill       ``controlled_replay`` with the spill threshold
                       priced from the governor's live penalty estimate
                       instead of the static hint (ROADMAP follow-up)
  controlled_serving   the self-tuning serving configuration of
                       ``examples/control_serving.py``: 2 replicas as
                       locality domains, re-prefill penalty, control plane
                       sized for request streams
  topology_flat        the topology benchmark's baseline arm: 8 domains on
                       an explicit *flat* distance tree (distance 1
                       everywhere) — builds the bit-identical single-level
                       steal scan, proving the flat TopologySpec is a no-op
  topology_two_level   the same runtime on a 4+4 socket pair (near 1,
                       far 4): nearest-first stealing, remote steals pay
                       the scaled link distance
  topology_pods_adaptive
                       the full hierarchical control plane on a 2×4 pod
                       tree (cross-pod distance from
                       ``core.topology.tpu_topology``'s remote factor):
                       adaptive per-level θ, level-aware breaker,
                       breaker-aware cost routing, per-domain governed
                       batching
"""
from __future__ import annotations

from .model import (BatchSpec, BreakerSpec, GovernorSpec, PenaltySpec,
                    RouterSpec, RuntimeSpec, ServingSpec, SpecError,
                    TopologySpec, TraceSpec)

# Benchmark-wide constants these policies share (see benchmarks/
# runtime_throughput.py and benchmarks/control_plane.py).
_RUNTIME_PENALTY = 4.0        # runtime_throughput's abstract steal cost
_REPLAY_PENALTY = 6.0         # trace_replay / control_plane re-prefill cost

_CONTROLLED = RuntimeSpec(
    num_domains=4,
    steal_order="cost_weighted",
    penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
    governor=GovernorSpec(kind="greedy", breaker=BreakerSpec()),
    router=RouterSpec(kind="cost", spill_penalty=_REPLAY_PENALTY),
    batch=BatchSpec(kind="governed"),
)

_REGISTRY: dict[str, RuntimeSpec] = {
    "paper_cyclic": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_RUNTIME_PENALTY),
        governor=GovernorSpec(kind="greedy"),
    ),
    "static_local": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_RUNTIME_PENALTY),
        governor=GovernorSpec(kind="none"),
    ),
    "tasking_round_robin": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_RUNTIME_PENALTY),
        governor=GovernorSpec(kind="greedy"),
        router=RouterSpec(kind="round_robin"),
    ),
    "adaptive_theta": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_RUNTIME_PENALTY),
        governor=GovernorSpec(kind="adaptive",
                              penalty_hint=_RUNTIME_PENALTY),
    ),
    "measured_theta": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="measured", penalty_hint=1.0),
    ),
    "replay_baseline": RuntimeSpec(
        num_domains=4, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="greedy"),
        trace=TraceSpec(record=True),
    ),
    "controlled_replay": _CONTROLLED,
    "measured_spill": RuntimeSpec(
        num_domains=4,
        steal_order="cost_weighted",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="adaptive", penalty_hint=_REPLAY_PENALTY,
                              breaker=BreakerSpec()),
        router=RouterSpec(kind="cost", spill_penalty=_REPLAY_PENALTY,
                          spill="measured"),
        batch=BatchSpec(kind="governed"),
    ),
    "controlled_serving": RuntimeSpec(
        num_domains=2,
        steal_order="longest",
        penalty=PenaltySpec(kind="cost_if_homed", value=1.0),
        governor=GovernorSpec(
            kind="greedy",
            breaker=BreakerSpec(width=2, min_executed=2, cooldown=2)),
        router=RouterSpec(kind="cost", spill_penalty=8.0),
        batch=BatchSpec(kind="governed", target_service=24.0, batch_cap=4),
        serving=ServingSpec(num_replicas=2, max_seq=64, policy="locality"),
    ),
    "topology_flat": RuntimeSpec(
        num_domains=8, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="greedy"),
        trace=TraceSpec(record=True),
        topology=TopologySpec(kind="flat"),
    ),
    "topology_two_level": RuntimeSpec(
        num_domains=8, steal_order="cyclic",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="greedy"),
        trace=TraceSpec(record=True),
        topology=TopologySpec(kind="grouped", groups=(4, 4), far=4.0),
    ),
    "topology_pods_adaptive": RuntimeSpec(
        num_domains=8, steal_order="cost_weighted",
        penalty=PenaltySpec(kind="constant", value=_REPLAY_PENALTY),
        governor=GovernorSpec(kind="adaptive", penalty_hint=_REPLAY_PENALTY,
                              breaker=BreakerSpec()),
        router=RouterSpec(kind="cost", spill_penalty=_REPLAY_PENALTY,
                          breaker_aware=True),
        batch=BatchSpec(kind="governed", per_domain=True),
        trace=TraceSpec(record=True),
        topology=TopologySpec(kind="pods", num_pods=2, domains_per_pod=4),
    ),
}


def policy_names() -> tuple[str, ...]:
    """The registered policy names, in registration order."""
    return tuple(_REGISTRY)


def named(name: str) -> RuntimeSpec:
    """The registered ``RuntimeSpec`` for ``name`` (frozen — use
    ``dataclasses.replace`` to derive variants, e.g. a different seed)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise SpecError(f"unknown policy {name!r} "
                        f"(registered: {list(_REGISTRY)})") from None
