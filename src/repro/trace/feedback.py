"""Measured-service-time feedback into the adaptive steal governor.

``runtime.AdaptiveSteal`` throttles stealing by θ ≈ penalty / task_cost, but
PR 1 left both inputs static: a hand-picked ``penalty_hint`` and a nominal
``task_cost`` of 1.  ``MeasuredPenalty`` replaces the hints with
measurements (the ROADMAP's "feed measured per-task service times back into
AdaptiveSteal"):

  * online — every executed task reports its cost to the governor
    (``StealGovernor.on_execute(..., cost=...)``); local runs stream into
    an EMA of the *local* service time (the θ denominator), steals stream
    their actually-charged penalty into the inherited penalty EMA (the θ
    numerator).  θ then tracks reality on both axes.

  * offline — ``MeasuredPenalty.from_trace`` seeds both estimates from a
    recorded trace's run/steal event pairs: a run event's service is its
    cost, a steal event's is cost + penalty, and the difference is what a
    steal really costs on this workload.  A replayed A/B can therefore
    start the governor where the previous run ended instead of re-learning
    from a guess.

The resulting θ is dimensionally a queue depth: "how many tasks deep must a
victim be before relieving it pays for the nonlocal access" — the paper's
balance-over-locality rule, priced with measured numbers.
"""
from __future__ import annotations

from ..runtime import AdaptiveSteal, Worker
from .schema import Trace, event_stolen

_MIN_COST = 1e-9


def _mean(xs) -> float | None:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else None


class MeasuredPenalty(AdaptiveSteal):
    """``AdaptiveSteal`` with both θ inputs learned from measurements.

    ``penalty_hint``/``task_cost`` act only as pre-measurement priors; after
    warm-up the estimates are entirely data-driven.  Construct directly for
    pure online learning, or via ``from_trace`` to start from a recorded
    run's observed service times.
    """

    def __init__(self, penalty_hint: float = 1.0, task_cost: float = 1.0,
                 ema: float = 0.2, max_threshold: int = 64):
        super().__init__(penalty_hint=penalty_hint, task_cost=task_cost,
                         ema=ema, max_threshold=max_threshold)
        self.observed_local = 0
        self.observed_steals = 0

    @classmethod
    def from_trace(cls, trace: Trace, ema: float = 0.2,
                   max_threshold: int = 64) -> "MeasuredPenalty":
        """Seed the estimates from a recorded trace's execution events.

        Local service = mean *cost* over executed events (cost is the local
        component of every execution, stolen or not — a stolen/backpressure
        execution's penalty must never inflate the θ denominator); steal
        penalty = mean actually-charged penalty over stolen executions,
        judged by victim queue so backpressure ``inline`` steals count
        (falling back to the steal-vs-local service gap, then to the local
        service itself when the trace recorded no steals — θ starts at 1,
        the greedy limit).
        """
        executed = [e for e in trace.events
                    if e.kind in ("run", "steal", "inline")]
        local = _mean(e.cost for e in executed)
        if local is None or local <= 0:
            local = 1.0
        stolen = [e for e in executed if event_stolen(e)]
        pen = _mean(e.penalty for e in stolen)
        if pen is None:
            steal_service = _mean(trace.service_times()["steal"])
            pen = (steal_service - local) if steal_service is not None else local
        gov = cls(penalty_hint=max(pen, 0.0), task_cost=max(local, _MIN_COST),
                  ema=ema, max_threshold=max_threshold)
        gov.observed_local = len(executed) - len(stolen)
        gov.observed_steals = len(stolen)
        return gov

    @property
    def local_cost_estimate(self) -> float:
        """Current EMA of local service time — θ's denominator."""
        return self.task_cost

    def on_execute(self, worker: Worker, stolen: bool, penalty: float,
                   cost: float = 1.0, level: int = 1) -> None:
        if stolen:
            self.observed_steals += 1
        else:
            self.observed_local += 1
            self.task_cost = max(
                (1 - self.ema) * self.task_cost + self.ema * cost, _MIN_COST)
        super().on_execute(worker, stolen, penalty, cost, level=level)
