"""JSONL trace files: ``TraceWriter`` / ``TraceReader``.

One JSON object per line, in record order header → submissions → events →
footer (see ``repro.trace.schema`` for the record shapes).  JSONL keeps the
format append-friendly and greppable; the reader is order-insensitive apart
from requiring a header, and rejects unknown schema versions up front.

    TraceWriter("run.trace.jsonl").write(trace)
    trace = TraceReader("run.trace.jsonl").read()

Segmented + streaming export (long-running servers): pass
``segment_records=N`` and the writer treats ``path`` as a *directory* of
rotating JSONL segments (``segment-00000.jsonl``, ``segment-00001.jsonl``,
…), each at most N records.  Segments can be written in one shot
(``write``) or incrementally — ``begin(meta)`` opens the stream and emits
the header, ``add_submission``/``add_event`` append (rotating as needed),
``end(trace)`` emits the footer and closes — so a server exports as it
runs instead of pausing for one big ``finish()`` dump.  ``TraceReader``
reads a segment directory transparently: point it at the directory and it
concatenates ``*.jsonl`` segments in name order.

Columnar events (schema v5): pass ``columnar_events=N`` and events are
written as ``events`` chunk records of up to N events each (parallel column
lists) instead of one record per event — a million-event trace shrinks to
a few hundred lines and parses lazily (``schema.ColumnarEvents``).  In
streaming mode chunking buffers up to N events in memory and flushes a
chunk line at each boundary (and at ``end``), trading the per-record
on-disk-live guarantee for compactness; per-event mode (the default) keeps
the original record-per-line durability.

``dumps_lines``/``loads_lines`` expose the same round-trip on in-memory line
lists (no filesystem), which tests and the serving engine's trace hook use.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Iterable, Iterator, Optional, TextIO

from ..runtime import Event
from .schema import (SubmissionRecord, Trace, TraceSchemaError, event_dict,
                     events_chunk_dict, footer_dict, header_dict,
                     parse_records, submission_dict)

SEGMENT_PATTERN = "segment-*.jsonl"


def _event_chunks(events, size: int) -> Iterator[list[Event]]:
    chunk: list[Event] = []
    for e in events:
        chunk.append(e)
        if len(chunk) >= size:
            yield chunk
            chunk = []
    if chunk:
        yield chunk


def dumps_lines(trace: Trace, *,
                columnar_events: Optional[int] = None) -> list[str]:
    """Serialize ``trace`` to JSONL lines (no trailing newlines).

    ``columnar_events=N`` writes events as schema-v5 columnar chunk records
    of up to N events each instead of one record per event.
    """
    lines = [json.dumps(header_dict(trace.meta))]
    lines += [json.dumps(submission_dict(s)) for s in trace.submissions]
    if columnar_events is None:
        lines += [json.dumps(event_dict(e)) for e in trace.events]
    else:
        lines += [json.dumps(events_chunk_dict(chunk))
                  for chunk in _event_chunks(trace.events, columnar_events)]
    lines.append(json.dumps(footer_dict(trace)))
    return lines


def loads_lines(lines: Iterable[str]) -> Trace:
    """Parse JSONL lines (blank lines ignored) back into a ``Trace``."""
    records = (json.loads(ln) for ln in lines if ln.strip())
    return parse_records(records)


class TraceWriter:
    """Write a ``Trace`` to a JSONL file, or to rotating JSONL segments.

    ``segment_records=None`` (default): ``path`` is a single file, written
    whole by ``write``.  ``segment_records=N``: ``path`` is a directory of
    rotating segments of at most N records each, usable either via
    ``write`` or via the streaming ``begin``/``add_*``/``end`` protocol.

    ``columnar_events=N`` switches event serialization to schema-v5
    columnar chunks of up to N events per record (lazy-decoded on read).
    In streaming mode events are buffered until a chunk fills (or ``end``
    flushes the remainder) — a chunk counts as one record toward segment
    rotation.
    """

    def __init__(self, path: str | os.PathLike,
                 segment_records: Optional[int] = None,
                 columnar_events: Optional[int] = None):
        if segment_records is not None and segment_records < 1:
            raise ValueError("segment_records must be >= 1")
        if columnar_events is not None and columnar_events < 1:
            raise ValueError("columnar_events must be >= 1")
        self.path = os.fspath(path)
        self.segment_records = segment_records
        self.columnar_events = columnar_events
        self._fh: Optional[TextIO] = None
        self._seg = 0          # next segment index
        self._in_seg = 0       # records in the open segment
        self._chunk: list[Event] = []   # buffered events (columnar mode)
        self.records_written = 0

    # -- one-shot ------------------------------------------------------------
    def write(self, trace: Trace) -> str:
        """Write ``trace`` whole; returns the file (or directory) path."""
        if self.segment_records is None:
            parent = os.path.dirname(self.path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            with open(self.path, "w", encoding="utf-8") as fh:
                for ln in dumps_lines(trace,
                                      columnar_events=self.columnar_events):
                    fh.write(ln + "\n")
            return self.path
        self.begin(trace.meta)
        for s in trace.submissions:
            self.add_submission(s)
        self.add_events(trace.events)
        self.end(trace)
        return self.path

    # -- streaming -----------------------------------------------------------
    def begin(self, meta: dict[str, Any]) -> "TraceWriter":
        """Open the stream and write the header record (segmented mode)."""
        if self.segment_records is None:
            raise RuntimeError("streaming export needs segment_records=N "
                               "(single-file mode is one-shot write() only)")
        if self._fh is not None or self._seg:
            raise RuntimeError("TraceWriter stream already begun; "
                               "use one writer per run")
        os.makedirs(self.path, exist_ok=True)
        self._append(header_dict(meta))
        return self

    def add_submission(self, s: SubmissionRecord) -> None:
        self._append(submission_dict(s))

    def add_event(self, e: Event) -> None:
        if self.columnar_events is None:
            self._append(event_dict(e))
            return
        self._chunk.append(e)
        if len(self._chunk) >= self.columnar_events:
            self._flush_chunk()

    def add_events(self, events: Iterable[Event]) -> None:
        """Append a whole event sequence (chunked when columnar)."""
        for e in events:
            self.add_event(e)

    def _flush_chunk(self) -> None:
        if self._chunk:
            self._append(events_chunk_dict(self._chunk))
            self._chunk = []

    def end(self, trace: Trace) -> str:
        """Write the footer (taken from ``trace``) and close the stream."""
        self._flush_chunk()
        self._append(footer_dict(trace))
        self._fh.close()
        self._fh = None
        return self.path

    def _append(self, record: dict[str, Any]) -> None:
        if self._fh is None or self._in_seg >= self.segment_records:
            if self._fh is not None:
                self._fh.close()
            name = os.path.join(self.path, f"segment-{self._seg:05d}.jsonl")
            # repro: allow[hook-purity] sanctioned streaming-export sink: the submit hook writes records out, it never reads anything back into a decision
            self._fh = open(name, "w", encoding="utf-8")
            self._seg += 1
            self._in_seg = 0
        self._fh.write(json.dumps(record) + "\n")
        self._fh.flush()       # stream contract: records are on disk live
        self._in_seg += 1
        self.records_written += 1


class TraceReader:
    """Read a JSONL trace back in — a single file or a segment directory.

    A directory path is read as rotating segments: every
    ``segment-*.jsonl`` inside is concatenated in name order (the writer's
    zero-padded segment names sort chronologically), so segmented and
    single-file traces are interchangeable to callers.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)

    def _lines(self) -> Iterator[str]:
        if os.path.isdir(self.path):
            segments = sorted(glob.glob(os.path.join(self.path,
                                                     SEGMENT_PATTERN)))
            if not segments:
                raise TraceSchemaError(
                    f"no {SEGMENT_PATTERN} segments in directory "
                    f"{self.path!r}")
            for seg in segments:
                with open(seg, "r", encoding="utf-8") as fh:
                    yield from fh
        else:
            with open(self.path, "r", encoding="utf-8") as fh:
                yield from fh

    def read(self) -> Trace:
        return loads_lines(self._lines())
