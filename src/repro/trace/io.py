"""JSONL trace files: ``TraceWriter`` / ``TraceReader``.

One JSON object per line, in record order header → submissions → events →
footer (see ``repro.trace.schema`` for the record shapes).  JSONL keeps the
format append-friendly and greppable; the reader is order-insensitive apart
from requiring a header, and rejects unknown schema versions up front.

    TraceWriter("run.trace.jsonl").write(trace)
    trace = TraceReader("run.trace.jsonl").read()

``dumps_lines``/``loads_lines`` expose the same round-trip on in-memory line
lists (no filesystem), which tests and the serving engine's trace hook use.
"""
from __future__ import annotations

import json
import os
from typing import Iterable

from .schema import (Trace, event_dict, footer_dict, header_dict,
                     parse_records, submission_dict)


def dumps_lines(trace: Trace) -> list[str]:
    """Serialize ``trace`` to JSONL lines (no trailing newlines)."""
    lines = [json.dumps(header_dict(trace.meta))]
    lines += [json.dumps(submission_dict(s)) for s in trace.submissions]
    lines += [json.dumps(event_dict(e)) for e in trace.events]
    lines.append(json.dumps(footer_dict(trace)))
    return lines


def loads_lines(lines: Iterable[str]) -> Trace:
    """Parse JSONL lines (blank lines ignored) back into a ``Trace``."""
    records = (json.loads(ln) for ln in lines if ln.strip())
    return parse_records(records)


class TraceWriter:
    """Write a ``Trace`` to a JSONL file (parent dirs created)."""

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)

    def write(self, trace: Trace) -> str:
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(self.path, "w", encoding="utf-8") as fh:
            for ln in dumps_lines(trace):
                fh.write(ln + "\n")
        return self.path


class TraceReader:
    """Read a JSONL trace file back into a ``Trace``."""

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)

    def read(self) -> Trace:
        with open(self.path, "r", encoding="utf-8") as fh:
            return loads_lines(fh)
