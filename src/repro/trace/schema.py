"""Trace record formats and schema versioning.

A *trace* is everything needed to (a) re-drive an ``Executor`` through the
exact same submit/step interleaving it saw online and (b) analyze what the
scheduler did.  It has four record kinds, serialized one-JSON-object-per-line
(JSONL, see ``repro.trace.io``):

  header      — schema version + the executor's construction parameters
                (``num_domains``, ``worker_domains``, ``steal_order``,
                ``pool_cap``, ``seed``, governor class name) and, for
                executors built from a ``repro.spec.RuntimeSpec`` (schema
                v2), the full serialized spec under ``spec`` — the complete
                name of the system that produced the trace, enough for
                ``replay()`` to reconstruct it with no executor argument
  submission  — one per submitted task: ``(uid, step, home, cost, domain)``
                where ``step`` is the scheduling round at submission time
                (the arrival clock) and ``domain`` the queue it was routed
                to.  This is the *complete* replay input: payloads are
                opaque and deliberately not serialized.
  event       — one per retained ``runtime.Event`` (window semantics: the
                ring buffer keeps the newest ``event_maxlen`` events; the
                header's ``events_total`` counts carry whole-run totals).
  footer      — end-of-run ground truth: ``total_steps`` plus the full
                ``RuntimeStats`` snapshot, the replay-fidelity oracle.

``SCHEMA_VERSION`` gates the reader: traces written by a future incompatible
format raise instead of silently mis-replaying.  v1 traces (pre-spec
headers) stay readable — their headers simply carry no ``spec``, so replay
falls back to ``executor_from_meta`` / an explicit factory, as before v2.
v2 traces (pre-topology headers) likewise stay readable: no ``topology``
block simply means the flat machine, which is what every v2 executor was.
Schema v3 adds the serialized ``repro.topology.DistanceMatrix`` under
``topology`` when the recorded executor carried one, so a hierarchical
trace replays bit-identically from its header alone.
Schema v4 adds the serialized ``repro.spec.ObsSpec`` under ``obs`` when the
recorded run carried a live observation (``RuntimeSpec.obs.enabled``) — an
informational block naming how the run was observed.  Observation never
perturbs the schedule (the obs layer's gated invariant), so v1–v3 readers
and replays need nothing from it, and v3 traces (no ``obs``) stay readable:
the run simply was not observed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

from ..runtime import Event

SCHEMA_VERSION = 4
SUPPORTED_SCHEMAS = (1, 2, 3, SCHEMA_VERSION)
TRACE_KIND = "repro.runtime-trace"


class TraceSchemaError(ValueError):
    """Raised when a trace's schema/shape doesn't match this reader."""


@dataclasses.dataclass(frozen=True)
class SubmissionRecord:
    """One recorded ``Executor.submit``: the replayable arrival."""

    uid: int
    step: int          # executor step count when the task was enqueued
    home: int
    cost: float
    domain: int        # the queue the executor routed it to


@dataclasses.dataclass
class Trace:
    """In-memory form of a recorded run (see module docstring)."""

    meta: dict[str, Any]
    submissions: list[SubmissionRecord]
    events: list[Event]
    total_steps: int
    stats: dict[str, float]
    event_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    events_retained: int = 0

    @property
    def num_domains(self) -> int:
        return int(self.meta["num_domains"])

    @property
    def spec_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.RuntimeSpec`` embedded in the header
        (schema v2, spec-built executors), or None for v1 / raw-kwarg
        traces.  Parse with ``repro.spec.RuntimeSpec.from_dict``."""
        return self.meta.get("spec")

    @property
    def topology_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.topology.DistanceMatrix`` the recorded
        executor stole across (schema v3, topology-built executors), or
        None for flat machines and v1/v2 traces.  Parse with
        ``repro.topology.DistanceMatrix.from_dict``."""
        return self.meta.get("topology")

    @property
    def obs_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.ObsSpec`` the recorded run was
        observed under (schema v4, obs-enabled spec-built executors), or
        None for unobserved runs and v1–v3 traces.  Purely informational:
        observation never changes the schedule."""
        return self.meta.get("obs")

    @property
    def events_dropped(self) -> int:
        """Events the recorded run's ring buffer discarded before the trace
        was cut (whole-run totals minus the retained window).  A nonzero
        value means ``events`` is a *window* of the run — window-sensitive
        analyses (``repro.trace.storms``) refuse such traces."""
        total = sum(self.event_counts.values()) if self.event_counts else 0
        return max(total - self.events_retained, 0)

    @property
    def experiment_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.ExperimentSpec`` embedded in the
        header when the run was driven by ``repro.spec.experiments``
        (policy + workload + run parameters), or None.  Parse with
        ``repro.spec.ExperimentSpec.from_dict``."""
        return self.meta.get("experiment")

    @property
    def n_tasks(self) -> int:
        return len(self.submissions)

    def service_times(self) -> dict[str, list[float]]:
        """Measured per-task service times from the retained execution
        events, keyed by how the task was served (``run``/``steal``/
        ``inline``).  A steal's service is its cost plus the nonlocal
        penalty actually charged — the raw material for
        ``repro.trace.MeasuredPenalty``.  Stolenness is judged by the
        victim queue, not the event kind: a backpressure ``inline``
        execution that took a foreign task counts as ``steal`` (the
        executor labels it ``inline`` but it pays the nonlocal penalty
        all the same)."""
        out: dict[str, list[float]] = {"run": [], "steal": [], "inline": []}
        for e in self.events:
            if e.kind in out:
                key = "steal" if event_stolen(e) else e.kind
                out[key].append(e.service)
        return out


def event_stolen(e: Event) -> bool:
    """True when an execution event took its task from a foreign queue
    (``run``/``steal``/``inline`` alike): the victim queue differs from the
    worker's own domain.  Matches the executor's ``stolen`` accounting,
    which the ``inline`` kind label hides for backpressure steals."""
    return (e.kind in ("run", "steal", "inline")
            and e.src_domain >= 0 and e.src_domain != e.domain)


# -- dict (de)serialization, one record per line -----------------------------

def header_dict(meta: dict[str, Any]) -> dict[str, Any]:
    return {"record": "header", "kind": TRACE_KIND,
            "schema": SCHEMA_VERSION, **meta}


def submission_dict(s: SubmissionRecord) -> dict[str, Any]:
    return {"record": "submission", "uid": s.uid, "step": s.step,
            "home": s.home, "cost": s.cost, "domain": s.domain}


def event_dict(e: Event) -> dict[str, Any]:
    return {"record": "event", "step": e.step, "kind": e.kind,
            "worker": e.worker, "domain": e.domain, "task_uid": e.task_uid,
            "src_domain": e.src_domain, "cost": e.cost, "penalty": e.penalty}


def footer_dict(trace: Trace) -> dict[str, Any]:
    return {"record": "footer", "total_steps": trace.total_steps,
            "stats": trace.stats, "event_counts": trace.event_counts,
            "events_retained": trace.events_retained}


def parse_records(records: Iterable[dict[str, Any]]) -> Trace:
    """Assemble a ``Trace`` from parsed record dicts, validating schema."""
    meta: dict[str, Any] | None = None
    submissions: list[SubmissionRecord] = []
    events: list[Event] = []
    footer: dict[str, Any] = {}
    for rec in records:
        r = rec.get("record")
        if r == "header":
            if rec.get("kind") != TRACE_KIND:
                raise TraceSchemaError(f"not a runtime trace: {rec.get('kind')!r}")
            if rec.get("schema") not in SUPPORTED_SCHEMAS:
                raise TraceSchemaError(
                    f"trace schema {rec.get('schema')!r} not in "
                    f"supported {SUPPORTED_SCHEMAS}")
            meta = {k: v for k, v in rec.items()
                    if k not in ("record", "kind", "schema")}
        elif r == "submission":
            submissions.append(SubmissionRecord(
                uid=int(rec["uid"]), step=int(rec["step"]),
                home=int(rec["home"]), cost=float(rec["cost"]),
                domain=int(rec["domain"])))
        elif r == "event":
            events.append(Event(
                step=int(rec["step"]), kind=str(rec["kind"]),
                worker=int(rec["worker"]), domain=int(rec["domain"]),
                task_uid=int(rec["task_uid"]),
                src_domain=int(rec.get("src_domain", -1)),
                cost=float(rec.get("cost", 0.0)),
                penalty=float(rec.get("penalty", 0.0))))
        elif r == "footer":
            footer = rec
        else:
            raise TraceSchemaError(f"unknown trace record {r!r}")
    if meta is None:
        raise TraceSchemaError("trace has no header record")
    return Trace(meta=meta, submissions=submissions, events=events,
                 total_steps=int(footer.get("total_steps", 0)),
                 stats=dict(footer.get("stats", {})),
                 event_counts=dict(footer.get("event_counts", {})),
                 events_retained=int(footer.get("events_retained",
                                                len(events))))
