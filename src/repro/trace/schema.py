"""Trace record formats and schema versioning.

A *trace* is everything needed to (a) re-drive an ``Executor`` through the
exact same submit/step interleaving it saw online and (b) analyze what the
scheduler did.  It has four record kinds, serialized one-JSON-object-per-line
(JSONL, see ``repro.trace.io``):

  header      — schema version + the executor's construction parameters
                (``num_domains``, ``worker_domains``, ``steal_order``,
                ``pool_cap``, ``seed``, governor class name) and, for
                executors built from a ``repro.spec.RuntimeSpec`` (schema
                v2), the full serialized spec under ``spec`` — the complete
                name of the system that produced the trace, enough for
                ``replay()`` to reconstruct it with no executor argument
  submission  — one per submitted task: ``(uid, step, home, cost, domain)``
                where ``step`` is the scheduling round at submission time
                (the arrival clock) and ``domain`` the queue it was routed
                to.  This is the *complete* replay input: payloads are
                opaque and deliberately not serialized.
  event       — one per retained ``runtime.Event`` (window semantics: the
                ring buffer keeps the newest ``event_maxlen`` events; the
                header's ``events_total`` counts carry whole-run totals).
  events      — schema v5's columnar alternative to per-``event`` lines: one
                record carries a *chunk* of consecutive events as parallel
                column lists (``{"columns": {"step": [...], "kind": [...],
                ...}, "n": N}``).  Readers decode chunks lazily
                (``ColumnarEvents``), so a million-event trace parses
                without building a million ``Event`` objects up front.
                Writers choose per trace: per-event records (the default,
                maximally greppable) or chunks (compact, fast).
  footer      — end-of-run ground truth: ``total_steps`` plus the full
                ``RuntimeStats`` snapshot, the replay-fidelity oracle.

``SCHEMA_VERSION`` gates the reader: traces written by a future incompatible
format raise instead of silently mis-replaying.  v1 traces (pre-spec
headers) stay readable — their headers simply carry no ``spec``, so replay
falls back to ``executor_from_meta`` / an explicit factory, as before v2.
v2 traces (pre-topology headers) likewise stay readable: no ``topology``
block simply means the flat machine, which is what every v2 executor was.
Schema v3 adds the serialized ``repro.topology.DistanceMatrix`` under
``topology`` when the recorded executor carried one, so a hierarchical
trace replays bit-identically from its header alone.
Schema v4 adds the serialized ``repro.spec.ObsSpec`` under ``obs`` when the
recorded run carried a live observation (``RuntimeSpec.obs.enabled``) — an
informational block naming how the run was observed.  Observation never
perturbs the schedule (the obs layer's gated invariant), so v1–v3 readers
and replays need nothing from it, and v3 traces (no ``obs``) stay readable:
the run simply was not observed.
Schema v5 adds the columnar ``events`` chunk record.  v1–v4 traces (only
per-event records) stay readable unchanged; a v5 trace that sticks to
per-event records is byte-compatible with v4 apart from the header's
version stamp.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from collections.abc import Sequence as _SequenceABC
from typing import Any, Iterable, Iterator, Sequence

from ..runtime import Event

SCHEMA_VERSION = 5
SUPPORTED_SCHEMAS = (1, 2, 3, 4, SCHEMA_VERSION)
TRACE_KIND = "repro.runtime-trace"

# serialization order of the per-event columns in an ``events`` chunk
EVENT_COLUMNS = ("step", "kind", "worker", "domain", "task_uid",
                 "src_domain", "cost", "penalty")


class TraceSchemaError(ValueError):
    """Raised when a trace's schema/shape doesn't match this reader."""


def _decode_chunk(columns: dict[str, list]) -> list[Event]:
    """Materialize one chunk's column lists into ``Event`` objects."""
    return [Event(step=int(s), kind=str(k), worker=int(w), domain=int(d),
                  task_uid=int(u), src_domain=int(sd), cost=float(c),
                  penalty=float(p))
            for s, k, w, d, u, sd, c, p in zip(*(columns[col]
                                                 for col in EVENT_COLUMNS))]


class ColumnarEvents(_SequenceABC):
    """Lazy event sequence backed by schema-v5 columnar chunks.

    Holds the parsed chunk payloads (plain column lists) and decodes
    ``Event`` objects only when iterated or indexed — ``len`` / slicing /
    elementwise ``==`` against any event sequence all work, so consumers
    written against ``list[Event]`` (storm detection, span assembly,
    ``service_times``) run unchanged.  Parts may interleave chunks with
    already-materialized event runs (a trace mixing per-event and chunk
    records decodes in record order).
    """

    def __init__(self, parts: list[tuple[int, Any]]):
        # parts: (n, payload) in record order; payload is a columns dict
        # (lazy chunk) or a list[Event] (pre-materialized run)
        self._parts = parts
        self._offsets = [0]
        for n, _ in parts:
            self._offsets.append(self._offsets[-1] + n)

    def __len__(self) -> int:
        return self._offsets[-1]

    def __iter__(self) -> Iterator[Event]:
        for _, payload in self._parts:
            if isinstance(payload, dict):
                yield from _decode_chunk(payload)
            else:
                yield from payload

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self)[i]
        n = len(self)
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError("ColumnarEvents index out of range")
        part = bisect_right(self._offsets, i) - 1
        local = i - self._offsets[part]
        payload = self._parts[part][1]
        if isinstance(payload, dict):
            return Event(step=int(payload["step"][local]),
                         kind=str(payload["kind"][local]),
                         worker=int(payload["worker"][local]),
                         domain=int(payload["domain"][local]),
                         task_uid=int(payload["task_uid"][local]),
                         src_domain=int(payload["src_domain"][local]),
                         cost=float(payload["cost"][local]),
                         penalty=float(payload["penalty"][local]))
        return payload[local]

    def __eq__(self, other) -> bool:
        if not isinstance(other, (list, tuple, ColumnarEvents)):
            return NotImplemented
        if len(other) != len(self):
            return False
        return all(a == b for a, b in zip(self, other))

    __hash__ = None   # mutable-ish sequence semantics, like list

    def __repr__(self) -> str:
        return (f"ColumnarEvents(n={len(self)}, "
                f"parts={len(self._parts)})")


def events_chunk_dict(events: Sequence[Event]) -> dict[str, Any]:
    """Serialize a run of consecutive events as one columnar chunk record."""
    return {"record": "events", "n": len(events),
            "columns": {
                "step": [e.step for e in events],
                "kind": [e.kind for e in events],
                "worker": [e.worker for e in events],
                "domain": [e.domain for e in events],
                "task_uid": [e.task_uid for e in events],
                "src_domain": [e.src_domain for e in events],
                "cost": [e.cost for e in events],
                "penalty": [e.penalty for e in events],
            }}


@dataclasses.dataclass(frozen=True)
class SubmissionRecord:
    """One recorded ``Executor.submit``: the replayable arrival."""

    uid: int
    step: int          # executor step count when the task was enqueued
    home: int
    cost: float
    domain: int        # the queue the executor routed it to


@dataclasses.dataclass
class Trace:
    """In-memory form of a recorded run (see module docstring)."""

    meta: dict[str, Any]
    submissions: list[SubmissionRecord]
    # list[Event] for per-event traces, ColumnarEvents for chunked (v5)
    # ones; both are event sequences and compare elementwise
    events: Sequence[Event]
    total_steps: int
    stats: dict[str, float]
    event_counts: dict[str, int] = dataclasses.field(default_factory=dict)
    events_retained: int = 0

    @property
    def num_domains(self) -> int:
        return int(self.meta["num_domains"])

    @property
    def spec_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.RuntimeSpec`` embedded in the header
        (schema v2, spec-built executors), or None for v1 / raw-kwarg
        traces.  Parse with ``repro.spec.RuntimeSpec.from_dict``."""
        return self.meta.get("spec")

    @property
    def topology_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.topology.DistanceMatrix`` the recorded
        executor stole across (schema v3, topology-built executors), or
        None for flat machines and v1/v2 traces.  Parse with
        ``repro.topology.DistanceMatrix.from_dict``."""
        return self.meta.get("topology")

    @property
    def obs_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.ObsSpec`` the recorded run was
        observed under (schema v4, obs-enabled spec-built executors), or
        None for unobserved runs and v1–v3 traces.  Purely informational:
        observation never changes the schedule."""
        return self.meta.get("obs")

    @property
    def events_dropped(self) -> int:
        """Events the recorded run's ring buffer discarded before the trace
        was cut (whole-run totals minus the retained window).  A nonzero
        value means ``events`` is a *window* of the run — window-sensitive
        analyses (``repro.trace.storms``) refuse such traces."""
        total = sum(self.event_counts.values()) if self.event_counts else 0
        return max(total - self.events_retained, 0)

    @property
    def experiment_dict(self) -> dict[str, Any] | None:
        """The serialized ``repro.spec.ExperimentSpec`` embedded in the
        header when the run was driven by ``repro.spec.experiments``
        (policy + workload + run parameters), or None.  Parse with
        ``repro.spec.ExperimentSpec.from_dict``."""
        return self.meta.get("experiment")

    @property
    def n_tasks(self) -> int:
        return len(self.submissions)

    def service_times(self) -> dict[str, list[float]]:
        """Measured per-task service times from the retained execution
        events, keyed by how the task was served (``run``/``steal``/
        ``inline``).  A steal's service is its cost plus the nonlocal
        penalty actually charged — the raw material for
        ``repro.trace.MeasuredPenalty``.  Stolenness is judged by the
        victim queue, not the event kind: a backpressure ``inline``
        execution that took a foreign task counts as ``steal`` (the
        executor labels it ``inline`` but it pays the nonlocal penalty
        all the same)."""
        out: dict[str, list[float]] = {"run": [], "steal": [], "inline": []}
        for e in self.events:
            if e.kind in out:
                key = "steal" if event_stolen(e) else e.kind
                out[key].append(e.service)
        return out


def event_stolen(e: Event) -> bool:
    """True when an execution event took its task from a foreign queue
    (``run``/``steal``/``inline`` alike): the victim queue differs from the
    worker's own domain.  Matches the executor's ``stolen`` accounting,
    which the ``inline`` kind label hides for backpressure steals."""
    return (e.kind in ("run", "steal", "inline")
            and e.src_domain >= 0 and e.src_domain != e.domain)


# -- dict (de)serialization, one record per line -----------------------------

def header_dict(meta: dict[str, Any]) -> dict[str, Any]:
    return {"record": "header", "kind": TRACE_KIND,
            "schema": SCHEMA_VERSION, **meta}


def submission_dict(s: SubmissionRecord) -> dict[str, Any]:
    return {"record": "submission", "uid": s.uid, "step": s.step,
            "home": s.home, "cost": s.cost, "domain": s.domain}


def event_dict(e: Event) -> dict[str, Any]:
    return {"record": "event", "step": e.step, "kind": e.kind,
            "worker": e.worker, "domain": e.domain, "task_uid": e.task_uid,
            "src_domain": e.src_domain, "cost": e.cost, "penalty": e.penalty}


def footer_dict(trace: Trace) -> dict[str, Any]:
    return {"record": "footer", "total_steps": trace.total_steps,
            "stats": trace.stats, "event_counts": trace.event_counts,
            "events_retained": trace.events_retained}


def parse_records(records: Iterable[dict[str, Any]]) -> Trace:
    """Assemble a ``Trace`` from parsed record dicts, validating schema."""
    meta: dict[str, Any] | None = None
    submissions: list[SubmissionRecord] = []
    events: list[Event] = []          # current run of per-event records
    parts: list[tuple[int, Any]] = []  # chunk / event-run parts, in order
    footer: dict[str, Any] = {}

    def flush_events() -> None:
        nonlocal events
        if events:
            parts.append((len(events), events))
            events = []

    for rec in records:
        r = rec.get("record")
        if r == "header":
            if rec.get("kind") != TRACE_KIND:
                raise TraceSchemaError(f"not a runtime trace: {rec.get('kind')!r}")
            if rec.get("schema") not in SUPPORTED_SCHEMAS:
                raise TraceSchemaError(
                    f"trace schema {rec.get('schema')!r} not in "
                    f"supported {SUPPORTED_SCHEMAS}")
            meta = {k: v for k, v in rec.items()
                    if k not in ("record", "kind", "schema")}
        elif r == "submission":
            submissions.append(SubmissionRecord(
                uid=int(rec["uid"]), step=int(rec["step"]),
                home=int(rec["home"]), cost=float(rec["cost"]),
                domain=int(rec["domain"])))
        elif r == "event":
            events.append(Event(
                step=int(rec["step"]), kind=str(rec["kind"]),
                worker=int(rec["worker"]), domain=int(rec["domain"]),
                task_uid=int(rec["task_uid"]),
                src_domain=int(rec.get("src_domain", -1)),
                cost=float(rec.get("cost", 0.0)),
                penalty=float(rec.get("penalty", 0.0))))
        elif r == "events":
            columns = rec.get("columns")
            if not isinstance(columns, dict):
                raise TraceSchemaError("events chunk has no columns dict")
            missing = [c for c in EVENT_COLUMNS if c not in columns]
            if missing:
                raise TraceSchemaError(
                    f"events chunk missing columns {missing}")
            n = int(rec.get("n", len(columns["step"])))
            bad = [c for c in EVENT_COLUMNS if len(columns[c]) != n]
            if bad:
                raise TraceSchemaError(
                    f"events chunk declares n={n} but columns {bad} "
                    "have a different length")
            flush_events()
            parts.append((n, columns))   # decoded lazily (ColumnarEvents)
        elif r == "footer":
            footer = rec
        else:
            raise TraceSchemaError(f"unknown trace record {r!r}")
    if meta is None:
        raise TraceSchemaError("trace has no header record")
    all_events: Sequence[Event]
    if parts:
        flush_events()
        all_events = ColumnarEvents(parts)
    else:
        all_events = events   # per-event-only trace: a plain list, as ever
    return Trace(meta=meta, submissions=submissions, events=all_events,
                 total_steps=int(footer.get("total_steps", 0)),
                 stats=dict(footer.get("stats", {})),
                 event_counts=dict(footer.get("event_counts", {})),
                 events_retained=int(footer.get("events_retained",
                                                len(all_events))))
