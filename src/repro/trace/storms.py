"""Steal-storm analysis: windowed detectors over runtime event streams.

The paper's Fig. 4 argument is that aggregate throughput hides what the
scheduler is doing — you need per-thread, per-interval timelines to see the
runs where dynamic scheduling degenerates into a storm of nonlocal accesses.
This module is the online analogue: it folds an event stream (live
``EventLog`` contents or a recorded ``Trace``) into fixed-width step
windows and flags the pathological ones:

  steal storm     — execution in a window dominated by steals: the balance
                    mechanism is bulk-migrating work (paying the nonlocal
                    penalty on most tasks) instead of occasionally topping
                    up an idle domain.
  inline burst    — a burst of submitter-executed tasks: the bounded pool
                    saturated and backpressure kicked in (§2.1), i.e.
                    arrivals outran the worker team.
  depth imbalance — per-domain queue depths diverging inside a window: the
                    leading indicator (deep victim queues) that a storm is
                    about to start.

``render_timeline`` draws the per-worker picture as text — one row per
worker, one column per window, with a marker row underneath flagging storm
windows — the terminal-friendly stand-in for the paper's variability plots.
"""
from __future__ import annotations

import dataclasses
from itertools import islice
from typing import Iterable, Optional, Sequence

from ..runtime import Event
from .schema import event_stolen

EXEC_KINDS = ("run", "steal", "inline")

# streams longer than this are sampled automatically (every k-th event,
# counts scaled by k) unless the caller pins an explicit sample_stride.
# Default ring buffers retain at most 65536 events, so every committed
# analysis stays exact; only deliberately huge streams (streamed segments,
# raised event_maxlen) cross the threshold.
AUTO_SAMPLE_THRESHOLD = 1 << 18


class DroppedEventsError(ValueError):
    """Raised when storm analysis is asked to run over an event window that
    lost events to the ring buffer: a window with holes would silently
    under-count steals/inlines and mis-date every window, so the detectors
    refuse instead of degrading.  Raise ``event_maxlen`` (or analyze
    streamed trace segments) to observe the whole run."""


def _checked_events(events: Iterable[Event]) -> Iterable[Event]:
    """Accept an event iterable, a live ``EventLog``, or a ``Trace``;
    refuse any source that has already dropped events."""
    dropped = getattr(events, "events_dropped", None)    # Trace
    if dropped is None:
        dropped = getattr(events, "dropped", None)       # live EventLog
    if dropped:
        raise DroppedEventsError(
            f"event window lost {dropped} events to the ring buffer; storm "
            "analysis over a holed window would mis-count — raise "
            "event_maxlen or analyze streamed trace segments (pass a plain "
            "event list to override deliberately)")
    inner = getattr(events, "events", None)              # Trace payload
    return inner if inner is not None else events


@dataclasses.dataclass(frozen=True)
class Window:
    """Aggregate of one fixed-width step interval ``[start, start+width)``.

    ``remote_steals`` counts the subset of executed-from-a-foreign-queue
    events whose victim sat at topology level >= 2 from the thief (cross
    socket/pod); it is only populated when ``windows`` is given the
    run's ``DistanceMatrix`` — flat analyses leave it 0.
    """

    start: int
    width: int
    runs: int = 0
    steals: int = 0
    inlines: int = 0
    idles: int = 0
    submits: int = 0
    remote_steals: int = 0

    @property
    def executed(self) -> int:
        return self.runs + self.steals + self.inlines

    @property
    def steal_fraction(self) -> float:
        return self.steals / max(self.executed, 1)

    @property
    def inline_fraction(self) -> float:
        return self.inlines / max(self.executed, 1)

    @property
    def remote_fraction(self) -> float:
        return self.remote_steals / max(self.executed, 1)


def _resolve_stride(events, sample_stride: Optional[int]) -> int:
    """The effective sampling stride for a (possibly sized) event source.

    An explicit ``sample_stride`` wins.  Otherwise sized sources longer
    than ``AUTO_SAMPLE_THRESHOLD`` get the smallest stride that brings the
    sample under the threshold (deterministic — a pure function of the
    length); everything else stays exact (stride 1).
    """
    if sample_stride is not None:
        if sample_stride < 1:
            raise ValueError("sample_stride must be >= 1")
        return sample_stride
    try:
        n = len(events)
    except TypeError:
        return 1
    if n <= AUTO_SAMPLE_THRESHOLD:
        return 1
    return -(-n // AUTO_SAMPLE_THRESHOLD)    # ceil division


def windows(events: Iterable[Event], width: int = 8,
            topology=None, *,
            sample_stride: Optional[int] = None) -> list[Window]:
    """Fold an event stream into consecutive step windows of ``width``.

    With a ``repro.topology.DistanceMatrix`` as ``topology``, each window
    additionally counts its *remote* steals: execution events that took a
    task from a queue at distance level >= 2 (cross socket/pod) — the
    level dimension ``detect_remote_storms`` and the online
    ``control.StormBreaker`` act on.

    ``events`` may be a plain event iterable, a live ``runtime.EventLog``,
    or a recorded ``Trace``.  A log/trace that already *dropped* events to
    its ring buffer is refused with ``DroppedEventsError`` (a holed window
    would silently mis-count); pass ``list(log)`` to analyze the retained
    window deliberately.

    Sampling at large n: folding is per-event, so million-event streams
    (streamed segment traces, raised ``event_maxlen``) would pay a Python
    loop per event.  When the source's length exceeds
    ``AUTO_SAMPLE_THRESHOLD`` (or ``sample_stride=k`` is passed
    explicitly), only every k-th event is folded and each counted
    contribution is weighted by k — window counts become deterministic
    stride-k *estimates* (fractions unbiased, small windows noisier), and
    windows with no sampled events disappear.  ``sample_stride=1`` pins
    the analysis exact regardless of size.  Default-sized ring buffers
    (65536) never auto-sample.
    """
    if width < 1:
        raise ValueError("window width must be >= 1")
    evs = _checked_events(events)
    stride = _resolve_stride(evs, sample_stride)
    source: Iterable[Event] = evs
    if stride > 1:
        source = islice(iter(evs), 0, None, stride)
    acc: dict[int, dict[str, int]] = {}
    for e in source:
        w = acc.setdefault(e.step // width,
                           {"run": 0, "steal": 0, "inline": 0,
                            "idle": 0, "submit": 0, "remote": 0})
        if e.kind in w:
            w[e.kind] += stride
        if (topology is not None and event_stolen(e)
                and topology.level(e.src_domain, e.domain) >= 2):
            w["remote"] += stride
    return [Window(start=k * width, width=width, runs=v["run"],
                   steals=v["steal"], inlines=v["inline"], idles=v["idle"],
                   submits=v["submit"], remote_steals=v["remote"])
            for k, v in sorted(acc.items())]


def detect_steal_storms(events: Iterable[Event], width: int = 8,
                        frac: float = 0.5, min_executed: int = 4, *,
                        sample_stride: Optional[int] = None) -> list[Window]:
    """Windows where at least ``frac`` of executed tasks were steals (and
    enough ran for the fraction to mean anything).  ``sample_stride``
    forwards to ``windows`` (sampled estimates at large n)."""
    return [w for w in windows(events, width, sample_stride=sample_stride)
            if w.executed >= min_executed and w.steal_fraction >= frac]


def detect_remote_storms(events: Iterable[Event], topology, width: int = 8,
                         frac: float = 0.25,
                         min_executed: int = 4, *,
                         sample_stride: Optional[int] = None) -> list[Window]:
    """Windows where cross-tier (topology level >= 2) steals make up at
    least ``frac`` of executed tasks: work is leaving its socket/pod in
    bulk, each migration paying the scaled deep-link penalty.  The evidence
    bar defaults *lower* than ``detect_steal_storms`` — remote steals cost
    more apiece, so fewer justify flagging — matching the online
    ``control.StormBreaker(remote_frac=...)`` detector."""
    return [w for w in windows(events, width, topology=topology,
                               sample_stride=sample_stride)
            if w.executed >= min_executed and w.remote_fraction >= frac]


def detect_inline_bursts(events: Iterable[Event], width: int = 8,
                         frac: float = 0.25, min_executed: int = 4, *,
                         sample_stride: Optional[int] = None) -> list[Window]:
    """Windows where backpressure made the submitter do ≥ ``frac`` of the
    executing — the pool-saturated regime."""
    return [w for w in windows(events, width, sample_stride=sample_stride)
            if w.executed >= min_executed and w.inline_fraction >= frac]


def depth_imbalance(depth_series: Sequence[tuple[int, tuple[int, ...]]],
                    width: int = 8) -> list[tuple[int, float]]:
    """Per-window queue-depth imbalance from ``MetricsRecorder.depth_series``.

    Imbalance of one sample is ``max(depths) - mean(depths)`` (how far the
    deepest queue runs ahead of the average, in tasks); each window reports
    its worst sample.  Returns ``[(window_start, imbalance), ...]``.
    """
    acc: dict[int, float] = {}
    for step, sizes in depth_series:
        if not sizes:
            continue
        imb = max(sizes) - sum(sizes) / len(sizes)
        key = step // width
        acc[key] = max(acc.get(key, 0.0), imb)
    return [(k * width, v) for k, v in sorted(acc.items())]


def _cell(runs: int, steals: int, inlines: int, idles: int) -> str:
    executed = runs + steals + inlines
    if executed == 0:
        return "·" if idles == 0 else "i"
    if steals >= max(runs, inlines):
        return "S"
    if inlines >= max(runs, steals):
        return "I"
    return "r"


def render_timeline(events: Iterable[Event], num_workers: int,
                    width: int = 8, storm_frac: float = 0.5,
                    min_executed: int = 4) -> str:
    """Text timeline: one row per worker, one column per step window.

    Cell legend: ``r`` run-dominated, ``S`` steal-dominated, ``I`` inline-
    dominated (backpressure), ``i`` idle polls only, ``·`` no activity.
    A marker row underneath carries ``^`` beneath detected steal-storm
    windows.  This is the Fig. 4 per-thread variability picture rendered
    for a terminal.
    """
    evs = list(events)
    if not evs:
        return "(no events)"
    n_win = max(e.step for e in evs) // width + 1
    per_worker = [[[0, 0, 0, 0] for _ in range(n_win)]
                  for _ in range(num_workers)]
    for e in evs:
        if 0 <= e.worker < num_workers:
            cell = per_worker[e.worker][e.step // width]
            if e.kind == "run":
                cell[0] += 1
            elif e.kind == "steal":
                cell[1] += 1
            elif e.kind == "inline":
                cell[2] += 1
            elif e.kind == "idle":
                cell[3] += 1
    storm_keys = {w.start // width
                  for w in detect_steal_storms(evs, width, storm_frac,
                                               min_executed)}
    label = max(len(f"w{num_workers - 1}"), 5)
    lines = [f"{'steps':>{label}} 0..{n_win * width} in windows of {width} "
             f"(r=run S=steal I=inline i=idle ·=none)"]
    for wid in range(num_workers):
        row = "".join(_cell(*c) for c in per_worker[wid])
        lines.append(f"{f'w{wid}':>{label}} {row}")
    marker = "".join("^" if k in storm_keys else " " for k in range(n_win))
    lines.append(f"{'storm':>{label}} {marker}".rstrip())
    return "\n".join(lines)
