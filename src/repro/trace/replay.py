"""Deterministic replay: re-drive an ``Executor`` from a recorded trace.

The runtime is deterministic by construction (cooperative round-robin
workers, seeded RNG), so a run is fully determined by (a) the executor's
construction parameters and (b) the interleaving of ``submit`` and ``step``
calls.  A trace records exactly that: each submission carries the step-clock
value at which it was enqueued plus the queue it was routed to, and the
footer carries the total step count.  ``replay`` reconstructs the
interleaving:

    for each recorded submission, step the executor until its step clock
    matches, then submit an equivalent task (same uid/home/cost) to the
    recorded queue; finally step out the remaining recorded rounds and
    drain.

Because the routed domain is recorded, replay is *schedule-faithful* on the
submission side regardless of how the original chose queues (home routing,
round-robin, explicit) — and the execution side re-decides under whatever
governor/steal-order the replay executor carries.  That is the point: the
same arrival sequence, different policy ⇒ an honest A/B of steal policies
(``benchmarks/trace_replay.py``).  With a policy-equivalent executor (the
default factory + the recorded governor semantics and the same penalty
function), the replayed ``RuntimeStats`` reproduce the recorded ones
bit-for-bit — asserted by ``ReplayResult.matches_recorded``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from ..runtime import (AdaptiveSteal, Executor, GreedySteal, NoSteal,
                       StealGovernor, Task)
from .schema import Trace

GOVERNORS: dict[str, Callable[[], StealGovernor]] = {
    "GreedySteal": GreedySteal,
    "NoSteal": NoSteal,
    "AdaptiveSteal": AdaptiveSteal,
    "StealGovernor": StealGovernor,
}

# stats keys that must agree for a replay to count as exact; results of
# handlers (payload-dependent) are deliberately out of scope.
FIDELITY_KEYS = ("submitted", "executed", "local", "stolen", "inline_runs",
                 "idle_polls", "steal_penalty", "max_pool_depth",
                 "local_fraction", "steal_fraction")


def executor_from_meta(trace: Trace, *,
                       governor: StealGovernor | None = None,
                       steal_penalty=None, handler=None,
                       steal_order: str | None = None) -> Executor:
    """Build a fresh executor matching the trace header.

    ``governor=None`` reconstructs the recorded governor *class* (default
    construction — governor hyper-parameters are not serialized; pass an
    instance to override).  ``steal_penalty``/``handler``/``steal_order``
    override the respective knobs for policy A/B replays.
    """
    meta = trace.meta
    if governor is None:
        factory = GOVERNORS.get(str(meta.get("governor")))
        governor = factory() if factory is not None else None
    return Executor(
        int(meta["num_domains"]),
        [int(d) for d in meta["worker_domains"]],
        handler=handler,
        pool_cap=meta.get("pool_cap"),
        steal_order=steal_order or str(meta.get("steal_order", "cyclic")),
        governor=governor,
        steal_penalty=steal_penalty,
        seed=int(meta.get("seed", 0)),
    )


@dataclasses.dataclass
class ReplayResult:
    executor: Executor
    trace: Trace

    @property
    def stats(self) -> dict[str, float]:
        return self.executor.metrics.snapshot()

    @property
    def matches_recorded(self) -> bool:
        """True when the replayed RuntimeStats reproduce the recorded ones
        exactly (the determinism acceptance check)."""
        rec, got = self.trace.stats, self.stats
        return all(got.get(k) == rec.get(k) for k in FIDELITY_KEYS)

    def mismatches(self) -> dict[str, tuple[Any, Any]]:
        rec, got = self.trace.stats, self.stats
        return {k: (rec.get(k), got.get(k)) for k in FIDELITY_KEYS
                if got.get(k) != rec.get(k)}


def replay(trace: Trace,
           executor_factory: Optional[Callable[[Trace], Executor]] = None,
           *, assert_match: bool = False) -> ReplayResult:
    """Re-drive an executor through the trace's recorded arrival sequence.

    ``executor_factory(trace) -> Executor`` supplies the executor (default:
    ``executor_from_meta`` — the recorded configuration).  The factory must
    return a *fresh* executor whose step clock is at 0.  With
    ``assert_match=True`` the replayed stats are checked bit-for-bit
    against the recorded footer stats (use only with a policy-equivalent
    factory, including the recorded run's penalty function).
    """
    ex = (executor_factory or executor_from_meta)(trace)
    if ex.step_count != 0:
        raise ValueError("replay needs a fresh executor (step clock at 0)")
    for rec in trace.submissions:
        while ex.step_count < rec.step:
            ex.step()
        ex.submit(Task(uid=rec.uid, payload=None, home=rec.home,
                       cost=rec.cost), domain=rec.domain)
    # replicate any trailing rounds (including idle polls on empty queues —
    # they are part of the recorded stats), then drain whatever is left.
    while ex.step_count < trace.total_steps:
        ex.step()
    ex.run_until_drained()
    result = ReplayResult(executor=ex, trace=trace)
    if assert_match and not result.matches_recorded:
        raise AssertionError(
            f"replay diverged from recorded stats: {result.mismatches()}")
    return result
