"""Deterministic replay: re-drive an ``Executor`` from a recorded trace.

The runtime is deterministic by construction (cooperative round-robin
workers, seeded RNG), so a run is fully determined by (a) the executor's
construction parameters and (b) the interleaving of ``submit`` and ``step``
calls.  A trace records exactly that: each submission carries the step-clock
value at which it was enqueued plus the queue it was routed to, and the
footer carries the total step count.  ``replay`` reconstructs the
interleaving:

    for each recorded submission, step the executor until its step clock
    matches, then submit an equivalent task (same uid/home/cost) to the
    recorded queue; finally step out the remaining recorded rounds and
    drain.

Because the routed domain is recorded, replay is *schedule-faithful* on the
submission side regardless of how the original chose queues (home routing,
round-robin, explicit) — and the execution side re-decides under whatever
governor/steal-order the replay executor carries.  That is the point: the
same arrival sequence, different policy ⇒ an honest A/B of steal policies
(``benchmarks/trace_replay.py``).  With a policy-equivalent executor (the
default factory + the recorded governor semantics and the same penalty
function), the replayed ``RuntimeStats`` reproduce the recorded ones
bit-for-bit — asserted by ``ReplayResult.matches_recorded``.

Two counterfactual extensions:

  * ``reroute=True`` keeps the arrival sequence but lets the replay
    executor re-decide the submit domains — the A/B for *routing* policies
    (the recorded-domain default is the A/B for *steal* policies).
  * ``ReplayResult.task_times`` + ``compare_replays`` report per-task
    wait/sojourn and their per-uid deltas between two replays of the same
    trace, so a governor change is judged by which tasks it helped and
    hurt, not only by aggregate stats.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional

from ..runtime import (AdaptiveSteal, Event, Executor, GreedySteal, NoSteal,
                       StealGovernor, Task)
from .feedback import MeasuredPenalty
from .schema import Trace

GOVERNORS: dict[str, Callable[[], StealGovernor]] = {
    "GreedySteal": GreedySteal,
    "NoSteal": NoSteal,
    "AdaptiveSteal": AdaptiveSteal,
    "StealGovernor": StealGovernor,
    "MeasuredPenalty": MeasuredPenalty,
}

# stats keys that must agree for a replay to count as exact; results of
# handlers (payload-dependent) are deliberately out of scope.
FIDELITY_KEYS = ("submitted", "executed", "local", "stolen", "inline_runs",
                 "idle_polls", "steal_penalty", "max_pool_depth",
                 "local_fraction", "steal_fraction")
# keys checked only when the recorded footer carries them: v1/v2 traces
# predate remote-steal accounting, and their absence must not fail replays.
OPTIONAL_FIDELITY_KEYS = ("remote_steals",)


def executor_from_spec(trace: Trace) -> Executor:
    """Reconstruct the *exact* recorded system from the spec embedded in a
    schema-v2 trace header: governor (with breaker decoration), router,
    batch policy, penalty rule and all — the configuration is data, so no
    hand-written factory is needed.  Raises ``ValueError`` when the trace
    carries no spec (v1 traces, raw-kwarg executors): pass an explicit
    ``executor_factory`` instead, as before v2.
    """
    sd = trace.spec_dict
    if sd is None:
        raise ValueError(
            "trace header embeds no spec (v1 trace or raw-kwarg executor); "
            "pass an executor_factory, e.g. executor_from_meta")
    from ..spec import RuntimeSpec, TraceSpec   # lazy: spec builds trace objs
    spec = RuntimeSpec.from_dict(sd)
    # Replay re-drives the *scheduler*; re-attaching the recorded run's own
    # recorder would at best waste memory and at worst (streamed segments)
    # demand a trace_path nobody has — recording is the one block a replay
    # deliberately does not reconstruct.  Stats are unaffected.
    return dataclasses.replace(spec, trace=TraceSpec()).build().executor


def executor_from_meta(trace: Trace, *,
                       governor: StealGovernor | None = None,
                       steal_penalty=None, handler=None,
                       steal_order: str | None = None) -> Executor:
    """Build a fresh executor matching the trace header.

    ``governor=None`` reconstructs the recorded governor *class* (default
    construction — governor hyper-parameters are not serialized; pass an
    instance to override).  A recorded governor name this module cannot
    reconstruct (e.g. ``StormBreaker``, which needs its control loop)
    raises instead of silently substituting the default — pass an explicit
    ``governor`` (or a full factory that rebuilds the control plane, as
    ``benchmarks.control_plane`` does).  ``steal_penalty``/``handler``/
    ``steal_order`` override the respective knobs for policy A/B replays.

    Schema-v3 headers carry the recorded ``repro.topology.DistanceMatrix``
    under ``topology``; it is rebuilt and handed to the fresh executor, so
    hierarchical traces replay their nearest-first steal scans exactly.
    """
    meta = trace.meta
    if governor is None:
        name = meta.get("governor")
        if name is not None and name not in GOVERNORS:
            raise ValueError(
                f"trace was recorded under governor {name!r}, which "
                "executor_from_meta cannot reconstruct; pass governor= "
                "explicitly (or a factory that rebuilds it)")
        factory = GOVERNORS.get(str(name))
        governor = factory() if factory is not None else None
    topology = None
    if meta.get("topology") is not None:
        from ..topology import DistanceMatrix   # lazy: keep import light
        topology = DistanceMatrix.from_dict(meta["topology"])
    return Executor(
        int(meta["num_domains"]),
        [int(d) for d in meta["worker_domains"]],
        handler=handler,
        pool_cap=meta.get("pool_cap"),
        steal_order=steal_order or str(meta.get("steal_order", "cyclic")),
        governor=governor,
        steal_penalty=steal_penalty,
        seed=int(meta.get("seed", 0)),
        topology=topology,
    )


@dataclasses.dataclass(frozen=True)
class TaskTiming:
    """Per-task timing of one replayed (or recorded) execution.

    ``wait`` is queueing delay in scheduling rounds (execute step − submit
    step); ``service`` is the executed cost plus any nonlocal penalty paid
    (cost units ≈ rounds at the repo's unit task cost); ``sojourn`` is
    their sum — the discrete analogue of a request's end-to-end latency.
    """

    uid: int
    submit_step: int
    exec_step: int
    service: float

    @property
    def wait(self) -> int:
        return self.exec_step - self.submit_step

    @property
    def sojourn(self) -> float:
        return self.wait + self.service


def task_times(submissions, events: Iterable[Event]) -> dict[int, TaskTiming]:
    """Fold submissions + execution events into per-task timings.

    Works on a recorded ``Trace`` (``task_times(t.submissions, t.events)``)
    or on a replay executor's live log.  Only tasks whose execution event is
    still in the (ring-buffered) event window appear; for small runs that is
    all of them.
    """
    submit_step = {s.uid: s.step for s in submissions}
    out: dict[int, TaskTiming] = {}
    for e in events:
        if e.kind in ("run", "steal", "inline") and e.task_uid in submit_step:
            out[e.task_uid] = TaskTiming(
                uid=e.task_uid, submit_step=submit_step[e.task_uid],
                exec_step=e.step, service=e.service)
    return out


@dataclasses.dataclass
class ReplayResult:
    executor: Executor
    trace: Trace

    @property
    def stats(self) -> dict[str, float]:
        return self.executor.metrics.snapshot()

    @property
    def matches_recorded(self) -> bool:
        """True when the replayed RuntimeStats reproduce the recorded ones
        exactly (the determinism acceptance check)."""
        return not self.mismatches()

    def mismatches(self) -> dict[str, tuple[Any, Any]]:
        rec, got = self.trace.stats, self.stats
        keys = FIDELITY_KEYS + tuple(k for k in OPTIONAL_FIDELITY_KEYS
                                     if k in rec)
        return {k: (rec.get(k), got.get(k)) for k in keys
                if got.get(k) != rec.get(k)}

    def task_times(self) -> dict[int, TaskTiming]:
        """Per-task wait/sojourn of this replay (uid -> ``TaskTiming``),
        from the replay executor's event log — the counterfactual-metrics
        raw material (``compare_replays``)."""
        if self.executor.events is None:
            raise RuntimeError("replay executor recorded no events "
                               "(record_events=False)")
        return task_times(self.trace.submissions, self.executor.events)

    def sojourn_percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        """Exact nearest-rank percentiles of this replay's per-task sojourn
        (``{"p50": ..., "p95": ..., "p99": ...}``) — the latency summary
        ``BENCH_experiments.json`` exports per run.  Computed over the full
        retained sample by ``repro.obs.percentiles`` (no bucket estimates).
        """
        from ..obs.metrics import percentiles   # lazy: obs imports trace
        times = self.task_times()
        if not times:
            raise RuntimeError("no retained task timings to summarize")
        return percentiles([t.sojourn for t in times.values()], qs)


@dataclasses.dataclass
class ReplayComparison:
    """Per-task deltas between two replays of the same trace (B − A)."""

    wait_delta: dict[int, int]        # uid -> wait_b - wait_a (rounds)
    sojourn_delta: dict[int, float]   # uid -> sojourn_b - sojourn_a
    mean_wait: tuple[float, float]    # (A, B)
    mean_sojourn: tuple[float, float]

    @property
    def n_tasks(self) -> int:
        return len(self.wait_delta)

    @property
    def improved(self) -> int:
        """Tasks whose sojourn strictly improved under B."""
        return sum(1 for d in self.sojourn_delta.values() if d < 0)

    @property
    def regressed(self) -> int:
        return sum(1 for d in self.sojourn_delta.values() if d > 0)


def compare_replays(a: ReplayResult, b: ReplayResult) -> ReplayComparison:
    """Per-task counterfactual: what did policy B do to each task that
    policy A also served?  Both replays must come from the *same* trace
    (same submission uids/steps); tasks present in both event windows are
    compared, per uid, not just in aggregate.
    """
    ta, tb = a.task_times(), b.task_times()
    shared = sorted(set(ta) & set(tb))
    if not shared:
        raise ValueError("replays share no retained tasks to compare")
    wait = {u: tb[u].wait - ta[u].wait for u in shared}
    sojourn = {u: tb[u].sojourn - ta[u].sojourn for u in shared}
    return ReplayComparison(
        wait_delta=wait, sojourn_delta=sojourn,
        mean_wait=(sum(ta[u].wait for u in shared) / len(shared),
                   sum(tb[u].wait for u in shared) / len(shared)),
        mean_sojourn=(sum(ta[u].sojourn for u in shared) / len(shared),
                      sum(tb[u].sojourn for u in shared) / len(shared)))


def replay(trace: Trace,
           executor_factory: Optional[Callable[[Trace], Executor]] = None,
           *, assert_match: bool = False,
           reroute: bool = False) -> ReplayResult:
    """Re-drive an executor through the trace's recorded arrival sequence.

    ``executor_factory(trace) -> Executor`` supplies the executor.  The
    default reconstructs the recorded configuration: when the header embeds
    a spec (schema v2, spec-built executors) the *exact* system is rebuilt
    from it (``executor_from_spec`` — governor, breaker, router, batch
    policy, penalty rule), so ``replay(trace)`` with no arguments
    reproduces the recorded ``RuntimeStats`` bit-for-bit; v1/spec-less
    traces fall back to ``executor_from_meta`` (flat fields only — pass a
    factory for penalty functions etc., as before v2).  The factory must
    return a *fresh* executor whose step clock is at 0.  With
    ``assert_match=True`` the replayed stats are checked bit-for-bit
    against the recorded footer stats (use only with a policy-equivalent
    factory, including the recorded run's penalty function).

    ``reroute=True`` replays the *arrivals* (uid/home/cost/step) but lets
    the replay executor re-decide each submit domain (router/home/
    round-robin) instead of forcing the recorded queue — the counterfactual
    for submit-side policies (``repro.control.CostRouter`` A/Bs), just as a
    plain replay is the counterfactual for dequeue-side steal policies.
    Incompatible with ``assert_match`` (routing is the treatment).
    """
    if reroute and assert_match:
        raise ValueError("reroute re-decides routing; recorded stats are "
                         "not expected to match")
    if executor_factory is None:
        executor_factory = (executor_from_spec if trace.spec_dict is not None
                            else executor_from_meta)
    ex = executor_factory(trace)
    if ex.step_count != 0:
        raise ValueError("replay needs a fresh executor (step clock at 0)")
    for rec in trace.submissions:
        while ex.step_count < rec.step:
            ex.step()
        ex.submit(Task(uid=rec.uid, payload=None, home=rec.home,
                       cost=rec.cost),
                  domain=None if reroute else rec.domain)
    # replicate any trailing rounds (including idle polls on empty queues —
    # they are part of the recorded stats), then drain whatever is left.
    while ex.step_count < trace.total_steps:
        ex.step()
    ex.run_until_drained()
    result = ReplayResult(executor=ex, trace=trace)
    if assert_match and not result.matches_recorded:
        raise AssertionError(
            f"replay diverged from recorded stats: {result.mismatches()}")
    return result
