"""Composable, reproducible workload generators for the runtime.

A workload is a finite stream of ``Arrival(step, home, cost)`` records —
which task arrives at which scheduling round, homed on which locality
domain, with what abstract service cost.  Everything is derived from an
explicit seed, so the *same* arrival sequence can be driven through
different steal policies (the paper's apples-to-apples policy comparison)
or recorded once and replayed forever.

Arrival processes (production-like shapes, not just the benchmark's
hand-rolled waves):

  ``poisson``   — steady traffic: per-step arrival counts ~ Poisson(rate).
  ``bursty``    — a two-state Markov-modulated Poisson process (MMPP):
                  a hidden quiet/storm state with sticky transitions
                  modulates the rate, giving synchronized bursts separated
                  by lulls (the steal-storm trigger).
  ``diurnal``   — a sinusoidal day/night rate profile over the horizon
                  (capacity is provisioned for the peak; the trough is
                  where locality-oblivious stealing looks free but isn't).

Combinators reshape an existing stream without touching its clock:

  ``hot_skew``       — re-home a fraction of tasks onto one hot domain
                       (the paper's "one socket owns the data" pathology).
  ``lognormal_costs``— heavy-tailed service costs (long prefills).

``standard_scenarios`` bundles the canonical set used by the benchmarks;
``drive`` runs any workload through an executor with one scheduling round
per arrival step (arrivals overlap service, the online regime).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..runtime import Executor


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One task arrival: at scheduling round ``step``, homed on ``home``."""

    step: int
    home: int
    cost: float = 1.0


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named, finite, reproducible arrival stream.

    ``tail_steps`` appends that many arrival-free scheduling rounds after
    the last arrival (the cadence a load generator keeps after its final
    burst); ``drive`` steps through them before draining, so idle-poll
    accounting is part of the workload's definition, not the drive loop's.
    """

    name: str
    num_domains: int
    arrivals: tuple[Arrival, ...]
    tail_steps: int = 0

    @property
    def n_tasks(self) -> int:
        return len(self.arrivals)

    @property
    def horizon(self) -> int:
        """Last arrival step + 1 (the drive loop's minimum round count)."""
        return max((a.step for a in self.arrivals), default=-1) + 1

    def by_step(self) -> dict[int, list[Arrival]]:
        out: dict[int, list[Arrival]] = {}
        for a in self.arrivals:
            out.setdefault(a.step, []).append(a)
        return out


def _homes(rng: np.random.Generator, n: int, num_domains: int) -> np.ndarray:
    return rng.integers(0, num_domains, n)


def _from_counts(name: str, counts: np.ndarray, num_domains: int,
                 rng: np.random.Generator, cost: float) -> Workload:
    arrivals = []
    for step, k in enumerate(int(c) for c in counts):
        for home in _homes(rng, k, num_domains):
            arrivals.append(Arrival(step=step, home=int(home), cost=cost))
    return Workload(name, num_domains, tuple(arrivals))


def poisson(rate: float, steps: int, num_domains: int, seed: int = 0,
            cost: float = 1.0) -> Workload:
    """Steady traffic: arrivals per step ~ Poisson(``rate``), homes uniform."""
    rng = np.random.default_rng(seed)
    return _from_counts(f"poisson(rate={rate:g})",
                        rng.poisson(rate, steps), num_domains, rng, cost)


def bursty(rate_quiet: float, rate_storm: float, steps: int,
           num_domains: int, seed: int = 0, p_enter: float = 0.08,
           p_exit: float = 0.25, cost: float = 1.0) -> Workload:
    """Two-state MMPP: quiet ↔ storm with sticky transitions.

    ``p_enter``/``p_exit`` are the per-step probabilities of switching into/
    out of the storm state, giving geometric burst lengths of mean
    ``1/p_exit`` steps at rate ``rate_storm``.
    """
    rng = np.random.default_rng(seed)
    counts = np.empty(steps, dtype=np.int64)
    storming = False
    for t in range(steps):
        flip = rng.random()
        storming = (flip >= p_exit) if storming else (flip < p_enter)
        counts[t] = rng.poisson(rate_storm if storming else rate_quiet)
    return _from_counts(
        f"bursty(q={rate_quiet:g},s={rate_storm:g})", counts,
        num_domains, rng, cost)


def diurnal(peak_rate: float, steps: int, num_domains: int, seed: int = 0,
            trough_frac: float = 0.1, periods: float = 1.0,
            cost: float = 1.0) -> Workload:
    """Sinusoidal day/night ramp: rate sweeps trough → peak → trough over
    ``periods`` full cycles across the horizon."""
    rng = np.random.default_rng(seed)
    trough = peak_rate * trough_frac
    t = np.arange(steps)
    phase = 2.0 * math.pi * periods * t / max(steps, 1)
    rates = trough + (peak_rate - trough) * 0.5 * (1.0 - np.cos(phase))
    return _from_counts(f"diurnal(peak={peak_rate:g})",
                        rng.poisson(rates), num_domains, rng, cost)


def hot_skew(workload: Workload, hot_domain: int = 0, p_hot: float = 0.8,
             seed: int = 0) -> Workload:
    """Re-home a ``p_hot`` fraction of arrivals onto ``hot_domain``."""
    rng = np.random.default_rng(seed)
    hot = rng.random(workload.n_tasks) < p_hot
    arrivals = tuple(
        dataclasses.replace(a, home=hot_domain) if h else a
        for a, h in zip(workload.arrivals, hot))
    return dataclasses.replace(
        workload, name=f"{workload.name}+hot{hot_domain}@{p_hot:g}",
        arrivals=arrivals)


def lognormal_costs(workload: Workload, median: float = 1.0,
                    sigma: float = 0.75, seed: int = 0) -> Workload:
    """Heavy-tailed service costs: cost ~ LogNormal(ln median, sigma)."""
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(math.log(median), sigma, workload.n_tasks)
    arrivals = tuple(dataclasses.replace(a, cost=float(c))
                     for a, c in zip(workload.arrivals, costs))
    return dataclasses.replace(
        workload, name=f"{workload.name}+lncost", arrivals=arrivals)


def benchmark_waves(n_tasks: int, num_domains: int = 4,
                    seed: int = 0) -> dict[str, Workload]:
    """The online-runtime benchmark's hand-rolled wave scenarios as
    ``Workload`` values (``benchmarks.runtime_throughput``'s historical
    arrival construction, preserved arrival-for-arrival):

      ``uniform`` — homes uniform over domains, 8 arrivals per round.
      ``bursty``  — synchronized 64-task waves separated by 6 idle rounds
                    (``tail_steps`` keeps the trailing idle rounds).
      ``skewed``  — 80% of tasks homed on domain 0, 8 per round.

    All three draw from one shared RNG stream in this order — that coupling
    is part of the recorded benchmark numbers, so it is reproduced here
    rather than cleaned up.
    """
    rng = np.random.default_rng(seed)

    def batched(name: str, homes: np.ndarray, per_round: int) -> Workload:
        arrivals = tuple(Arrival(step=i // per_round, home=int(h))
                         for i, h in enumerate(homes))
        return Workload(name, num_domains, arrivals)

    uniform = batched("uniform_waves", rng.integers(0, num_domains, n_tasks), 8)
    burst_homes = rng.integers(0, num_domains, n_tasks)
    bursts = tuple(Arrival(step=(i // 64) * 7, home=int(h))
                   for i, h in enumerate(burst_homes))
    bursty_wl = Workload("bursty_waves", num_domains, bursts, tail_steps=6)
    hot = rng.random(n_tasks) < 0.8
    skew_homes = np.where(hot, 0, rng.integers(0, num_domains, n_tasks))
    skewed = batched("skewed_waves", skew_homes, 8)
    return {"uniform": uniform, "bursty": bursty_wl, "skewed": skewed}


def standard_scenarios(num_domains: int = 4, steps: int = 48,
                       seed: int = 0) -> dict[str, Workload]:
    """The canonical scenario set the benchmarks compare policies across.

    Rates are scaled so each scenario offers roughly ``num_domains`` tasks
    per scheduling round at its busy points — enough pressure that steal
    decisions matter, not so much that every policy degenerates to a
    saturated queue.
    """
    d = num_domains
    return {
        "poisson": poisson(rate=d, steps=steps, num_domains=d, seed=seed),
        "bursty": bursty(rate_quiet=d * 0.25, rate_storm=d * 3.0,
                         steps=steps, num_domains=d, seed=seed + 1),
        "diurnal": diurnal(peak_rate=d * 2.0, steps=steps, num_domains=d,
                           seed=seed + 2),
        "hot_skew": hot_skew(
            poisson(rate=d, steps=steps, num_domains=d, seed=seed + 3),
            hot_domain=0, p_hot=0.8, seed=seed + 3),
    }


def drive(executor: Executor, workload: Workload,
          payload=None, drain_budget: int | None = None) -> Executor:
    """Run ``workload`` through ``executor``: submit each step's arrivals,
    take one scheduling round, repeat (through any ``tail_steps``); then
    drain.  Returns the executor (stats/events on it).  Arrivals land at
    exactly ``Arrival.step`` on the executor's step clock, so a recorded
    trace of this drive replays on the same clock.

    ``drain_budget`` caps the post-arrival drain at that many extra
    scheduling rounds; exceeding it raises ``RuntimeError`` (the guard a
    declarative experiment wants against a policy that cannot drain its
    workload).  Within the budget the run is bit-identical to the unbounded
    default."""
    by_step = workload.by_step()
    for t in range(workload.horizon + workload.tail_steps):
        for a in by_step.get(t, ()):
            executor.submit(executor.make_task(
                payload=payload, home=a.home, cost=a.cost))
        executor.step()
    if drain_budget is None:
        executor.run_until_drained()
    else:
        for _ in range(drain_budget):
            if not len(executor.queues):
                break
            executor.step()
        if len(executor.queues):
            raise RuntimeError(
                f"workload {workload.name!r} not drained within "
                f"drain_budget={drain_budget} extra rounds "
                f"({len(executor.queues)} tasks still queued)")
        executor.results.clear()       # parity with run_until_drained, whose
        # returned results this drive loop likewise discards
    return executor
