"""Recording a live ``Executor`` run into a replayable ``Trace``.

``TraceRecorder`` attaches to an executor via its ``submit_hook`` (the only
instrumentation point recording needs: everything else the runtime already
traces in its ``EventLog``), accumulates one ``SubmissionRecord`` per
enqueued task, and on ``finish()`` snapshots the executor's construction
meta, retained events, whole-run event counts, and final ``RuntimeStats``
into a ``Trace``.

Usage::

    rec = TraceRecorder()
    ex = rec.attach(Executor(4, steal_penalty=...))
    ... drive ex (submit/step/run_until_drained) ...
    trace = rec.finish()
    TraceWriter(path).write(trace)           # repro.trace.io

Long-running servers can stream instead of snapshotting: pass a segmented
``TraceWriter`` (``segment_records=N``) as ``stream`` and the recorder
writes the header at ``attach`` time and every submission as it happens;
``finish()`` then only appends the retained events and the footer — no
whole-trace export pause.  A writer configured with ``columnar_events=N``
streams those events as schema-v5 chunk records (one line per N events)
instead of one line each.  When controllers rewire the executor
(``repro.control.ControlLoop`` swaps the governor), attach them *before*
the recorder so the streamed header names the effective governor.
"""
from __future__ import annotations

from typing import Optional, TYPE_CHECKING

from ..runtime import Executor, Task
from .schema import SubmissionRecord, Trace

if TYPE_CHECKING:                                # no import cycle at runtime
    from .io import TraceWriter


def executor_meta(ex: Executor) -> dict:
    """The executor construction parameters a trace header records.

    For executors built from a ``repro.spec.RuntimeSpec`` (``ex.spec`` is
    set), the full serialized spec rides along under ``"spec"`` — the
    schema-v2 guarantee that a trace completely names the system that
    produced it (``replay(trace)`` rebuilds it with no executor argument).
    The flat v1 fields stay alongside for older readers and quick greps.
    """
    meta = {
        "num_domains": ex.num_domains,
        "worker_domains": [w.domain for w in ex.pool],
        "steal_order": ex.queues.steal_order,
        "pool_cap": ex.pool_cap,
        "seed": ex.seed,
        "governor": type(ex.governor).__name__,
    }
    topology = getattr(ex, "topology", None)
    if topology is not None:
        # schema v3: the distance matrix the steal scan walked — replay can
        # rebuild the hierarchical executor from the header alone, spec or
        # no spec.
        meta["topology"] = topology.to_dict()
    spec = getattr(ex, "spec", None)
    if spec is not None:
        meta["spec"] = spec.to_dict()
    obs = getattr(ex, "obs", None)
    if obs is not None:
        # schema v4: name how the run was observed.  Informational only —
        # observation is passive, so replay needs nothing from this block.
        meta["obs"] = obs.spec.to_dict()
    experiment = getattr(ex, "experiment", None)
    if experiment is not None:
        # executors driven by repro.spec.experiments also name the full
        # experiment (policy + workload + run parameters) that produced
        # the trace; replay only needs "spec", but the workload block makes
        # the trace a self-describing experiment artifact.
        meta["experiment"] = experiment.to_dict()
    return meta


class TraceRecorder:
    """Capture an executor run as a replayable submission + event trace."""

    def __init__(self, stream: Optional["TraceWriter"] = None) -> None:
        self.submissions: list[SubmissionRecord] = []
        self.stream = stream
        self._ex: Optional[Executor] = None

    def attach(self, executor: Executor) -> Executor:
        """Hook into ``executor`` and return it (chainable).  The executor
        should record events (``record_events=True``, the default) if storm
        analysis or measured-penalty feedback is wanted; the submission
        trace alone is enough for replay."""
        if self._ex is not None:
            raise RuntimeError("TraceRecorder is already attached; "
                               "use one recorder per run")
        executor.submit_hook = self._on_submit
        self._ex = executor
        if self.stream is not None:
            self.stream.begin(executor_meta(executor))
        return executor

    def _on_submit(self, task: Task, domain: int, step: int) -> None:
        rec = SubmissionRecord(uid=task.uid, step=step, home=task.home,
                               cost=float(task.cost), domain=domain)
        self.submissions.append(rec)
        if self.stream is not None:
            self.stream.add_submission(rec)

    @property
    def executor(self) -> Executor:
        if self._ex is None:
            raise RuntimeError("TraceRecorder is not attached to an executor")
        return self._ex

    def finish(self) -> Trace:
        """Snapshot the attached executor's end-of-run state as a ``Trace``.

        Call after the drive loop (typically after ``run_until_drained``);
        calling mid-run simply yields a trace of the run so far.  With a
        ``stream`` writer attached, also appends the retained events and
        the footer to the stream and closes it.
        """
        ex = self.executor
        events = list(ex.events) if ex.events is not None else []
        counts = ex.events.counts() if ex.events is not None else {}
        trace = Trace(meta=executor_meta(ex),
                      submissions=list(self.submissions),
                      events=events, total_steps=ex.step_count,
                      stats=ex.metrics.snapshot(), event_counts=counts,
                      events_retained=len(events))
        if self.stream is not None:
            self.stream.add_events(events)
            self.stream.end(trace)
            self.stream = None
        return trace
