"""repro.trace — workloads, trace export, deterministic replay, storm analysis.

PR 1's ``repro.runtime`` executes tasks online but exposes only aggregate
counters; the paper's key evidence is timeline-level (the per-thread
variability behind Fig. 4).  This package closes the loop around the
runtime: generate production-like arrival streams, record a run as a
replayable trace, re-drive the *same* arrival sequence under a different
steal policy, detect steal storms in the event timeline, and feed measured
service times back into the adaptive governor.

Paper-concept map (Wittmann & Hager, 2010), continuing the table in
``repro/runtime/__init__.py``:

  paper concept (§)                      trace object
  -------------------------------------  ---------------------------------
  benchmark task streams (§2.1, §3)      ``workloads``: ``poisson`` /
                                         ``bursty`` (MMPP) / ``diurnal``
                                         arrival processes, ``hot_skew`` /
                                         ``lognormal_costs`` combinators
  identical work, different schedule     ``TraceRecorder`` + ``replay``:
  (the Fig. 3 A/B methodology)           the recorded submission trace is
                                         the controlled variable, the steal
                                         policy the treatment
  per-thread timelines behind Fig. 4     ``storms.render_timeline`` (text
                                         timeline) over ``runtime.Event``
                                         streams
  nonlocal-access storms (§3.1's         ``storms.detect_steal_storms`` /
  degraded dynamic runs)                 ``detect_inline_bursts`` /
                                         ``depth_imbalance`` windowed
                                         detectors
  nonlocal penalty, measured not         ``MeasuredPenalty``: run/steal
  assumed (§1.4 bandwidth ratios)        service-time pairs → θ estimate of
                                         ``runtime.AdaptiveSteal``

Usage::

    from repro import trace
    from repro.runtime import Executor

    wl = trace.hot_skew(trace.poisson(rate=4, steps=64, num_domains=4))
    rec = trace.TraceRecorder()
    ex = rec.attach(Executor(4, steal_penalty=lambda t, w: 4.0))
    trace.drive(ex, wl)
    t = rec.finish()
    trace.TraceWriter("run.jsonl").write(t)

    print(trace.render_timeline(t.events, num_workers=4))
    result = trace.replay(                           # bit-identical stats
        t, lambda tr: trace.executor_from_meta(
            tr, steal_penalty=lambda t, w: 4.0), assert_match=True)
    gov = trace.MeasuredPenalty.from_trace(t)        # measured θ seed
"""
from .feedback import MeasuredPenalty
from .io import TraceReader, TraceWriter, dumps_lines, loads_lines
from .record import TraceRecorder, executor_meta
from .replay import (ReplayComparison, ReplayResult, TaskTiming,
                     compare_replays, executor_from_meta, executor_from_spec,
                     replay, task_times)
from .schema import (SCHEMA_VERSION, ColumnarEvents, SubmissionRecord,
                     Trace, TraceSchemaError, event_stolen)
from .storms import (DroppedEventsError, Window, depth_imbalance,
                     detect_inline_bursts, detect_remote_storms,
                     detect_steal_storms, render_timeline, windows)
from .workloads import (Arrival, Workload, benchmark_waves, bursty, diurnal,
                        drive, hot_skew, lognormal_costs, poisson,
                        standard_scenarios)

__all__ = [
    "MeasuredPenalty",
    "TraceReader", "TraceWriter", "dumps_lines", "loads_lines",
    "TraceRecorder", "executor_meta",
    "ReplayComparison", "ReplayResult", "TaskTiming", "compare_replays",
    "executor_from_meta", "executor_from_spec", "replay", "task_times",
    "SCHEMA_VERSION", "ColumnarEvents", "SubmissionRecord", "Trace",
    "TraceSchemaError", "event_stolen",
    "DroppedEventsError", "Window", "depth_imbalance", "detect_inline_bursts",
    "detect_remote_storms", "detect_steal_storms", "render_timeline",
    "windows",
    "Arrival", "Workload", "benchmark_waves", "bursty", "diurnal", "drive",
    "hot_skew", "lognormal_costs", "poisson", "standard_scenarios",
]
