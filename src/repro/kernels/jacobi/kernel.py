"""Pallas TPU kernel for the blocked six-point Jacobi sweep.

TPU adaptation of the paper's hot loop (paper §1.4).  The paper's cache
blocking (600x10x10 blocks sized for L2/L3) becomes VMEM blocking: the grid
is tiled over (i-blocks, j-blocks); the k extent stays whole inside a block
(the paper keeps dk = Nk "to make best use of the hardware prefetching" — on
TPU the analogue is keeping the innermost, lane-mapped dimension long and
contiguous for efficient VREG utilisation).

Halos: Pallas BlockSpecs tile disjointly, so each invocation reads its centre
block plus the four neighbouring blocks (N/S/W/E) of the same array via
shifted, clamped index maps, and assembles the +-1 element shifts in VMEM.
This trades a 5x VMEM read footprint for strictly sequential HBM streams —
the TPU-native equivalent of the paper's "one load + one store per site"
streaming bound, since the five streams are all contiguous and
prefetch-friendly.  Lattice boundaries are Dirichlet-zero, applied by masking
the clamped neighbour contributions.

VMEM budget (paper block 10x10x600, f32): 6 blocks x 240 kB = 1.4 MB << 16 MB.
TPU-tuned variants use dk a multiple of 128 lanes and dj a multiple of 8
sublanes; correctness is validated for arbitrary shapes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(c_ref, center_ref, north_ref, south_ref, west_ref,
                   east_ref, out_ref, *, nbi: int, nbj: int):
    """One (di, dj, nk) output block.

    north/south are the -1/+1 neighbour blocks along i; west/east along j.
    Index maps clamp at the lattice edge; masks zero the out-of-domain
    contributions (Dirichlet).
    """
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    c = c_ref[0]

    centre = center_ref[...]
    di, dj, nk = centre.shape
    dtype = centre.dtype

    # i-direction neighbours: previous row comes from centre shifted, with
    # row 0 filled from the north block's last row (or zero at the edge).
    north_last = north_ref[di - 1, :, :]
    north_last = jnp.where(bi == 0, jnp.zeros_like(north_last), north_last)
    up = jnp.concatenate([north_last[None], centre[:-1]], axis=0)

    south_first = south_ref[0, :, :]
    south_first = jnp.where(bi == nbi - 1, jnp.zeros_like(south_first),
                            south_first)
    down = jnp.concatenate([centre[1:], south_first[None]], axis=0)

    # j-direction neighbours.
    west_last = west_ref[:, dj - 1, :]
    west_last = jnp.where(bj == 0, jnp.zeros_like(west_last), west_last)
    left = jnp.concatenate([west_last[:, None], centre[:, :-1]], axis=1)

    east_first = east_ref[:, 0, :]
    east_first = jnp.where(bj == nbj - 1, jnp.zeros_like(east_first),
                           east_first)
    right = jnp.concatenate([centre[:, 1:], east_first[:, None]], axis=1)

    # k-direction shifts stay inside the block (dk == Nk, paper §1.4).
    zcol = jnp.zeros((di, dj, 1), dtype)
    back = jnp.concatenate([zcol, centre[:, :, :-1]], axis=2)
    front = jnp.concatenate([centre[:, :, 1:], zcol], axis=2)

    out_ref[...] = (c * (up + down + left + right + back + front)).astype(dtype)


@functools.partial(jax.jit, static_argnames=("di", "dj", "interpret"))
def jacobi_sweep_pallas(f: jnp.ndarray, c: jnp.ndarray | float = 1.0 / 6.0,
                        di: int = 10, dj: int = 10,
                        interpret: bool = True) -> jnp.ndarray:
    """One Jacobi sweep over a (Ni, Nj, Nk) lattice with (di, dj, Nk) blocks.

    ``interpret=True`` executes the kernel body in Python on CPU (validation
    mode); on TPU pass ``interpret=False``.
    """
    ni, nj, nk = f.shape
    if ni % di or nj % dj:
        raise ValueError(f"lattice {f.shape} not divisible by block ({di},{dj})")
    nbi, nbj = ni // di, nj // dj

    def centre_map(bi, bj):
        return (bi, bj, 0)

    def north_map(bi, bj):
        return (jnp.maximum(bi - 1, 0), bj, 0)

    def south_map(bi, bj):
        return (jnp.minimum(bi + 1, nbi - 1), bj, 0)

    def west_map(bi, bj):
        return (bi, jnp.maximum(bj - 1, 0), 0)

    def east_map(bi, bj):
        return (bi, jnp.minimum(bj + 1, nbj - 1), 0)

    block = (di, dj, nk)
    # scalar c as a (1,) operand broadcast to every grid cell
    c_arr = jnp.asarray(c, dtype=f.dtype).reshape(1)
    in_specs = [
        pl.BlockSpec((1,), lambda bi, bj: (0,)),
        pl.BlockSpec(block, centre_map),
        pl.BlockSpec(block, north_map),
        pl.BlockSpec(block, south_map),
        pl.BlockSpec(block, west_map),
        pl.BlockSpec(block, east_map),
    ]
    kern = functools.partial(_jacobi_kernel, nbi=nbi, nbj=nbj)
    return pl.pallas_call(
        kern,
        grid=(nbi, nbj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, centre_map),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=interpret,
    )(c_arr, f, f, f, f, f)
