"""Temporally-blocked Jacobi Pallas kernel — the paper's own §4 outlook.

The paper closes with: "Further potentials ... may be found in the
possibility to implement temporal blocking (doing more than one time step
on a block to reduce pressure on the memory subsystem)".  This kernel does
exactly that on the TPU memory hierarchy: TWO Jacobi sweeps per HBM pass.

Each grid cell loads a (di+4, dj+4, nk) extended tile (assembled in VMEM
from the centre block, its 4 edge neighbours and 4 corner neighbours via
clamped index maps + Dirichlet masks), computes sweep 1 on the inner
(di+2, dj+2) region and sweep 2 on the (di, dj) interior, and stores one
output block.  HBM traffic per site stays ~one load + one store while the
FLOPs double — arithmetic intensity 2x, which converts the paper's
memory-bound 8/3 B/flop kernel toward the compute roofline.  Generalizes
to s steps with a 2s-deep halo (VMEM budget: (di+2s)(dj+2s)nk * 4 B).

No global barrier is needed between the two steps — the paper's locality
queues are what make this safe dynamically ("no frequent global barriers
would be required", §4): a block's 2-step update depends only on its
2-halo, which the owning domain already holds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sweep_interior(x: jnp.ndarray, c) -> jnp.ndarray:
    """One Jacobi step on the interior (trims one i/j ring; k uses zero
    boundaries — dk == Nk spans the whole lattice)."""
    dtype = x.dtype
    up = x[:-2, 1:-1]
    down = x[2:, 1:-1]
    left = x[1:-1, :-2]
    right = x[1:-1, 2:]
    zcol = jnp.zeros_like(x[1:-1, 1:-1, :1])
    back = jnp.concatenate([zcol, x[1:-1, 1:-1, :-1]], axis=2)
    front = jnp.concatenate([x[1:-1, 1:-1, 1:], zcol], axis=2)
    return (c * (up + down + left + right + back + front)).astype(dtype)


def _temporal_kernel(c_ref, cc, nn, ss, ww, ee, nw, ne, sw, se, out_ref, *,
                     di: int, dj: int, nbi: int, nbj: int):
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    c = c_ref[0]
    nk = cc.shape[2]
    h = 2  # halo depth for 2 steps

    # assemble the (di+4, dj+4, nk) extended tile from the 9 blocks
    left = jnp.concatenate([nw[0][-h:, -h:], ww[0][:, -h:], sw[0][:h, -h:]],
                           axis=0)
    mid = jnp.concatenate([nn[0][-h:, :], cc[0], ss[0][:h, :]], axis=0)
    right = jnp.concatenate([ne[0][-h:, :h], ee[0][:, :h], se[0][:h, :h]],
                            axis=0)
    ext = jnp.concatenate([left, mid, right], axis=1)

    # Dirichlet mask: zero everything outside the global lattice
    gi = bi * di - h + jax.lax.broadcasted_iota(jnp.int32, ext.shape, 0)
    gj = bj * dj - h + jax.lax.broadcasted_iota(jnp.int32, ext.shape, 1)
    inside = (gi >= 0) & (gi < nbi * di) & (gj >= 0) & (gj < nbj * dj)
    ext = jnp.where(inside, ext, jnp.zeros_like(ext))

    t1 = _sweep_interior(ext, c)        # (di+2, dj+2, nk)
    # Dirichlet holds at every time step: re-zero t1 entries that lie
    # outside the global lattice before they feed sweep 2
    gi1 = bi * di - 1 + jax.lax.broadcasted_iota(jnp.int32, t1.shape, 0)
    gj1 = bj * dj - 1 + jax.lax.broadcasted_iota(jnp.int32, t1.shape, 1)
    inside1 = (gi1 >= 0) & (gi1 < nbi * di) & (gj1 >= 0) & (gj1 < nbj * dj)
    t1 = jnp.where(inside1, t1, jnp.zeros_like(t1))
    t2 = _sweep_interior(t1, c)         # (di,   dj,   nk)
    out_ref[0] = t2


@functools.partial(jax.jit, static_argnames=("di", "dj", "interpret"))
def jacobi_two_step_pallas(f: jnp.ndarray, c: jnp.ndarray | float = 1.0 / 6.0,
                           di: int = 10, dj: int = 10,
                           interpret: bool = True) -> jnp.ndarray:
    """TWO Jacobi sweeps in one HBM pass over a (Ni, Nj, Nk) lattice.

    Requires di, dj >= 2 (2-deep halo must fit inside one neighbour block).
    """
    ni, nj, nk = f.shape
    if ni % di or nj % dj:
        raise ValueError(f"lattice {f.shape} not divisible by ({di},{dj})")
    if di < 2 or dj < 2:
        raise ValueError("temporal blocking needs di, dj >= 2")
    nbi, nbj = ni // di, nj // dj

    def clamp(i, n):
        return jnp.clip(i, 0, n - 1)

    block = (1, di, dj, nk)
    f4 = f[None]

    def mk(di_off, dj_off):
        def idx(bi, bj):
            return (0, clamp(bi + di_off, nbi), clamp(bj + dj_off, nbj), 0)
        return pl.BlockSpec(block, idx)

    c_arr = jnp.asarray(c, dtype=f.dtype).reshape(1)
    kern = functools.partial(_temporal_kernel, di=di, dj=dj, nbi=nbi, nbj=nbj)
    out = pl.pallas_call(
        kern,
        grid=(nbi, nbj),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, bj: (0,)),
            mk(0, 0),                     # centre
            mk(-1, 0), mk(1, 0),          # N, S
            mk(0, -1), mk(0, 1),          # W, E
            mk(-1, -1), mk(-1, 1),        # NW, NE
            mk(1, -1), mk(1, 1),          # SW, SE
        ],
        out_specs=mk(0, 0),
        out_shape=jax.ShapeDtypeStruct((1, ni, nj, nk), f.dtype),
        interpret=interpret,
    )(c_arr, f4, f4, f4, f4, f4, f4, f4, f4, f4)
    return out[0]
