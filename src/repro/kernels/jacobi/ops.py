"""Public jit'd entry points for the Jacobi stencil kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import jacobi_sweep_pallas
from .ref import jacobi_sweep_ref


def jacobi_sweep(f: jnp.ndarray, c: float = 1.0 / 6.0, di: int = 10,
                 dj: int = 10, use_pallas: bool = True,
                 interpret: bool = True) -> jnp.ndarray:
    """One Jacobi sweep; Pallas kernel (TPU target) or jnp reference.

    ``interpret`` is forced on CPU (this container); on real TPU hardware
    call with ``interpret=False``.
    """
    if use_pallas:
        return jacobi_sweep_pallas(f, c, di=di, dj=dj, interpret=interpret)
    return jacobi_sweep_ref(f, c)


def jacobi_iterate(f: jnp.ndarray, steps: int, c: float = 1.0 / 6.0,
                   use_pallas: bool = False) -> jnp.ndarray:
    """`steps` sweeps via lax.scan (double-buffered, as in the paper)."""
    def body(carry, _):
        return jacobi_sweep(carry, c, use_pallas=use_pallas), None

    out, _ = jax.lax.scan(body, f, None, length=steps)
    return out
