"""Pure-jnp oracle for the blocked 3D six-point Jacobi sweep (paper §1.4).

F_{t+1}(i,j,k) = c * [ F_t(i-1,j,k) + F_t(i+1,j,k)
                     + F_t(i,j-1,k) + F_t(i,j+1,k)
                     + F_t(i,j,k-1) + F_t(i,j,k+1) ]

Dirichlet boundary: sites outside the lattice are zero.
"""
from __future__ import annotations

import jax.numpy as jnp


def jacobi_sweep_ref(f: jnp.ndarray, c: float | jnp.ndarray = 1.0 / 6.0) -> jnp.ndarray:
    """One whole-lattice Jacobi sweep on a (Ni, Nj, Nk) array."""
    p = jnp.pad(f, 1)
    out = (p[:-2, 1:-1, 1:-1] + p[2:, 1:-1, 1:-1]
           + p[1:-1, :-2, 1:-1] + p[1:-1, 2:, 1:-1]
           + p[1:-1, 1:-1, :-2] + p[1:-1, 1:-1, 2:])
    return (c * out).astype(f.dtype)


def jacobi_block_ref(f: jnp.ndarray, i0: int, j0: int, di: int, dj: int,
                     c: float = 1.0 / 6.0) -> jnp.ndarray:
    """Jacobi update of one (di, dj, Nk) block of the full lattice — the
    paper's ``jacobi_sweep_block()`` — with global boundary conditions."""
    return jacobi_sweep_ref(f, c)[i0:i0 + di, j0:j0 + dj, :]
