"""Pure-jnp oracle for the RWKV-6 WKV recurrence (per head):

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = (S_{t-1} + diag(u) k_t v_tᵀ)ᵀ r_t
"""
from __future__ import annotations

import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0=None):
    """r,k,v,w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (o (B,T,H,hd), sT)."""
    b, t, h, hd = r.shape
    s = s0 if s0 is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    outs = []
    for i in range(t):
        rt, kt, vt, wt = (x[:, i].astype(jnp.float32) for x in (r, k, v, w))
        kv = kt[..., :, None] * vt[..., None, :]
        s_eff = s + u[None, :, :, None].astype(jnp.float32) * kv
        outs.append(jnp.einsum("bhij,bhi->bhj", s_eff, rt))
        s = wt[..., :, None] * s + kv
    return jnp.stack(outs, axis=1), s
