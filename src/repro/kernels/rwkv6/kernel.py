"""Pallas TPU kernel for the RWKV-6 WKV chunked recurrence.

TPU adaptation: the (hd x hd) state matrix per (batch, head) is the working
set; it stays resident in VMEM scratch across the sequential time-chunk
grid axis while (r, k, v, w) chunks stream HBM→VMEM.  A naive XLA scan
spills the state to HBM every step (T x hd² bytes of traffic); the kernel's
traffic is the streaming inputs plus one state spill per chunk — the same
insight as the paper's blocked Jacobi (keep the hot working set in the
near memory tier, stream the rest).

The matmul form of chunked linear attention (turning the inner loop into
MXU matmuls with decay-ratio matrices) requires log-space normalization to
avoid exp overflow with data-dependent decay; we keep the exact sequential
inner loop (VPU) and note the matmul variant as a further optimization in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref,
                s_scr, *, chunk: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0]                                       # (hd,)

    def body(i, s):
        rt = r_ref[0, i]
        kt = k_ref[0, i]
        vt = v_ref[0, i]
        wt = w_ref[0, i]
        kv = kt[:, None] * vt[None, :]                 # (hd, hd)
        s_eff = s + u[:, None] * kv
        o_ref[0, i] = jnp.einsum("ij,i->j", s_eff, rt)
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, body, s_scr[...])
    s_scr[...] = s

    @pl.when(ic == nc - 1)
    def _write_state():
        s_final_ref[0] = s


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (B, T, H, hd) f32; u: (H, hd). Returns (o, sT).

    Zero initial state (the model folds carried state outside the kernel).
    """
    b, t, h, hd = r.shape
    if t % chunk:
        raise ValueError(f"T={t} not divisible by chunk={chunk}")
    nc = t // chunk
    bh = b * h

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(bh, t, hd)

    rr, kk, vv, ww = (to_bh(x.astype(jnp.float32)) for x in (r, k, v, w))
    uu = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, hd)).reshape(bh, hd)

    def idx(ibh, ic):
        return (ibh, ic, 0)

    def u_idx(ibh, ic):
        return (ibh, 0)

    def s_idx(ibh, ic):
        return (ibh, 0, 0)

    o, s_final = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, nc=nc),
        grid=(bh, nc),
        in_specs=[pl.BlockSpec((1, chunk, hd), idx),
                  pl.BlockSpec((1, chunk, hd), idx),
                  pl.BlockSpec((1, chunk, hd), idx),
                  pl.BlockSpec((1, chunk, hd), idx),
                  pl.BlockSpec((1, hd), u_idx)],
        out_specs=[pl.BlockSpec((1, chunk, hd), idx),
                   pl.BlockSpec((1, hd, hd), s_idx)],
        out_shape=[jax.ShapeDtypeStruct((bh, t, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)

    o = o.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    return o, s_final.reshape(b, h, hd, hd)
