"""Public entry for the RWKV-6 WKV recurrence."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import wkv6_pallas
from .ref import wkv6_ref


def wkv6(r, k, v, w, u, use_pallas: bool = True, interpret: bool = True,
         chunk: int = 64):
    """(o, sT) for the RWKV-6 recurrence with zero initial state."""
    if use_pallas and r.shape[1] % chunk == 0:
        return wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return wkv6_ref(r, k, v, w, u)
