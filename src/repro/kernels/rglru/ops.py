"""Public entry for the RG-LRU scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_pallas
from .ref import rglru_scan_ref


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, use_pallas: bool = True,
               interpret: bool = True, chunk: int = 128) -> jnp.ndarray:
    if use_pallas and a.shape[1] % chunk == 0:
        return rglru_scan_pallas(a, b, chunk=chunk, interpret=interpret)
    # associative-scan fallback (what the model layer uses on CPU)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
