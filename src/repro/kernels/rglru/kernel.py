"""Pallas TPU kernel for the RG-LRU linear recurrence (chunked scan).

TPU adaptation: the recurrence h_t = a_t h_{t-1} + b_t is sequential in t,
but only the (W,)-wide carry crosses chunk boundaries.  The grid iterates
(batch, time-chunks) with the time axis innermost-sequential on TPU, so the
carry lives in a VMEM scratch that persists across chunk steps — the HBM
traffic is exactly one read of (a, b) and one write of h (the memory-bound
optimum), where a naive XLA scan materializes the carry to HBM every step.
Within a chunk a log-depth blocked doubling recurrence would also work; the
simple fori_loop over rows keeps the kernel exact and VPU-friendly since W
(the lane axis) is the wide dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def body(i, h):
        h = a_ref[0, i] * h + b_ref[0, i]
        o_ref[0, i] = h
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan_pallas(a: jnp.ndarray, b: jnp.ndarray, chunk: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """a, b: (B, T, W) f32; h0 = 0. Returns h (B, T, W)."""
    bt, t, w = a.shape
    if t % chunk:
        raise ValueError(f"T={t} not divisible by chunk={chunk}")
    nc = t // chunk

    def idx(ib, ic):
        return (ib, ic, 0)

    return pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=(bt, nc),
        in_specs=[pl.BlockSpec((1, chunk, w), idx),
                  pl.BlockSpec((1, chunk, w), idx)],
        out_specs=pl.BlockSpec((1, chunk, w), idx),
        out_shape=jax.ShapeDtypeStruct((bt, t, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
