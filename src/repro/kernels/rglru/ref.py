"""Pure-jnp oracle for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                   h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """a, b: (B, T, W); h0 (B, W). Returns h (B, T, W) — plain loop oracle."""
    bt, t, w = a.shape
    h = h0 if h0 is not None else jnp.zeros((bt, w), a.dtype)
    outs = []
    for i in range(t):
        h = a[:, i] * h + b[:, i]
        outs.append(h)
    return jnp.stack(outs, axis=1)
