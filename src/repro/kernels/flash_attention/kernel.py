"""Pallas TPU flash attention (GQA, causal, sliding window).

TPU adaptation notes: the kernel follows the classic FlashAttention-2
online-softmax recurrence, but the blocking is chosen for the MXU/VMEM
rather than for CUDA SMs — q/k blocks are multiples of 128 on the
lane-mapped (head_dim) and sublane (sequence) axes, the (bq x bk) logits
tile feeds the 128x128 systolic array directly, and the running (m, l, acc)
state lives in VMEM scratch that persists across the *sequential* TPU grid
(the innermost grid dimension on TPU iterates in order on one core, so no
atomics/semaphores are needed, unlike the GPU formulation).

Grid: (batch*q_heads, num_q_blocks, num_k_blocks) — k innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  q_offset: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # skip fully-masked blocks (upper triangle / outside the window)
    def block_needed():
        if not causal:
            return jnp.bool_(True)
        first_q = q_offset + iq * bq
        last_q = first_q + bq - 1
        first_k = ik * bk
        last_k = first_k + bk - 1
        need = first_k <= last_q
        if window > 0:
            need &= last_k > first_q - window
        return need

    @pl.when(block_needed())
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            ok = k_pos <= q_pos
            if window > 0:
                ok &= k_pos > q_pos - window
            s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-37)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B, Hq, Tq, hd); k, v (B, Hkv, Tk, hd) -> (B, Hq, Tq, hd).

    Requires Tq % bq == 0 and Tk % bk == 0 (pad upstream if needed).
    """
    b, hq, tq, hd = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    g = hq // hkv
    if tq % bq or tk % bk:
        raise ValueError(f"seq lens ({tq},{tk}) not divisible by blocks ({bq},{bk})")
    nq, nk = tq // bq, tk // bk
    bh = b * hq

    qr = q.reshape(bh, tq, hd)
    # expand kv heads to q heads via index map (no materialized broadcast)
    kr = k.reshape(b * hkv, tk, hd)
    vr = v.reshape(b * hkv, tk, hd)

    def q_map(h, iq, ik):
        return (h, iq, 0)

    def kv_map(h, iq, ik):
        # h enumerates (batch, q_head); its kv row is batch*hkv + q_head//g
        return ((h // hq) * hkv + (h % hq) // g, ik, 0)

    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        q_offset=q_offset, scale=hd ** -0.5)

    out = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((bh, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, tq, hd)
