"""Pure-jnp oracle for the flash attention kernel (GQA, causal, windowed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, window: int = 0,
            q_offset: int = 0) -> jnp.ndarray:
    """q (B, Hq, Tq, hd); k, v (B, Hkv, Tk, hd) -> (B, Hq, Tq, hd).

    GQA: q head h attends to kv head h // (Hq // Hkv).
    """
    b, hq, tq, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, tq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * (hd ** -0.5)
    if causal:
        qi = q_offset + jnp.arange(tq)[:, None]
        ki = jnp.arange(k.shape[2])[None, :]
        ok = ki <= qi
        if window > 0:
            ok &= ki > qi - window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, vf)
    return o.reshape(b, hq, tq, hd).astype(q.dtype)
