"""Public entry for flash attention: kernel on TPU, oracle elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention
from .ref import mha_ref


def fused_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, use_pallas: bool = True,
                    interpret: bool = True) -> jnp.ndarray:
    """(B, Hq, Tq, hd) x (B, Hkv, Tk, hd) -> (B, Hq, Tq, hd)."""
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, interpret=interpret)
    return mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
