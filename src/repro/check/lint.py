"""Head 1: the determinism linter — an AST pass over ``src/repro/``.

Determinism in this repo is a *source-level* property: the executor steps
workers in a fixed order, every RNG is a seeded ``default_rng``, and the
only clock any decision may read is the step counter.  The linter proves
the cheap half of that statically, per rule (see ``rules.LINT_RULES``):

  wall-clock      no ``time.time`` / ``perf_counter*`` / ``datetime.now``
                  outside explicitly suppressed profiler sites
  unseeded-rng    no stdlib ``random``, no ``np.random.<fn>`` module calls,
                  no ``default_rng()`` without a seed argument
  unordered-iter  no iteration over set/frozenset values in scheduling code
  id-order        no ``id()`` anywhere in the core (addresses vary per run)
  env-read        no ``os.environ`` / ``os.getenv`` in runtime/control/obs
  state-view      no public method returning a live mutable container
                  attribute (callers could mutate governor state through it)

``hook-purity`` is the expensive half and lives in ``check.purity`` (it
needs a cross-module call graph); both heads share the suppression and
report machinery here.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable

from .rules import (Violation, apply_suppressions, in_scope, package_of,
                    parse_suppressions)

# clock functions per module: reading any of these inside the core makes a
# decision (or a recorded value) depend on wall time
TIME_FUNCS = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns", "clock_gettime",
    "clock_gettime_ns"})
DATETIME_FUNCS = frozenset({"now", "utcnow", "today"})
# np.random module-level functions that draw from the hidden global state;
# constructing generators/seeds is fine — *using* the global stream is not
NP_RANDOM_OK = frozenset({"default_rng", "Generator", "SeedSequence",
                          "PCG64", "Philox", "BitGenerator"})
# constructors whose results are order-unstable across runs when iterated
MUTABLE_CTORS = frozenset({"dict", "list", "set", "defaultdict", "deque",
                           "OrderedDict", "Counter"})


def repro_root() -> str:
    """Absolute path of the ``repro`` package being linted (this tree)."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def iter_source_files(root: str | None = None) -> Iterable[tuple[str, str]]:
    """Yield ``(abspath, relpath)`` for every ``.py`` under the repro root,
    sorted so reports and suppression audits are stable."""
    root = root or repro_root()
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                ap = os.path.join(dirpath, fn)
                out.append((ap, os.path.relpath(ap, root)))
    return out


class _Imports(ast.NodeVisitor):
    """Track how time/datetime/random/numpy/os are visible in a module."""

    def __init__(self) -> None:
        self.modules: dict[str, str] = {}   # local name -> module it names
        self.members: dict[str, tuple[str, str]] = {}  # name -> (module, attr)

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.modules[a.asname or a.name.split(".")[0]] = a.name

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None or node.level:
            return                       # relative imports are repro-internal
        for a in node.names:
            self.members[a.asname or a.name] = (node.module, a.name)


def module_imports(tree: ast.AST) -> _Imports:
    imp = _Imports()
    imp.visit(tree)
    return imp


def call_target(node: ast.Call, imp: _Imports) -> tuple[str, str] | None:
    """Resolve a call to ``(module, func)`` when its callee is a plain
    imported module attribute (``time.time()``) or a from-imported name
    (``perf_counter_ns()``).  Dotted module imports (``os.path``) resolve to
    their root module."""
    f = node.func
    if isinstance(f, ast.Name):
        return imp.members.get(f.id)
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        mod = imp.modules.get(f.value.id)
        if mod is not None:
            return (mod, f.attr)
        member = imp.members.get(f.value.id)
        if member is not None:           # e.g. from datetime import datetime
            return (f"{member[0]}.{member[1]}", f.attr)
    return None


def is_wall_clock(node: ast.Call, imp: _Imports) -> bool:
    tgt = call_target(node, imp)
    if tgt is None:
        # np.datetime64('now') style is out of core scope; handle the common
        # datetime.datetime.now() chain explicitly
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in DATETIME_FUNCS
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == "datetime"
                and isinstance(f.value.value, ast.Name)
                and imp.modules.get(f.value.value.id) == "datetime"):
            return True
        return False
    mod, fn = tgt
    if mod == "time" and fn in TIME_FUNCS:
        return True
    if mod in ("datetime", "datetime.datetime") and fn in DATETIME_FUNCS:
        return True
    return False


def rng_violation(node: ast.Call, imp: _Imports) -> str | None:
    """Return a message when ``node`` draws nondeterministic randomness."""
    f = node.func
    # stdlib random: module functions and from-imports alike share one
    # hidden, unseeded-by-default global state
    tgt = call_target(node, imp)
    if tgt is not None and tgt[0] == "random":
        return f"stdlib random.{tgt[1]}() draws from hidden global state"
    # np.random.<fn>(...) — the legacy global stream
    if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Attribute)
            and f.value.attr == "random"
            and isinstance(f.value.value, ast.Name)
            and imp.modules.get(f.value.value.id) == "numpy"
            and f.attr not in NP_RANDOM_OK):
        return (f"np.random.{f.attr}() uses the global numpy stream — "
                "use a seeded default_rng Generator")
    # default_rng() with no seed argument
    is_default_rng = (
        (tgt is not None and tgt == ("numpy.random", "default_rng"))
        or (isinstance(f, ast.Attribute) and f.attr == "default_rng"))
    if is_default_rng and not node.args and not node.keywords:
        return "default_rng() without a seed is entropy-seeded"
    return None


def env_violation(node: ast.AST, imp: _Imports) -> str | None:
    if isinstance(node, ast.Attribute) and node.attr == "environ" \
            and isinstance(node.value, ast.Name) \
            and imp.modules.get(node.value.id) == "os":
        return "os.environ read"
    if isinstance(node, ast.Call):
        tgt = call_target(node, imp)
        if tgt in (("os", "getenv"), ("os", "environ")):
            return "os.getenv() read"
        if tgt is not None and tgt == ("os", "getenv"):
            return "os.getenv() read"
    if isinstance(node, ast.Name) and node.id in imp.members \
            and imp.members[node.id] == ("os", "environ"):
        return "os.environ read (from-import)"
    return None


def _is_unordered_expr(node: ast.AST,
                       set_names: set[str]) -> bool:
    """Does ``node`` evaluate to a set (or a dict keyed off one)?"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.Name) and node.id in set_names:
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "dict" and node.args \
            and _is_unordered_expr(node.args[0], set_names):
        return True
    return False


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, package: str, imp: _Imports):
        self.path = path
        self.package = package
        self.imp = imp
        self.violations: list[Violation] = []
        self._set_names: list[set[str]] = [set()]   # per-function scopes

    def flag(self, rule: str, node: ast.AST, message: str) -> None:
        if in_scope(rule, self.package):
            self.violations.append(
                Violation(self.path, getattr(node, "lineno", 1), rule,
                          message))

    # -- scope tracking for unordered-iter ----------------------------------
    def _in_function(self, node: ast.AST) -> None:
        self._set_names.append(set())
        self.generic_visit(node)
        self._set_names.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._in_function(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._in_function(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if _is_unordered_expr(node.value, self._set_names[-1]):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self._set_names[-1].add(t.id)
        else:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self._set_names[-1].discard(t.id)
        self.generic_visit(node)

    # -- the rules -----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        if is_wall_clock(node, self.imp):
            self.flag("wall-clock", node,
                      "wall-clock read in the deterministic core")
        msg = rng_violation(node, self.imp)
        if msg is not None:
            self.flag("unseeded-rng", node, msg)
        if isinstance(node.func, ast.Name) and node.func.id == "id" \
                and node.args:
            self.flag("id-order", node,
                      "id() keys/orders by object address, which varies "
                      "across runs")
        env = env_violation(node, self.imp)
        if env is not None:
            self.flag("env-read", node, env)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        env = env_violation(node, self.imp)
        if env is not None:
            self.flag("env-read", node, env)
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if _is_unordered_expr(node.iter, self._set_names[-1]):
            self.flag("unordered-iter", node,
                      "iteration over a set — order is hash-seed dependent; "
                      "sort first")
        self.generic_visit(node)

    def visit_comprehension_iter(self, node: ast.expr) -> None:
        if _is_unordered_expr(node, self._set_names[-1]):
            self.flag("unordered-iter", node,
                      "comprehension over a set — order is hash-seed "
                      "dependent; sort first")

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self.visit_comprehension_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._check_state_views(node)
        self.generic_visit(node)

    def _check_state_views(self, cls: ast.ClassDef) -> None:
        """state-view: public methods returning ``self._x`` where ``_x`` was
        initialized to a mutable container in this class."""
        mutable_attrs: set[str] = set()
        for stmt in ast.walk(cls):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t = stmt.targets[0]
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    v = stmt.value
                    is_mut = isinstance(v, (ast.Dict, ast.List, ast.Set,
                                            ast.ListComp, ast.SetComp,
                                            ast.DictComp))
                    if isinstance(v, ast.Call) \
                            and isinstance(v.func, ast.Name) \
                            and v.func.id in MUTABLE_CTORS:
                        is_mut = True
                    if is_mut:
                        mutable_attrs.add(t.attr)
        if not mutable_attrs:
            return
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name.startswith("_"):
                continue                  # private surface may share views
            for ret in ast.walk(item):
                if isinstance(ret, ast.Return) \
                        and isinstance(ret.value, ast.Attribute) \
                        and isinstance(ret.value.value, ast.Name) \
                        and ret.value.value.id == "self" \
                        and ret.value.attr in mutable_attrs:
                    self.flag("state-view", ret,
                              f"{cls.name}.{item.name} returns the live "
                              f"mutable attribute self.{ret.value.attr} — "
                              "return a copy")


def lint_source(source: str, relpath: str) -> list[Violation]:
    """Lint one module's source; returns suppression-applied violations
    (including ``bad-suppression`` findings)."""
    package = package_of(relpath)
    tree = ast.parse(source, filename=relpath)
    imp = module_imports(tree)
    linter = _FileLinter(relpath, package, imp)
    linter.visit(tree)
    sups, bad = parse_suppressions(source, relpath)
    return apply_suppressions(linter.violations, sups) + bad


def lint_tree(root: str | None = None) -> list[Violation]:
    """Lint every module under the repro root (plus the cross-module
    hook-purity pass); returns all findings, suppressed ones included."""
    from .purity import check_hook_purity     # avoid import cycle
    files = list(iter_source_files(root))
    sources = {rel: open(ap, "r", encoding="utf-8").read()
               for ap, rel in files}
    violations: list[Violation] = []
    for rel, src in sources.items():
        violations += lint_source(src, rel)
    violations += check_hook_purity(sources)
    violations.sort(key=lambda v: (v.file, v.line, v.rule))
    return violations
