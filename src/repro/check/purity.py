"""Hook purity: the call-graph half of the determinism linter.

The executor exposes five plug points — ``submit_hook``, ``step_hook``,
``router``, ``batch``, ``governor`` — and the whole record/replay guarantee
assumes everything plugged in is a *pure observer of deterministic executor
state*: it may read queue depths, stats, and step counters, but the moment a
hook touches wall clock, hidden RNG, the environment, or I/O, the schedule
(or the recorded trace) can differ between a run and its replay.

This pass finds every hook registration site across ``src/repro/``
(attribute assignments ``x.submit_hook = f`` and constructor keywords
``Executor(..., router=f)``), resolves each registered value to function
roots — a method, a module function, a lambda, or every public method of a
governor/batch class — and walks the static call graph underneath.  Any
reachable *impurity primitive* (clock read, global RNG draw, environment
read, ``open``/``print``/``subprocess``/... I/O) is reported as a
``hook-purity`` violation at the impure call site, naming the hook root it
is reachable from, so a sanctioned site (the streaming trace writer) can be
suppressed exactly where the impurity lives.

Resolution is name-based and deliberately over-approximate: ``self.m()``
binds to the enclosing class's ``m`` when it has one, otherwise (and for
``expr.m()``) to every class method named ``m`` in the tree, minus a
denylist of ubiquitous container/protocol names.  Over-approximation errs
toward false positives, which suppressions-with-reasons then document —
the right default for a determinism gate.
"""
from __future__ import annotations

import ast
import dataclasses

from .lint import (env_violation, is_wall_clock, module_imports,
                   rng_violation, call_target, _Imports)
from .rules import (Violation, apply_suppressions, in_scope, package_of,
                    parse_suppressions)

HOOK_NAMES = ("submit_hook", "step_hook", "router", "batch", "governor")

# method names too generic to resolve globally (every container has them);
# resolving these to all same-named methods would connect the whole tree
METHOD_DENYLIST = frozenset({
    "get", "items", "keys", "values", "append", "appendleft", "pop",
    "popleft", "add", "extend", "update", "clear", "copy", "sort", "remove",
    "discard", "insert", "count", "index", "join", "split", "strip",
    "startswith", "endswith", "format", "encode", "decode", "setdefault",
    "close", "flush", "write", "read", "readline", "get_event_loop",
    "walk", "mean", "sum", "min", "max", "round", "most_common"})

# bare-name calls that perform I/O (print included: hooks run on the hot
# path, and stdout writes there would also skew the self-profiler)
IO_BUILTINS = frozenset({"open", "print", "input", "breakpoint"})
IO_MODULE_CALLS = frozenset({
    ("os", "makedirs"), ("os", "remove"), ("os", "rmdir"), ("os", "system"),
    ("os", "popen"), ("os", "rename"), ("os", "replace"),
    ("shutil", "rmtree"), ("shutil", "copy"), ("shutil", "copytree"),
    ("subprocess", "run"), ("subprocess", "Popen"), ("subprocess", "call"),
    ("subprocess", "check_output"), ("subprocess", "check_call")})


@dataclasses.dataclass
class FuncNode:
    """One function/method/lambda in the cross-module graph."""

    qualname: str              # module:Class.method or module:function
    relpath: str
    node: ast.AST              # FunctionDef / AsyncFunctionDef / Lambda
    cls: str | None            # enclosing class name, if a method
    imports: _Imports          # its module's import table


class _Collector(ast.NodeVisitor):
    """Index every function definition in one module."""

    def __init__(self, relpath: str, imports: _Imports):
        self.relpath = relpath
        self.imports = imports
        self.funcs: dict[str, FuncNode] = {}       # qualname -> node
        self.by_class: dict[tuple[str, str], FuncNode] = {}
        self.module_funcs: dict[str, FuncNode] = {}  # bare name -> node
        self.classes: set[str] = set()
        self._stack: list[str] = []
        self._cls: list[str | None] = [None]

    def _add(self, name: str, node: ast.AST) -> FuncNode:
        qual = f"{self.relpath}:{'.'.join(self._stack + [name])}"
        fn = FuncNode(qual, self.relpath, node, self._cls[-1], self.imports)
        self.funcs[qual] = fn
        if self._cls[-1] is not None and len(self._stack) >= 1 \
                and self._stack[-1] == self._cls[-1]:
            self.by_class[(self._cls[-1], name)] = fn
        if not self._stack:
            self.module_funcs[name] = fn
        return fn

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._add(node.name, node)
        self._stack.append(node.name)
        self._cls.append(self._cls[-1])
        self.generic_visit(node)
        self._cls.pop()
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.classes.add(node.name)
        self._stack.append(node.name)
        self._cls.append(node.name)
        self.generic_visit(node)
        self._cls.pop()
        self._stack.pop()


class _Graph:
    """The whole-tree index the reachability walk runs over."""

    def __init__(self) -> None:
        self.collectors: dict[str, _Collector] = {}   # relpath -> collector
        self.trees: dict[str, ast.AST] = {}
        # method name -> every (class, node) defining it, across the tree
        self.methods: dict[str, list[FuncNode]] = {}
        # class name -> its collector (classes are uniquely named in repro)
        self.class_home: dict[str, _Collector] = {}

    def add_module(self, relpath: str, source: str) -> None:
        tree = ast.parse(source, filename=relpath)
        col = _Collector(relpath, module_imports(tree))
        col.visit(tree)
        self.collectors[relpath] = col
        self.trees[relpath] = tree
        for (cls, name), fn in col.by_class.items():
            self.methods.setdefault(name, []).append(fn)
        for cls in col.classes:
            self.class_home.setdefault(cls, col)

    # -- call resolution ----------------------------------------------------
    def resolve_call(self, call: ast.Call,
                     ctx: FuncNode) -> list[FuncNode]:
        f = call.func
        col = self.collectors[ctx.relpath]
        if isinstance(f, ast.Name):
            # bare name: module function, or a class -> its __init__
            fn = col.module_funcs.get(f.id)
            if fn is not None:
                return [fn]
            if f.id in col.classes:
                init = col.by_class.get((f.id, "__init__"))
                return [init] if init else []
            member = ctx.imports.members.get(f.id)
            if member is not None:
                # from-import of a repro-internal name resolves nowhere here
                # (relative imports carry module=None); absolute stdlib
                # imports are handled by the impurity primitives instead.
                return []
            return []
        if isinstance(f, ast.Attribute):
            name = f.attr
            if isinstance(f.value, ast.Name) and f.value.id in col.classes:
                fn = col.by_class.get((f.value.id, name))
                return [fn] if fn else []
            if isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and ctx.cls is not None:
                own = col.by_class.get((ctx.cls, name))
                if own is not None:
                    return [own]
            if name in METHOD_DENYLIST:
                return []
            return list(self.methods.get(name, []))
        return []

    def class_roots(self, cls_name: str) -> list[FuncNode]:
        """All public methods of a class — the hook faces a governor/batch
        object exposes.  Private helpers are reached transitively."""
        col = self.class_home.get(cls_name)
        if col is None:
            return []
        return [fn for (c, m), fn in col.by_class.items()
                if c == cls_name and not m.startswith("_")]


def _own_nodes(fn: FuncNode) -> list[ast.AST]:
    """All AST nodes lexically in ``fn``'s body, excluding nested def
    bodies (those only run if called; calls to them are graph edges).
    Lambdas are kept inline — a hook's inline lambda runs when it runs."""
    out: list[ast.AST] = []
    body = fn.node.body if isinstance(fn.node.body, list) else [fn.node.body]
    stack: list[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        out.append(node)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)
    return out


def _impurities(fn: FuncNode) -> list[tuple[int, str]]:
    """Impurity primitives directly inside one function body."""
    out: list[tuple[int, str]] = []
    for node in _own_nodes(fn):
        if isinstance(node, ast.Call):
            if is_wall_clock(node, fn.imports):
                out.append((node.lineno, "wall-clock read"))
            msg = rng_violation(node, fn.imports)
            if msg is not None:
                out.append((node.lineno, msg))
            if isinstance(node.func, ast.Name) \
                    and node.func.id in IO_BUILTINS:
                out.append((node.lineno, f"{node.func.id}() I/O"))
            tgt = call_target(node, fn.imports)
            if tgt in IO_MODULE_CALLS:
                out.append((node.lineno, f"{tgt[0]}.{tgt[1]}() I/O"))
        env = env_violation(node, fn.imports)
        if env is not None:
            out.append((node.lineno, env))
    return out


def _nested_defs(fn: FuncNode, col: _Collector) -> list[FuncNode]:
    """Functions lexically nested in ``fn`` (closures a hook may install)
    are conservatively treated as called: a step-hook closure's helpers run
    when it runs."""
    out = []
    for node in ast.walk(fn.node):
        if node is fn.node:
            continue
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for cand in col.funcs.values():
                if cand.node is node:
                    out.append(cand)
    return out


def _hook_roots(graph: _Graph) -> list[tuple[FuncNode, str, str]]:
    """Find hook registrations; return ``(root, hook_name, site)`` triples.

    Registration shapes resolved:
      x.<hook> = self.method          -> that method
      x.<hook> = name                 -> module function named ``name``
      x.<hook> = lambda ...           -> the lambda body
      x.<hook> = Cls(...) / a or Cls()-> every public method of ``Cls``
      x.<hook> = self.attr            -> class of ``self.attr = Cls(...)``
      Cls(..., <hook>=value)          -> same value resolution
    Unresolvable values (plain parameters being stored) are skipped — the
    registration that *supplied* the value is the checked site.
    """
    roots: list[tuple[FuncNode, str, str]] = []

    def resolve_value(value: ast.AST, col: _Collector,
                      cls: str | None) -> list[FuncNode]:
        if isinstance(value, ast.Lambda):
            for cand in col.funcs.values():
                if cand.node is value:
                    return [cand]
            # lambdas aren't collected as defs; wrap ad hoc
            return [FuncNode(f"{col.relpath}:<lambda>", col.relpath,
                             value, cls, col.imports)]
        if isinstance(value, ast.Name):
            fn = col.module_funcs.get(value.id)
            if fn is not None:
                return [fn]
            if value.id in col.classes:
                return graph.class_roots(value.id)
            return []
        if isinstance(value, ast.Attribute) \
                and isinstance(value.value, ast.Name) \
                and value.value.id == "self" and cls is not None:
            m = col.by_class.get((cls, value.attr))
            if m is not None:
                return [m]
            # self.attr holding an object: find ``self.attr = Cls(...)``
            out: list[FuncNode] = []
            for (c, _m), fn in col.by_class.items():
                if c != cls:
                    continue
                for node in ast.walk(fn.node):
                    if isinstance(node, ast.Assign) \
                            and len(node.targets) == 1 \
                            and isinstance(node.targets[0], ast.Attribute) \
                            and isinstance(node.targets[0].value, ast.Name) \
                            and node.targets[0].value.id == "self" \
                            and node.targets[0].attr == value.attr:
                        out += _classes_in(node.value, col)
            return out
        if isinstance(value, (ast.Call, ast.BoolOp, ast.IfExp)):
            return _classes_in(value, col)
        return []

    def _classes_in(value: ast.AST, col: _Collector) -> list[FuncNode]:
        out: list[FuncNode] = []
        for node in ast.walk(value):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in graph.class_home:
                out += graph.class_roots(node.func.id)
        return out

    for relpath, tree in graph.trees.items():
        col = graph.collectors[relpath]

        # walk with enclosing-class context so self.* resolves
        def walk(node: ast.AST, cls: str | None) -> None:
            for child in ast.iter_child_nodes(node):
                child_cls = child.name if isinstance(child,
                                                     ast.ClassDef) else cls
                if isinstance(child, ast.Assign) \
                        and len(child.targets) == 1 \
                        and isinstance(child.targets[0], ast.Attribute) \
                        and child.targets[0].attr in HOOK_NAMES:
                    tgt = child.targets[0]
                    # ``self.<hook> = <hook>`` parameter stores inside the
                    # registering class itself aren't registrations
                    if not (isinstance(child.value, ast.Name)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"
                            and child.value.id == tgt.attr):
                        site = f"{relpath}:{child.lineno}"
                        for fn in resolve_value(child.value, col, cls):
                            roots.append((fn, tgt.attr, site))
                if isinstance(child, ast.Call):
                    for kw in child.keywords:
                        if kw.arg in HOOK_NAMES:
                            site = f"{relpath}:{child.lineno}"
                            for fn in resolve_value(kw.value, col, cls):
                                roots.append((fn, kw.arg, site))
                walk(child, child_cls)

        walk(tree, None)
    return roots


def check_hook_purity(sources: dict[str, str]) -> list[Violation]:
    """Run the purity pass over ``{relpath: source}``; returns hook-purity
    violations with per-file suppressions already applied (bad-suppression
    findings are the per-file linter's job, not repeated here)."""
    graph = _Graph()
    for rel, src in sources.items():
        graph.add_module(rel, src)

    roots = _hook_roots(graph)
    violations: list[Violation] = []
    seen: set[tuple[str, int, str]] = set()
    for root, hook, site in roots:
        if not in_scope("hook-purity", package_of(root.relpath)):
            continue
        # BFS over the static call graph from this root (AST nodes hash by
        # identity, so the visited set needs no address-based key)
        visited: set[ast.AST] = set()
        frontier = [root]
        while frontier:
            fn = frontier.pop()
            if fn.node in visited:
                continue
            visited.add(fn.node)
            for lineno, what in _impurities(fn):
                key = (fn.relpath, lineno, what)
                if key in seen:
                    continue
                seen.add(key)
                violations.append(Violation(
                    fn.relpath, lineno, "hook-purity",
                    f"{what} reachable from {hook} hook "
                    f"(registered at {site}, via {fn.qualname})"))
            col = graph.collectors[fn.relpath]
            frontier += _nested_defs(fn, col)
            for node in _own_nodes(fn):
                if isinstance(node, ast.Call):
                    frontier += graph.resolve_call(node, fn)

    # apply each file's suppressions to its violations
    out: list[Violation] = []
    by_file: dict[str, list[Violation]] = {}
    for v in violations:
        by_file.setdefault(v.file, []).append(v)
    for rel, vs in by_file.items():
        sups, _bad = parse_suppressions(sources.get(rel, ""), rel)
        out += apply_suppressions(vs, sups)
    return out
