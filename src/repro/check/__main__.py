"""``python -m repro.check`` — the CI gate.

    python -m repro.check                       # lint the tree
    python -m repro.check --json R.json --md R.md
    python -m repro.check model T.jsonl DIR/    # model-check traces
    python -m repro.check all T.jsonl ...       # lint + model in one gate

Exit status 0 when the gate passes (zero unsuppressed lint findings, every
suppression reasoned, every checked trace structurally legal), 1 otherwise.
"""
from __future__ import annotations

import argparse
import sys

from .lint import lint_tree
from .model import ModelResult, check_path
from .report import CheckReport, render_markdown, write_json, write_markdown


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.check",
        description="determinism linter + trace model checker")
    ap.add_argument("mode", nargs="?", default="lint",
                    choices=("lint", "model", "all"),
                    help="lint the tree, model-check traces, or both")
    ap.add_argument("traces", nargs="*",
                    help="trace files / segment directories (model, all)")
    ap.add_argument("--root", default=None,
                    help="lint this package root instead of the installed "
                         "repro tree")
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--md", default=None,
                    help="write the markdown report here")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the stdout report")
    args = ap.parse_args(argv)

    if args.mode in ("model", "all") and not args.traces:
        ap.error(f"mode {args.mode!r} needs at least one trace path")
    if args.mode == "lint" and args.traces:
        ap.error("mode 'lint' takes no trace paths (use 'model' or 'all')")

    lint = lint_tree(args.root) if args.mode in ("lint", "all") else []
    model: list[ModelResult] = []
    for path in args.traces:
        try:
            model.append(check_path(path))
        except Exception as exc:       # unreadable/unparseable trace
            from .rules import Violation
            model.append(ModelResult(path, [Violation(
                path, 1, "fidelity-keys", f"trace unreadable: {exc}")], []))

    report = CheckReport(lint=lint, model=model)
    if args.json:
        write_json(report, args.json)
    if args.md:
        write_markdown(report, args.md)
    if not args.quiet:
        print(render_markdown(report))
    return 0 if report.gate() else 1


if __name__ == "__main__":
    sys.exit(main())
