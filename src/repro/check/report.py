"""JSON + markdown rendering of check results.

One report object carries both heads: the lint findings over the tree
(suppressed ones included, with their reasons — the suppression ledger is
part of the artifact CI uploads) and the model-check results per trace.
``gate()`` is the single pass/fail predicate ``make check`` exits on.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from .model import ModelResult
from .rules import ALL_RULES, Violation


@dataclasses.dataclass
class CheckReport:
    lint: list[Violation]
    model: list[ModelResult]

    @property
    def active(self) -> list[Violation]:
        """Unsuppressed lint findings — each one fails the gate."""
        return [v for v in self.lint if not v.suppressed]

    @property
    def suppressed(self) -> list[Violation]:
        return [v for v in self.lint if v.suppressed]

    def gate(self) -> bool:
        """True when the tree and every checked trace are clean."""
        return not self.active and all(m.ok for m in self.model)

    def to_dict(self) -> dict[str, Any]:
        return {
            "ok": self.gate(),
            "lint": {
                "active": [v.to_dict() for v in self.active],
                "suppressed": [v.to_dict() for v in self.suppressed],
            },
            "model": [m.to_dict() for m in self.model],
            "rules": {name: r.summary for name, r in sorted(ALL_RULES.items())},
        }


def _write(path: str, text: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(text)


def write_json(report: CheckReport, path: str) -> None:
    _write(path, json.dumps(report.to_dict(), indent=2, sort_keys=True)
           + "\n")


def render_markdown(report: CheckReport) -> str:
    from ..obs.report import markdown_table
    lines = ["# repro.check report", ""]
    verdict = "PASS" if report.gate() else "FAIL"
    lines += [f"**Gate: {verdict}** — {len(report.active)} active lint "
              f"finding(s), {len(report.suppressed)} suppressed, "
              f"{sum(len(m.violations) for m in report.model)} model "
              f"violation(s) over {len(report.model)} trace(s).", ""]
    if report.active:
        lines += ["## Active lint findings", "",
                  markdown_table(
                      ["file", "line", "rule", "message"],
                      [[v.file, v.line, v.rule, v.message]
                       for v in report.active]), ""]
    if report.suppressed:
        lines += ["## Suppressions (the sanctioned-sites ledger)", "",
                  markdown_table(
                      ["file", "line", "rule", "reason"],
                      [[v.file, v.line, v.rule, v.reason or ""]
                       for v in report.suppressed]), ""]
    if report.model:
        lines += ["## Model-checked traces", "",
                  markdown_table(
                      ["trace", "verdict", "violations", "notes"],
                      [[m.path, "ok" if m.ok else "FAIL",
                        len(m.violations), "; ".join(m.notes)]
                       for m in report.model]), ""]
        bad = [(m.path, v) for m in report.model for v in m.violations]
        if bad:
            lines += ["### Model violations", "",
                      markdown_table(
                          ["trace", "record", "rule", "message"],
                          [[p, v.line, v.rule, v.message]
                           for p, v in bad]), ""]
    return "\n".join(lines)


def write_markdown(report: CheckReport, path: str) -> None:
    _write(path, render_markdown(report))
