"""The rule catalog: names, scopes, and the suppression contract.

Every finding either head of ``repro.check`` produces — the determinism
linter (``check.lint`` + ``check.purity``) and the trace model checker
(``check.model``) — carries a *rule name* from the catalog below, so CI
output, suppression comments, and the mutation tests all speak the same
vocabulary.

Lint rules are *scoped*: each applies only inside the deterministic core of
``src/repro/`` (the record/replay stack), never to the jax/model side of the
tree, which legitimately reads clocks and draws device RNG.  The scope of a
rule is a tuple of top-level package names relative to the ``repro`` root.

Suppressions
------------
A violation is silenced inline with::

    # repro: allow[rule-name] why this site is sanctioned

placed on the flagged line or on the line immediately above it.  The reason
text is mandatory — a bare ``allow[...]`` (or one naming an unknown rule) is
itself a violation (``bad-suppression``), so the shipped tree can never
accumulate unexplained escapes.  Suppressed findings stay in the JSON report
(``suppressed: true`` with the reason) for auditability.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

# the deterministic core: every package whose behaviour the record/replay
# guarantee depends on.  (models/train/kernels/launch/... are the jax side —
# wall clocks and device RNG are their job, not a hazard.)
CORE_PACKAGES = ("runtime", "trace", "control", "spec", "obs", "topology",
                 "check")
# the subset making scheduling *decisions* (iteration order is schedule order)
SCHEDULING_PACKAGES = ("runtime", "control", "topology", "trace")
# the subset the issue bans environment reads from outright
ENV_PACKAGES = ("runtime", "control", "obs")
# governor/hook state lives here (live-view returns leak governor state)
STATE_PACKAGES = ("runtime", "control", "trace")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One named check: what it flags and where it applies."""

    name: str
    scope: tuple[str, ...]
    summary: str


LINT_RULES: dict[str, Rule] = {r.name: r for r in (
    Rule("wall-clock", CORE_PACKAGES,
         "wall-clock read (time.time/perf_counter*/datetime.now) outside "
         "the sanctioned profiler sites"),
    Rule("unseeded-rng", CORE_PACKAGES,
         "unseeded RNG: stdlib random.*, numpy np.random.* module "
         "functions, or default_rng() without a seed"),
    Rule("unordered-iter", SCHEDULING_PACKAGES,
         "iteration over a set/frozenset (or a dict built from one) in "
         "scheduling code — iteration order is schedule order"),
    Rule("id-order", CORE_PACKAGES,
         "id()-based ordering or keying — object addresses differ across "
         "runs"),
    Rule("env-read", ENV_PACKAGES,
         "os.environ / os.getenv read in runtime/control/obs — "
         "configuration must arrive through specs"),
    Rule("state-view", STATE_PACKAGES,
         "public accessor returns a live mutable container attribute — "
         "callers could mutate governor state through it"),
    Rule("hook-purity", CORE_PACKAGES,
         "function registered as a submit/step/router/batch/governor hook "
         "reaches wall-clock, unseeded RNG, environment, or I/O"),
    Rule("bad-suppression", CORE_PACKAGES,
         "a `# repro: allow[...]` comment without a reason, or naming an "
         "unknown rule"),
)}

MODEL_RULES: dict[str, Rule] = {r.name: r for r in (
    Rule("fidelity-keys", ("trace",),
         "header/footer is missing a replay-fidelity key required by its "
         "schema version, or carries an inconsistent one"),
    Rule("submit-unique", ("trace",),
         "a task uid was submitted more than once (or the submission "
         "records disagree with the submit events)"),
    Rule("exec-unique", ("trace",),
         "a task uid was executed more than once"),
    Rule("exec-unsubmitted", ("trace",),
         "an executed task uid was never submitted"),
    Rule("fifo-order", ("trace",),
         "a domain queue was served out of FIFO order (or popped while "
         "empty)"),
    Rule("steal-level", ("trace",),
         "a steal edge the header's DistanceMatrix/governor forbids: "
         "domain outside the matrix, a steal under NoSteal, or a deep-tier "
         "steal while a nearer tier held eligible work under GreedySteal"),
    Rule("local-first", ("trace",),
         "a worker stole while its own domain queue held work"),
    Rule("step-monotone", ("trace",),
         "event timestamps (scheduling rounds) regressed in stream order "
         "or per worker"),
    Rule("span-nesting", ("trace",),
         "a reconstructed per-task span tree is not well-nested"),
    Rule("stats-consistency", ("trace",),
         "footer RuntimeStats disagree with the recorded event stream"),
)}

ALL_RULES: dict[str, Rule] = {**LINT_RULES, **MODEL_RULES}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding, from either head.

    ``file``/``line`` locate it (the trace path and record ordinal for
    model findings); ``suppressed`` marks findings silenced by a reasoned
    ``# repro: allow[...]`` comment — they never fail the gate but stay in
    the report.
    """

    file: str
    line: int
    rule: str
    message: str
    suppressed: bool = False
    reason: str | None = None

    def to_dict(self) -> dict:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "message": self.message, "suppressed": self.suppressed,
                "reason": self.reason}

    def __str__(self) -> str:
        mark = " [suppressed]" if self.suppressed else ""
        return f"{self.file}:{self.line}: {self.rule}: {self.message}{mark}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One parsed ``# repro: allow[rule] reason`` comment."""

    line: int
    rule: str
    reason: str


SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([^\]]*)\]\s*[-—:]?\s*(.*?)\s*$")


def parse_suppressions(source: str,
                       path: str) -> tuple[list[Suppression],
                                           list[Violation]]:
    """Extract suppression comments and flag malformed ones.

    Returns ``(suppressions, bad_suppression_violations)``.  A suppression
    must name a known rule and carry a non-empty reason; anything else is a
    ``bad-suppression`` finding (which itself cannot be suppressed).
    """
    sups: list[Suppression] = []
    bad: list[Violation] = []
    try:
        comments = [(tok.start[0], tok.string) for tok in
                    tokenize.generate_tokens(io.StringIO(source).readline)
                    if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # unparseable source: fall back to raw lines so suppressions in a
        # broken file are still audited
        comments = list(enumerate(source.splitlines(), start=1))
    for lineno, text in comments:
        m = SUPPRESS_RE.search(text)
        if m is None:
            continue
        rule, reason = m.group(1).strip(), m.group(2).strip()
        if rule not in ALL_RULES:
            bad.append(Violation(path, lineno, "bad-suppression",
                                 f"allow[{rule}] names an unknown rule "
                                 f"(known: {sorted(ALL_RULES)})"))
        elif not reason:
            bad.append(Violation(path, lineno, "bad-suppression",
                                 f"allow[{rule}] carries no reason — every "
                                 "suppression must say why the site is "
                                 "sanctioned"))
        else:
            sups.append(Suppression(lineno, rule, reason))
    return sups, bad


def apply_suppressions(violations: list[Violation],
                       suppressions: list[Suppression]) -> list[Violation]:
    """Mark violations covered by a suppression on their own line or the
    line immediately above.  ``bad-suppression`` findings are never
    silenced."""
    by_line: dict[tuple[int, str], Suppression] = {
        (s.line, s.rule): s for s in suppressions}
    out: list[Violation] = []
    for v in violations:
        sup = None
        if v.rule != "bad-suppression":
            sup = (by_line.get((v.line, v.rule))
                   or by_line.get((v.line - 1, v.rule)))
        if sup is None:
            out.append(v)
        else:
            out.append(dataclasses.replace(v, suppressed=True,
                                           reason=sup.reason))
    return out


def package_of(relpath: str) -> str:
    """Top-level package of a path relative to the ``repro`` root
    (``runtime/executor.py`` -> ``runtime``; bare modules -> """")."""
    rel = relpath.replace("\\", "/")
    return rel.split("/", 1)[0] if "/" in rel else ""


def in_scope(rule: str, package: str) -> bool:
    r = ALL_RULES.get(rule)
    return r is not None and package in r.scope
