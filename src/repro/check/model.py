"""Head 2: the trace model checker — a happens-before verifier.

``obs.diff_traces`` judges the hot-path rewrite by *stats equality*; this
module judges it by *structural legality of the schedule itself*.  Given
any recorded v1–v4 trace it replays the event stream against the
executor's invariants, without executing anything:

  fidelity-keys      header carries the six replay-fidelity meta keys,
                     footer totals agree with the retained records, an
                     embedded topology/spec block parses and matches
  submit-unique      every task uid submitted exactly once (submission
                     records and submit events agree)
  exec-unique        every task uid executed at most once
  exec-unsubmitted   every executed uid has a submission record
  step-monotone      event steps are non-decreasing in stream order and
                     per worker (the step counter never runs backwards)
  fifo-order         a per-domain deque simulation of the stream: every
                     execution pops exactly the head of its source queue
  local-first        no worker steals while its own queue held work that
                     predates the attempt
  steal-level        steal edges the header forbids: domains outside the
                     matrix, any steal under NoSteal, and — under
                     GreedySteal on a hierarchical matrix — a tier-L steal
                     while a nearer tier held eligible work (the
                     nearest-first scan invariant)
  span-nesting       ``obs.assemble_spans`` trees are well-nested
  stats-consistency  footer ``RuntimeStats`` equal the event-stream counts

Ring-buffer windows: when ``trace.events_dropped > 0`` the event list is a
suffix of the run, so the stream-simulation checks (fifo-order,
local-first, the nearest-first half of steal-level, stats-consistency, and
submit-event agreement) are *skipped and recorded as notes* rather than
reporting false violations — the same refusal contract as
``trace.storms``.

Same-step interleaving: a handler (or backpressure helping) may submit
tasks *during* a scheduling round, so a submit event can precede, in
stream order, execution events whose dequeue actually happened earlier in
that round.  Occupancy-sensitive checks therefore only count queued tasks
whose submit step strictly predates the executing event's step — a
conservative under-count that cannot produce false positives.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any

from ..trace.schema import Trace, event_stolen
from .rules import Violation

REQUIRED_META = ("num_domains", "worker_domains", "steal_order", "pool_cap",
                 "seed", "governor")
EXEC_KINDS = ("run", "steal", "inline")


@dataclasses.dataclass
class ModelResult:
    """Outcome of model-checking one trace."""

    path: str
    violations: list[Violation]
    notes: list[str]                    # checks skipped (ring-buffer window)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict[str, Any]:
        return {"path": self.path, "ok": self.ok,
                "violations": [v.to_dict() for v in self.violations],
                "notes": list(self.notes)}


def _topology(trace: Trace):
    if trace.topology_dict is None:
        return None
    from ..topology import DistanceMatrix
    return DistanceMatrix.from_dict(trace.topology_dict)


def check_trace(trace: Trace, path: str = "<trace>") -> ModelResult:
    """Model-check one in-memory ``Trace``; never raises on an illegal
    schedule — every problem is a named ``Violation``."""
    v: list[Violation] = []
    notes: list[str] = []

    def flag(rule: str, line: int, msg: str) -> None:
        v.append(Violation(path, line, rule, msg))

    # -- fidelity-keys -------------------------------------------------------
    for key in REQUIRED_META:
        if key not in trace.meta:
            flag("fidelity-keys", 1, f"header is missing meta key {key!r}")
    nd = int(trace.meta.get("num_domains", 0) or 0)
    if nd < 1:
        flag("fidelity-keys", 1, f"num_domains={nd} is not a machine")
    wd = trace.meta.get("worker_domains") or []
    if not wd:
        flag("fidelity-keys", 1, "worker_domains is empty")
    for w, d in enumerate(wd):
        if not 0 <= int(d) < max(nd, 1):
            flag("fidelity-keys", 1,
                 f"worker {w} pinned to domain {d} outside 0..{nd - 1}")
    if trace.events_retained != len(trace.events):
        flag("fidelity-keys", 1,
             f"footer claims events_retained={trace.events_retained} but "
             f"{len(trace.events)} event records are present")
    topo = None
    if trace.topology_dict is not None:
        try:
            topo = _topology(trace)
            if topo is not None and topo.num_domains != nd:
                flag("fidelity-keys", 1,
                     f"embedded topology spans {topo.num_domains} domains, "
                     f"header says {nd}")
                topo = None
        except Exception as exc:       # TopologyError and shape errors alike
            flag("fidelity-keys", 1, f"embedded topology does not parse: "
                                     f"{exc}")
            topo = None
    if trace.spec_dict is not None:
        try:
            from ..spec import RuntimeSpec
            RuntimeSpec.from_dict(trace.spec_dict)
        except Exception as exc:
            flag("fidelity-keys", 1, f"embedded spec does not parse: {exc}")
    max_step = max((e.step for e in trace.events), default=0)
    max_step = max(max_step, max((s.step for s in trace.submissions),
                                 default=0))
    if trace.total_steps < max_step:
        flag("fidelity-keys", 1,
             f"footer total_steps={trace.total_steps} predates recorded "
             f"step {max_step}")
    kind_counts: dict[str, int] = {}
    for e in trace.events:
        kind_counts[e.kind] = kind_counts.get(e.kind, 0) + 1
    windowed = trace.events_dropped > 0
    if trace.event_counts:
        for kind, n in kind_counts.items():
            total = int(trace.event_counts.get(kind, 0))
            if total < n or (not windowed and total != n):
                flag("fidelity-keys", 1,
                     f"footer event_counts[{kind!r}]={total} vs {n} "
                     "retained events of that kind")

    # -- submit/exec uniqueness ---------------------------------------------
    sub_counts: dict[int, int] = {}
    for s in trace.submissions:
        sub_counts[s.uid] = sub_counts.get(s.uid, 0) + 1
    for uid, n in sub_counts.items():
        if n > 1:
            flag("submit-unique", 1,
                 f"task uid {uid} has {n} submission records")
    ev_submits: dict[int, int] = {}
    exec_counts: dict[int, int] = {}
    for i, e in enumerate(trace.events, start=1):
        if e.kind == "submit":
            ev_submits[e.task_uid] = ev_submits.get(e.task_uid, 0) + 1
        elif e.kind in EXEC_KINDS and e.task_uid >= 0:
            exec_counts[e.task_uid] = exec_counts.get(e.task_uid, 0) + 1
    for uid, n in ev_submits.items():
        if n > 1:
            flag("submit-unique", 1, f"task uid {uid} has {n} submit events")
    if not windowed:
        missing = set(sub_counts) - set(ev_submits)
        extra = set(ev_submits) - set(sub_counts)
        if missing:
            flag("submit-unique", 1,
                 f"{len(missing)} submitted uids have no submit event "
                 f"(e.g. {sorted(missing)[:3]})")
        if extra:
            flag("submit-unique", 1,
                 f"{len(extra)} submit events lack submission records "
                 f"(e.g. {sorted(extra)[:3]})")
    else:
        notes.append("submit-event agreement skipped: "
                     f"{trace.events_dropped} events dropped by the ring "
                     "buffer")
    for uid, n in exec_counts.items():
        if n > 1:
            flag("exec-unique", 1, f"task uid {uid} executed {n} times")
        if uid not in sub_counts:
            flag("exec-unsubmitted", 1,
                 f"executed uid {uid} was never submitted")

    # -- step monotonicity ---------------------------------------------------
    prev = 0
    prev_by_worker: dict[int, int] = {}
    for i, e in enumerate(trace.events, start=1):
        if e.step < prev:
            flag("step-monotone", i,
                 f"event {i} at step {e.step} follows step {prev} — the "
                 "step clock ran backwards")
        prev = max(prev, e.step)
        if e.worker >= 0:
            pw = prev_by_worker.get(e.worker, 0)
            if e.step < pw:
                flag("step-monotone", i,
                     f"worker {e.worker} regressed from step {pw} to "
                     f"{e.step} at event {i}")
            prev_by_worker[e.worker] = max(pw, e.step)

    # -- stream simulation: FIFO, local-first, nearest-first -----------------
    governor = str(trace.meta.get("governor", ""))
    if windowed:
        notes.append("fifo-order/local-first/nearest-first skipped: event "
                     "window is a suffix of the run")
    else:
        queues: dict[int, deque[tuple[int, int]]] = {
            d: deque() for d in range(max(nd, 1))}

        def pre_step_depth(domain: int, step: int) -> int:
            q = queues.get(domain)
            if q is None:
                return 0
            return sum(1 for (_uid, s) in q if s < step)

        for i, e in enumerate(trace.events, start=1):
            if e.kind == "submit":
                if e.domain in queues:
                    queues[e.domain].append((e.task_uid, e.step))
                continue
            if e.kind not in EXEC_KINDS or e.task_uid < 0:
                continue
            src = e.src_domain if e.src_domain >= 0 else e.domain
            q = queues.get(src)
            if q is None:
                continue                 # steal-level flags the bad domain
            if not q:
                flag("fifo-order", i,
                     f"event {i}: uid {e.task_uid} executed from domain "
                     f"{src} whose queue was empty")
                continue
            head_uid, _ = q[0]
            if head_uid != e.task_uid:
                flag("fifo-order", i,
                     f"event {i}: domain {src} served uid {e.task_uid} "
                     f"ahead of queued uid {head_uid}")
                # resync so one swap doesn't cascade down the stream
                try:
                    q.remove(next(p for p in q if p[0] == e.task_uid))
                except StopIteration:
                    q.popleft()
            else:
                q.popleft()
            if event_stolen(e):
                own_depth = pre_step_depth(e.domain, e.step)
                if own_depth > 0:
                    flag("local-first", i,
                         f"event {i}: worker {e.worker} stole uid "
                         f"{e.task_uid} from domain {src} while its own "
                         f"domain {e.domain} held {own_depth} older tasks")
                if (topo is not None and topo.hierarchical
                        and governor == "GreedySteal"
                        and 0 <= e.domain < nd and 0 <= src < nd):
                    lv = topo.level(e.domain, src)
                    for nearer in range(1, lv):
                        busy = [p for p in topo.peers(e.domain, nearer)
                                if pre_step_depth(p, e.step) > 0]
                        if busy:
                            flag("steal-level", i,
                                 f"event {i}: tier-{lv} steal from domain "
                                 f"{src} while tier-{nearer} peers {busy} "
                                 "held older work — nearest-first scan "
                                 "violated")
                            break

    # -- steal legality that needs no occupancy ------------------------------
    for i, e in enumerate(trace.events, start=1):
        if e.kind in EXEC_KINDS and e.task_uid >= 0:
            if not 0 <= e.domain < max(nd, 1):
                flag("steal-level", i,
                     f"event {i}: worker domain {e.domain} outside "
                     f"0..{nd - 1}")
            if e.src_domain >= 0 and not e.src_domain < max(nd, 1):
                flag("steal-level", i,
                     f"event {i}: source domain {e.src_domain} outside "
                     f"0..{nd - 1}")
            if event_stolen(e) and governor == "NoSteal":
                flag("steal-level", i,
                     f"event {i}: uid {e.task_uid} stolen from domain "
                     f"{e.src_domain} under the NoSteal governor")

    # -- span nesting --------------------------------------------------------
    try:
        from ..obs import assemble_spans
        forest = assemble_spans(trace)
        for uid in sorted(forest.spans):
            if not forest.spans[uid].well_nested():
                flag("span-nesting", 1,
                     f"task {uid}'s reconstructed span tree is not "
                     "well-nested")
    except Exception as exc:
        flag("span-nesting", 1, f"span reconstruction failed: {exc}")

    # -- stats consistency ---------------------------------------------------
    if windowed:
        notes.append("stats-consistency skipped: footer counts whole-run "
                     "totals, events are a window")
    elif trace.stats:
        homes = {s.uid: s.home for s in trace.submissions}
        execs = [e for e in trace.events
                 if e.kind in EXEC_KINDS and e.task_uid >= 0]
        stolen = [e for e in execs if event_stolen(e)]
        expect: dict[str, float] = {
            "submitted": len(trace.submissions),
            "executed": len(execs),
            "stolen": len(stolen),
            "inline_runs": sum(1 for e in execs if e.kind == "inline"),
            "idle_polls": kind_counts.get("idle", 0),
            "local": sum(1 for e in execs if not event_stolen(e)
                         and homes.get(e.task_uid) == e.domain),
            "steal_penalty": sum(e.penalty for e in stolen),
        }
        if topo is not None:
            expect["remote_steals"] = sum(
                1 for e in stolen
                if 0 <= e.domain < nd and 0 <= e.src_domain < nd
                and topo.level(e.domain, e.src_domain) >= 2)
        for key, want in expect.items():
            if key not in trace.stats:
                continue
            got = float(trace.stats[key])
            same = (math.isclose(got, want, rel_tol=1e-9, abs_tol=1e-9)
                    if key == "steal_penalty" else got == want)
            if not same:
                flag("stats-consistency", 1,
                     f"footer stats[{key!r}]={got} but the event stream "
                     f"says {want}")

    return ModelResult(path=path, violations=v, notes=notes)


def check_path(path: str) -> ModelResult:
    """Model-check a trace file or segment directory on disk."""
    from ..trace import TraceReader
    return check_trace(TraceReader(path).read(), path=path)
