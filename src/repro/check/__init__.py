"""repro.check — static guarantees for the record/replay stack.

Everything this reproduction claims — bit-identical replay (the paper's
A/B methodology for locality queues), obs passivity, controller purity —
rests on the *absence* of nondeterminism in ``src/repro/``.  Until now
that was enforced only dynamically, by golden tests that can miss a code
path; with ROADMAP item 2 about to rewrite the scheduler hot path (heap
victim selection, numpy ring buffers, columnar traces), this package adds
the static half of the gate:

  ``check.lint`` + ``check.purity``   the determinism linter: AST rules
      over the tree (wall-clock, unseeded RNG, unordered iteration,
      id()-ordering, environment reads, live state views) plus a
      cross-module call-graph walk proving every registered executor hook
      pure.  ``# repro: allow[rule] reason`` suppressions are the audited
      escape hatch.
  ``check.model``   the trace model checker: a happens-before verifier
      over any recorded v1–v4 trace (submit/exec uniqueness, per-domain
      FIFO legality, steal edges the header's DistanceMatrix permits,
      monotone step clocks, well-nested span trees, footer/stream
      agreement).

Usage::

    from repro import check

    bad = [v for v in check.lint_tree() if not v.suppressed]
    result = check.check_path("run.trace.jsonl")   # ModelResult
    assert result.ok, result.violations

    python -m repro.check all run.trace.jsonl      # the CI gate
"""
from .lint import lint_source, lint_tree, repro_root
from .model import ModelResult, check_path, check_trace
from .purity import check_hook_purity
from .report import (CheckReport, render_markdown, write_json,
                     write_markdown)
from .rules import (ALL_RULES, LINT_RULES, MODEL_RULES, Rule, Suppression,
                    Violation, apply_suppressions, parse_suppressions)

__all__ = [
    "lint_source", "lint_tree", "repro_root",
    "ModelResult", "check_path", "check_trace",
    "check_hook_purity",
    "CheckReport", "render_markdown", "write_json", "write_markdown",
    "ALL_RULES", "LINT_RULES", "MODEL_RULES", "Rule", "Suppression",
    "Violation", "apply_suppressions", "parse_suppressions",
]
