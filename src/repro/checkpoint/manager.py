"""Sharded, asynchronous checkpointing with retention and resume.

Design for the multi-pod deployment (DESIGN.md §6):
  * every host writes only the param/optimizer shards it owns (here, the
    single process writes per-shard files keyed by flattened leaf path —
    the addressable-shard walk is the same code that would run per-host);
  * writes happen on a background thread off the training loop ("async
    checkpointing": the step dump is staged to host memory synchronously,
    serialized asynchronously);
  * a manifest with step / config-hash / tree structure makes restores
    self-describing; retention keeps the newest K checkpoints;
  * restore-from-latest is the crash-recovery path exercised by
    tests/test_checkpoint.py (kill mid-run, resume, bit-identical state).
"""
from __future__ import annotations

import json
import pickle
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Stage `state` (device -> host) now; serialize in the background."""
        self.wait()                      # one in-flight checkpoint at a time
        staged = _flatten(jax.tree.map(np.asarray, state))

        def write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                tmp.mkdir(parents=True, exist_ok=True)
                manifest = {"step": step, "time": time.time(),
                            "arrays": {}}
                for key, arr in staged.items():
                    fn = key.replace("/", "__") + ".npy"
                    np.save(tmp / fn, arr)
                    manifest["arrays"][key] = {
                        "file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)}
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_write and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err!r}") from err

    def _retain(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and \
                    (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like` (device_put per shard)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        flat_sh = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat_like[0]))
        leaves = []
        for (path, leaf), sh in zip(flat_like[0], flat_sh):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            entry = manifest["arrays"][key]
            arr = np.load(d / entry["file"])
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(flat_like[1], leaves)

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, like
        return step, self.restore(step, like, shardings)
