"""Gemma3-1B [hf:google/gemma-3-1b-pt]: 5:1 local:global attention,
MQA (1 KV head), 262k vocab. Local layers use a 512-token sliding window,
which keeps decode sub-quadratic (ring-buffer KV) -> long_500k applies."""
from .base import ModelConfig, register


@register("gemma3-1b")
def gemma3_1b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        head_dim=256, d_ff=6912, vocab_size=262144,
        pattern=("local", "local", "local", "local", "local", "full"),
        attn_window=512, rope_theta=1e6, act="gelu",
        tie_embeddings=True, microbatches=2, subquadratic=True,
    )
