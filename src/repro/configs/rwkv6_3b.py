"""RWKV-6 "Finch" 3B [arXiv:2404.05892]: attention-free; data-dependent
per-channel decay (time-mix) + relu^2 channel-mix. O(1) decode state ->
long_500k applies. Attention-sharding aspects of the paper's technique are
inapplicable (DESIGN.md §5); the arch is implemented fully regardless."""
from .base import ModelConfig, register


@register("rwkv6-3b")
def rwkv6_3b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=8960, vocab_size=65536,
        pattern=("rwkv",), act="relu2", norm="layer",
        rope_theta=0.0, tie_embeddings=False,
        rwkv_head_dim=64, microbatches=8, subquadratic=True,
    )
