"""Config registry: one module per assigned architecture (+ the paper's own
Jacobi config in repro.stencil)."""
from .base import (
    SHAPES,
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    VisionConfig,
    cell_is_applicable,
    get_config,
    list_archs,
    register,
)

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        gemma3_1b,
        llama32_vision_90b,
        minicpm3_4b,
        phi35_moe_42b,
        qwen2_0_5b,
        qwen2_1_5b,
        qwen3_moe_30b_a3b,
        recurrentgemma_9b,
        rwkv6_3b,
        whisper_base,
    )
    _LOADED = True


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: tiny widths, few
    layers (enough to cover the pattern + a remainder), small vocab."""
    import dataclasses
    nl = max(len(cfg.pattern) + 1, 2)
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=nl,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        attn_window=min(cfg.attn_window, 16) if cfg.attn_window else 0,
        fsdp=False,
        microbatches=1,
        dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor high enough that nothing is dropped: exact
        # prefill/decode equivalence is testable (capacity-drop behaviour
        # itself is covered by the MoE unit tests)
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                        d_ff_expert=32, capacity_factor=8.0)
        kw["d_ff"] = 32
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(num_layers=2, num_frames=32, d_model=64,
                                      num_heads=4, d_ff=96)
    if cfg.vision is not None:
        kw["vision"] = VisionConfig(num_image_tokens=16,
                                    cross_every=cfg.vision.cross_every)
    return dataclasses.replace(cfg, **kw)
