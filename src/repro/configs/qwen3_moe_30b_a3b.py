"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: MoE, 128 experts top-8,
expert FFN width 768, no shared expert. The locality-biased router is the
paper's locality-queue technique applied to expert dispatch."""
from .base import ModelConfig, MoEConfig, register


@register("qwen3-moe-30b-a3b")
def qwen3_moe() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151936,
        rope_theta=1e6, tie_embeddings=False, fsdp=True, microbatches=4,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    )
