"""Whisper-base [arXiv:2212.04356]: encoder-decoder; conv frontend is a STUB
(input_specs provide precomputed frame embeddings, padded 1500->1536 frames
for lane-friendly sharding). Decoder layers = self+cross attention.

train_4k/decode_32k decoder lengths exceed Whisper's trained 448 positions;
kept as lowering/scale exercises per the assignment (see DESIGN.md §5)."""
from .base import EncoderConfig, ModelConfig, register


@register("whisper-base")
def whisper_base() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec",
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865,
        pattern=("cross",), act="gelu", norm="layer",
        rope_theta=0.0,  # whisper uses absolute positions, not rope
        tie_embeddings=True,
        encoder=EncoderConfig(num_layers=6, num_frames=1536, d_model=512,
                              num_heads=8, d_ff=2048),
    )
