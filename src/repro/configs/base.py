"""Model/config registry for the assigned architectures.

Every architecture is a ``ModelConfig``; reduced smoke variants share the
same code paths with tiny dimensions.  Input-shape sets (train_4k /
prefill_32k / decode_32k / long_500k) are defined here too, so dryrun,
benchmarks and tests agree on every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    locality_bias: float = 0.0      # paper-technique: bias router toward
                                    # experts resident on the token's devices
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper). Frontend is a stub: input_specs
    provide precomputed frame embeddings (post-conv)."""
    num_layers: int
    num_frames: int                 # padded to a lane-friendly multiple
    d_model: int
    num_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Vision frontend stub for VLMs: precomputed patch embeddings,
    already projected to the decoder width."""
    num_image_tokens: int
    cross_every: int                # one cross-attn layer per this many


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern: repeating kinds; remainder unrolled.
    #   kinds: "full" global attn, "local" sliding-window attn,
    #          "rglru" recurrent block, "cross" self+cross-attn, "rwkv"
    pattern: tuple[str, ...] = ("full",)
    attn_window: int = 0            # sliding window for "local" layers
    qkv_bias: bool = False
    rope_theta: float = 1e4
    act: str = "silu"
    norm: str = "rms"               # rms | layer
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    # rwkv
    rwkv_head_dim: int = 64
    # distribution hints
    fsdp: bool = False              # shard weights over the data axis too
    remat: bool = True
    microbatches: int = 1           # grad-accumulation steps per train step
    dtype: str = "bfloat16"
    # long_500k applicability (sub-quadratic attention path exists)
    subquadratic: bool = False

    # -- derived -----------------------------------------------------------
    def vocab_padded(self, multiple: int = 128) -> int:
        return math.ceil(self.vocab_size / multiple) * multiple

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> list[str]:
        """Expanded per-layer kind list of length num_layers."""
        reps = self.num_layers // len(self.pattern)
        rem = self.num_layers - reps * len(self.pattern)
        return list(self.pattern) * reps + list(self.pattern[:rem])

    def num_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded()
        n_attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        n_mlp = 3 * d * f if self.act in ("silu",) else 2 * d * f
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind in ("full", "local", "cross"):
                total += n_attn + n_mlp + 2 * d
                if kind == "cross":
                    total += n_attn + d
            elif kind == "rglru":
                total += 2 * d * d + d * d + n_mlp + 2 * d   # branches+proj
            elif kind == "rwkv":
                total += 5 * d * d + 2 * d * f + 4 * d
            if self.moe is not None and kind in ("full", "local"):
                total += -n_mlp + self.moe.num_experts * 3 * d * self.moe.d_ff_expert \
                    + d * self.moe.num_experts
        if self.mla is not None:
            m = self.mla
            per = (d * m.q_lora_rank
                   + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                   + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                   + self.num_heads * m.v_head_dim * d)
            total += self.num_layers * (per - n_attn)
        return total

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.num_params()
        d = self.d_model
        full = self.num_params()
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * self.moe.d_ff_expert
        return full - len([k for k in self.layer_kinds() if k in ("full", "local")]) * inactive


# ---------------------------------------------------------------------------
# input shapes (assigned): every LM arch carries these four cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import the arch modules lazily on first miss
        from . import _load_all  # noqa: F401  (populates the registry)
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    from . import _load_all
    _load_all()
    return sorted(_REGISTRY)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason if skipped (DESIGN §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""
