"""Phi-3.5-MoE (42B total, 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]:
16 experts top-2, expert FFN 6400. At EP=16 exactly one expert lives on each
model-axis device, which makes the locality-vs-balance trade maximally
visible."""
from .base import ModelConfig, MoEConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=6400, vocab_size=32064,
        rope_theta=1e4, tie_embeddings=False, fsdp=True, microbatches=4,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
    )
