"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427]: RG-LRU recurrent blocks
and local-attention blocks at 2:1, MQA, window 2048. O(1) decode state ->
long_500k applies."""
from .base import ModelConfig, register


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        pattern=("rglru", "rglru", "local"),
        attn_window=2048, rope_theta=1e4, act="gelu",
        tie_embeddings=True, fsdp=True, microbatches=4, subquadratic=True,
    )
