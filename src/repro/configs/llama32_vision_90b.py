"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled per the
assignment]: 100-layer decoder with gated cross-attention to vision tokens
every 5th layer. The vision tower is a STUB: input_specs provide precomputed
patch embeddings already projected to d_model."""
from .base import ModelConfig, VisionConfig, register


@register("llama-3.2-vision-90b")
def llama32_vision_90b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=128256,
        pattern=("full", "full", "full", "full", "cross"),
        rope_theta=5e5, tie_embeddings=False,
        fsdp=True, microbatches=16,
        vision=VisionConfig(num_image_tokens=1600, cross_every=5),
    )
