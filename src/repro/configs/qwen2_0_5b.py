"""Qwen2-0.5B [arXiv:2407.10671]: dense GQA with QKV bias."""
from .base import ModelConfig, register


@register("qwen2-0.5b")
def qwen2_0_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151936,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    )
