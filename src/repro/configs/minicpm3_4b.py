"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense with Multi-head Latent
Attention (MLA). The KV cache stores only the compressed latent
(kv_lora_rank) plus the shared rope key."""
from .base import MLAConfig, ModelConfig, register


@register("minicpm3-4b")
def minicpm3_4b() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=64, d_ff=6400, vocab_size=73448,
        rope_theta=1e4, tie_embeddings=True, microbatches=8,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
    )
