"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU.

RG-LRU (Real-Gated Linear Recurrent Unit, arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)         (data-dependent decay, c=8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is linear in h, so training/prefill uses an associative scan
(log-depth on TPU); decode is an O(1) state update.  The surrounding block
is Griffin's: two input branches (GeLU gate × conv1d→RG-LRU), merged by an
output projection.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import Params, dense_init, matmul_lowp, split_keys

_C = 8.0
_CONV_W = 4


def _gate_blocks(w: int) -> int:
    """Griffin's RG-LRU gates use BLOCK-DIAGONAL weights (one block per
    head in the reference implementation).  Block-diagonality is also the
    locality win on the mesh: each lru-shard's gates depend only on its own
    channels, so the gate matmuls contract shard-locally — no all-reduce
    (EXPERIMENTS.md §Perf-2)."""
    for nb in (16, 8, 4, 2):
        if w % nb == 0 and (w // nb) >= 8:
            return nb
    return 1


def rglru_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    w = d  # lru width = d_model
    nb = _gate_blocks(w)
    bw = w // nb
    ks = split_keys(key, 6)
    scale = 1.0 / jnp.sqrt(bw)
    return {
        "w_gate_branch": dense_init(ks[0], d, w, dtype),
        "w_x_branch": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.truncated_normal(ks[2], -3, 3, (_CONV_W, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": (jax.random.truncated_normal(ks[3], -3, 3, (nb, bw, bw)) * scale).astype(dtype),
        "b_a": jnp.zeros((w,), dtype),
        "w_i": (jax.random.truncated_normal(ks[4], -3, 3, (nb, bw, bw)) * scale).astype(dtype),
        "b_i": jnp.zeros((w,), dtype),
        # Λ init so that a = exp(-c*softplus(Λ)) spans ~(0.9, 0.999)
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)),
            dtype=jnp.float32),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _block_diag_matmul(u: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """u (B,T,W) x block-diagonal w (nb, W/nb, W/nb) -> (B,T,W)."""
    b, t, width = u.shape
    nb, bw, _ = w.shape
    ub = u.reshape(b, t, nb, bw)
    out = jnp.einsum("btnw,nwv->btnv", ub, w)
    return out.reshape(b, t, width)


def _rglru_scan(a: jnp.ndarray, bx: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None,
                chunk: int = 256) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + bx_t over axis 1 (time).

    Chunked scan with rematerialization: the backward pass keeps only the
    chunk-boundary states (T/chunk x (B, W)) and recomputes inside each
    chunk — the same blocking the Pallas kernel uses in VMEM.  Short or
    non-divisible sequences fall back to an associative scan.
    """
    if h0 is not None:
        # fold the carried state into the first step
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    b, t, w = a.shape
    if t % chunk or t <= chunk:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        return h

    nc = t // chunk
    a_c = a.reshape(b, nc, chunk, w).swapaxes(0, 1)
    b_c = bx.reshape(b, nc, chunk, w).swapaxes(0, 1)

    def chunk_fn(h, inp):
        ac, bc = inp                       # (b, chunk, w)
        def step(hh, xs):
            ai, bi = xs
            hh = ai * hh + bi
            return hh, hh
        h, hs = jax.lax.scan(step, h, (ac.swapaxes(0, 1), bc.swapaxes(0, 1)))
        return h, hs.swapaxes(0, 1)

    # default checkpoint: saves only chunk inputs; the backward pass
    # recomputes the chunk forward once with transient residuals (NOT
    # nothing_saveable, which would force O(chunk^2) re-recomputation
    # inside the inner scan's backward)
    chunk_fn = jax.checkpoint(chunk_fn)
    _, outs = jax.lax.scan(chunk_fn, jnp.zeros((b, w), a.dtype), (a_c, b_c))
    return outs.swapaxes(0, 1).reshape(b, t, w)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d, width 4. x (B,T,W); state (B,3,W) history.

    Returns (y, new_state)."""
    hist = state if state is not None else jnp.zeros(
        (x.shape[0], _CONV_W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(_CONV_W)) + b
    return y, xp[:, -(_CONV_W - 1):]


def rglru_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                cache: Optional[Params] = None):
    """Griffin recurrent block. cache = {"h": (B,W), "conv": (B,3,W)}."""
    gate = jax.nn.gelu(x @ p["w_gate_branch"], approximate=True)
    u = x @ p["w_x_branch"]
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"],
                                 cache["conv"] if cache is not None else None)

    r = jax.nn.sigmoid((_block_diag_matmul(u, p["w_a"]) + p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((_block_diag_matmul(u, p["w_i"]) + p["b_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    # keep u's cotangent path in bf16: (beta*i) folds to the input dtype
    # before touching u, so the backward row-parallel psums toward x stay
    # bf16 instead of f32 (§Perf-2); the recurrence itself stays f32.
    bx = ((beta * i).astype(u.dtype) * u).astype(jnp.float32)

    if cache is not None and x.shape[1] == 1:
        h = a[:, 0] * cache["h"].astype(jnp.float32) + bx[:, 0]
        out = h[:, None]
        new_cache = {"h": h.astype(cache["h"].dtype), "conv": conv_state}
    else:
        h0 = cache["h"].astype(jnp.float32) if cache is not None else None
        out = _rglru_scan(a, bx, h0)
        new_cache = None
        if cache is not None:
            new_cache = {"h": out[:, -1].astype(cache["h"].dtype),
                         "conv": conv_state}

    y = matmul_lowp(out.astype(x.dtype) * gate, p["w_out"])
    return y, new_cache
