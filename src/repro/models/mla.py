"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries come from a low-rank down/up projection; keys/values from a shared
compressed latent ``c_kv`` (kv_lora_rank) plus a single shared rotary key.
The decode cache stores only (c_kv, k_rope) — (kv_lora + rope_dim) floats
per token instead of 2 * H * hd: for MiniCPM3-4B that is 288 vs 5120 per
token, an ~18x KV-cache reduction, which is the arch's whole point.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from ..distributed.sharding import shard
from .common import Params, apply_rope, dense_init, rms_norm, rms_norm_init, split_keys
from .attention import NEG_INF, _causal_mask


def mla_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = split_keys(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": rms_norm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, h * qk, dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": rms_norm_init(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "w_kr": dense_init(ks[5], d, m.qk_rope_head_dim, dtype),
        "wo": dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def _absorbed_chunked_local(q_lat, q_rope, ckv, kr, q_offset, scale,
                            q_chunk: int = 512, k_chunk: int = 1024):
    """Online-softmax attention in latent space: scores against (B,S,r)
    ckv/kr, output accumulated as (B,T,H,r).  q_offset may be traced."""
    b, t, h, r = q_lat.shape
    s = ckv.shape[1]
    dr = q_rope.shape[-1]
    nq = -(-t // q_chunk)
    nk = -(-s // k_chunk)
    qp = jnp.pad(q_lat, ((0, 0), (0, nq * q_chunk - t), (0, 0), (0, 0)))
    qr = jnp.pad(q_rope, ((0, 0), (0, nq * q_chunk - t), (0, 0), (0, 0)))
    cp = jnp.pad(ckv, ((0, 0), (0, nk * k_chunk - s), (0, 0)))
    kp = jnp.pad(kr, ((0, 0), (0, nk * k_chunk - s), (0, 0)))
    qs = qp.reshape(b, nq, q_chunk, h, r).transpose(1, 0, 2, 3, 4)
    qrs = qr.reshape(b, nq, q_chunk, h, dr).transpose(1, 0, 2, 3, 4)
    cs = cp.reshape(b, nk, k_chunk, r).transpose(1, 0, 2, 3)
    krs = kp.reshape(b, nk, k_chunk, dr).transpose(1, 0, 2, 3)

    def outer(_, xs):
        ql, qrl, iq = xs
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def inner(carry, ys):
            mm, ll, acc = carry
            cc, kk, ik = ys
            k_pos = ik * k_chunk + jnp.arange(k_chunk)
            sc = (jnp.einsum("bqhr,bsr->bhqs", ql, cc)
                  + jnp.einsum("bqhd,bsd->bhqs", qrl, kk)
                  ).astype(jnp.float32) * scale
            ok = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < s)
            sc = jnp.where(ok[None, None], sc, NEG_INF)
            m_new = jnp.maximum(mm, sc.max(-1))
            pw = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(mm - m_new)
            ll = ll * alpha + pw.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bsr->bhqr", pw.astype(ql.dtype), cc).astype(jnp.float32)
            return (m_new, ll, acc), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, r), jnp.float32)
        (mm, ll, acc), _ = jax.lax.scan(
            jax.checkpoint(inner,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), (cs, krs, jnp.arange(nk)))
        o = acc / jnp.maximum(ll[..., None], 1e-37)
        return None, o.transpose(0, 2, 1, 3).astype(ql.dtype)  # (b,qc,h,r)

    _, outs = jax.lax.scan(outer, None, (qs, qrs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, r)
    return out[:, :t]


def _absorbed_chunked(q_lat, q_rope, ckv, kr, q_offset, scale):
    """Sequence-parallel wrapper: shard q's sequence over the model axis
    (shard_map — a scan cannot iterate a sharded axis, see attention.py)."""
    from ..distributed.sharding import current_rules
    rules = current_rules()
    axis = rules.rules.get("seq_q") if rules is not None else None
    b, t, h, r = q_lat.shape
    if isinstance(axis, str):
        n = rules.mesh.shape[axis]
        if n > 1 and t % n == 0 and (t // n) % 512 == 0:
            def local(ql, qr, c, k):
                idx = jax.lax.axis_index(axis)
                off = q_offset + idx * ql.shape[1]
                return _absorbed_chunked_local(ql, qr, c, k, off, scale)
            return jax.shard_map(
                local, mesh=rules.mesh,
                in_specs=(rules.spec("batch", "seq_q", None, None),
                          rules.spec("batch", "seq_q", None, None),
                          rules.spec("batch", None, None),
                          rules.spec("batch", None, None)),
                out_specs=rules.spec("batch", "seq_q", None, None),
                check_vma=False)(q_lat, q_rope, ckv, kr)
    return _absorbed_chunked_local(q_lat, q_rope, ckv, kr, q_offset, scale)


def mla_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              pos_offset=0, cache: Optional[Params] = None):
    """Returns (out, new_cache). Cache = {"ckv": (B,S,r), "kr": (B,S,dr)}."""
    m = cfg.mla
    h = cfg.num_heads
    b, t, _ = x.shape

    q = rms_norm(p["q_norm"], x @ p["w_dq"]) @ p["w_uq"]
    q = q.reshape(b, t, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)

    ckv_new = rms_norm(p["kv_norm"], x @ p["w_dkv"])          # (B,T,r)
    kr_new = x @ p["w_kr"]                                     # (B,T,dr)

    positions = pos_offset + jnp.arange(t)
    q_rope = apply_rope(q_rope, jnp.broadcast_to(positions, (b, t)),
                        cfg.rope_theta)
    kr_new = apply_rope(kr_new[:, :, None, :],
                        jnp.broadcast_to(positions, (b, t)),
                        cfg.rope_theta)[:, :, 0]

    new_cache = None
    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos_offset, 0))
        kr = jax.lax.dynamic_update_slice(
            cache["kr"], kr_new.astype(cache["kr"].dtype), (0, pos_offset, 0))
        ckv = shard(ckv, "batch", "kv_seq", None)
        kr = shard(kr, "batch", "kv_seq", None)
        new_cache = {"ckv": ckv, "kr": kr}
        s = ckv.shape[1]
    else:
        ckv, kr = ckv_new, kr_new
        s = t

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if t > 2048 and cache is None:
        # TRAINING at long seq: the absorbed form's r=288 contraction costs
        # ~3x the score flops and its backward re-pays it twice more —
        # expansion + sequence-parallel attention wins (§Perf-1, iter 1c).
        from .attention import seq_parallel_attention, chunked_attention
        from ..distributed.sharding import current_rules
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [(ckv @ p["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim),
             jnp.broadcast_to(kr[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
            axis=-1)
        v_full = (ckv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
        o = seq_parallel_attention(q_full, k_full, v_full,
                                   pos_offset=pos_offset, window=0,
                                   rules=current_rules())
        if o is None:
            q_full = shard(q_full, "batch", "seq_q", None, None)
            o = chunked_attention(q_full, k_full, v_full, pos_offset)
        return o @ p["wo"], new_cache

    if (cache is not None) and (t > 2048 or t == 1):
        # ABSORBED attention (§Perf-1): fold W_uk into the query and W_uv
        # out of the value sum, so scores and the output accumulate against
        # the (B,S,r) latent directly — the per-head (B,S,H,*) K/V are never
        # materialized.  This is the arch's whole point at inference (the
        # cache *is* the latent) and the decisive memory win at 32k prefill.
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk)
        if cache is not None and t == 1:
            logits = (jnp.einsum("bthr,bsr->bhts", q_lat, ckv)
                      + jnp.einsum("bthd,bsd->bhts", q_rope, kr)
                      ).astype(jnp.float32) * scale
            valid = jnp.arange(s)[None, None, None, :] < (pos_offset + 1)
            logits = jnp.where(valid, logits, NEG_INF)
            w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
            o_lat = jnp.einsum("bhts,bsr->bthr", w, ckv)
        else:
            q_lat = shard(q_lat, "batch", "seq_q", None, None)
            q_rope_s = shard(q_rope, "batch", "seq_q", None, None)
            o_lat = _absorbed_chunked(q_lat, q_rope_s, ckv, kr, pos_offset,
                                      scale)
        o = jnp.einsum("bthr,rhv->bthv", o_lat, w_uv)
        return o.reshape(b, t, h * m.v_head_dim) @ p["wo"], new_cache

    # reference (expansion) form for short sequences — the oracle the
    # absorbed form is tested against (decode-consistency tests)
    k_nope = (ckv @ p["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = (ckv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
    logits = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope)
              + jnp.einsum("bthd,bsd->bhts", q_rope, kr)).astype(jnp.float32)
    logits = logits * scale

    if cache is not None and t == 1:
        valid = jnp.arange(s)[None, None, None, :] < (pos_offset + 1)
        logits = jnp.where(valid, logits, NEG_INF)
    else:
        logits = logits + _causal_mask(t, s, pos_offset)[None, None]

    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhts,bshd->bthd", w, v).reshape(b, t, h * m.v_head_dim)
    return o @ p["wo"], new_cache
