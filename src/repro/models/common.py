"""Shared building blocks for the model zoo (pure-pytree, functional).

No flax/haiku — parameters are nested dicts of jnp arrays, layers are pure
functions.  Everything takes an explicit PRNG key at init and is
shape-polymorphic so the same code serves reduced smoke configs and the
full assigned architectures (which are only ever lowered abstractly).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out)) * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -3, 3, (vocab, d)) * 0.02).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with (1 + scale) parameterization (gemma/llama style).

    Statistics in f32, application in the input dtype: keeping the (B,T,D)
    tensor (and hence its cotangent, and hence every cross-shard psum of
    the residual stream) in bf16 halves TP wire traffic vs upcasting x
    wholesale (EXPERIMENTS.md §Perf-2)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    mult = (jax.lax.rsqrt(var + eps)
            * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)
    return x * mult


def layer_norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    mult = (jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32))
    return (x - mu.astype(x.dtype)) * mult.astype(x.dtype) + \
        p["bias"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary / positional embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (n, d)."""
    log_timescale = math.log(10000) / (d // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(d // 2, dtype=jnp.float32))
    t = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


# ---------------------------------------------------------------------------
# activations / losses
# ---------------------------------------------------------------------------

def matmul_lowp(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row-parallel projection matmul with low-precision partials.

    When the contraction dim is sharded, XLA keeps each shard's partial dot
    in f32 and all-reduces f32 — doubling the wire bytes of every TP
    projection.  Requesting a bf16 result dtype makes the partials (and the
    all-reduce) bf16; the MXU still accumulates each local dot in f32
    internally, so only the ≤16-way cross-shard addition runs in bf16.
    (EXPERIMENTS.md §Perf-2.)
    """
    if a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16:
        return jnp.matmul(a, b, preferred_element_type=jnp.bfloat16)
    return a @ b


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits (..., V) in any float dtype.

    Written so XLA's SPMD partitioner never gathers the (possibly
    vocab-sharded) logits: the max / sum-exp / gold-pick are all plain
    reductions over the vocab axis, which lower to local partials plus a
    tiny (B, S)-sized all-reduce — the vocab-parallel cross-entropy of
    Megatron, in SPMD-native form.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    z = jnp.sum(jnp.exp(shifted), axis=-1)                      # psum
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold_shifted = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], shifted, 0.0), axis=-1)
    nll = jnp.log(z) - gold_shifted
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
