"""Mixture-of-Experts with expert parallelism and locality-biased routing.

The dispatch/combine are Switch-Transformer-style one-hot einsums over
per-device token groups; experts are sharded over the ``model`` axis (EP),
so the dispatched activations move through an all-to-all that XLA's SPMD
partitioner inserts between the group-sharded and expert-sharded einsums.

**Locality-biased routing — the paper's technique as a first-class
feature**: each token group (= device) has a set of *local* experts (those
resident on the same model-axis coordinate when dispatch is EP-local, or
the same pod in multi-pod meshes).  A bias is added to the router logits of
local experts, exactly like the paper's locality queues prefer the home
domain's tasks; the capacity limit plays the role of bounded work stealing
(overflow tokens spill to remote experts), and the auxiliary load-balance
loss enforces the paper's balance-over-locality priority.  The measurable
effect is a smaller all-to-all (collective roofline term) at equal step
semantics — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import current_rules, shard
from .common import Params, dense_init, split_keys


def moe_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = split_keys(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -3, 3, (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.truncated_normal(ks[2], -3, 3, (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.truncated_normal(ks[3], -3, 3, (e, f, d)) * (1.0 / jnp.sqrt(f))).astype(dtype),
    }


def _local_expert_bias(num_groups: int, num_experts: int,
                       bias: float) -> jnp.ndarray:
    """(G, E) bias favoring experts co-resident with each token group.

    Group g's tokens live on model-axis coordinate (g % A) when groups are
    laid out batch-major over a (data, model)-flattened device order; expert
    e lives on coordinate (e // (E/A)).  The bias is the paper's "local
    queue first" preference in logit space.
    """
    rules = current_rules()
    a = 1
    if rules is not None:
        model_axis = rules.rules.get("experts")
        if model_axis is not None:
            a = rules.mesh.shape[model_axis]
    if a <= 1 or num_experts % a:
        return jnp.zeros((num_groups, num_experts), jnp.float32)
    per = num_experts // a
    g_coord = jnp.arange(num_groups) % a
    e_coord = jnp.arange(num_experts) // per
    return jnp.where(g_coord[:, None] == e_coord[None, :], bias, 0.0)


GROUP_TOKENS = 512   # dispatch/combine one-hots are O(T_g^2): keep T_g small


def moe_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              num_groups: Optional[int] = None):
    """x: (B, T, D) -> (out, aux_loss).

    Tokens are reshaped to (G, T', D) groups riding the data-parallel batch
    sharding.  The per-group (T', E, C) dispatch tensor scales as T'^2·k/E,
    so groups are capped at GROUP_TOKENS tokens (the sort-based dispatch
    that avoids the one-hot entirely is the §Perf follow-up).
    """
    m = cfg.moe
    b, t, d = x.shape
    e, k = m.num_experts, m.top_k
    if num_groups is None:
        per_seq = max(t // GROUP_TOKENS, 1)
        num_groups = b * per_seq
    g = num_groups
    xg = x.reshape(g, (b * t) // g, d)
    tokens = xg.shape[1]

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)  # (G,T,E)
    if m.locality_bias:
        logits = logits + _local_expert_bias(g, e, m.locality_bias)[:, None, :]

    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                     # (G,T,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # capacity per expert per group (bounded stealing: overflow is dropped
    # to the residual path, the SPMD analogue of re-queueing)
    cap = max(int(tokens * k / e * m.capacity_factor), 1)

    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)        # (G,T,k,E)
    flat = onehot.reshape(g, tokens * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                       # arrival order
    pos = pos.reshape(g, tokens, k, e)
    in_cap = (pos < cap) & (onehot > 0)

    # combine weights (G,T,E,cap); dispatch = nonzero mask
    pos_oh = jax.nn.one_hot(jnp.where(in_cap, pos, -1), cap, dtype=xg.dtype)
    combine = jnp.einsum("gtke,gtkec,gtk->gtec", onehot.astype(xg.dtype),
                         pos_oh, topv.astype(xg.dtype))
    dispatch = combine > 0

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(xg.dtype), xg)
    expert_in = shard(expert_in, "batch", "experts", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    expert_out = shard(expert_out, "batch", "experts", None, None)

    out = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    density = dispatch.any(-1).astype(jnp.float32).mean(axis=1)   # (G,E) frac tokens
    router_prob = gates.mean(axis=1)                              # (G,E)
    aux = (density * router_prob).sum(-1).mean() * e * m.router_aux_weight

    return out.reshape(b, t, d), aux
