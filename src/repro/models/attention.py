"""Attention: GQA (optional bias / sliding window / cross), train + decode.

Three execution paths, one semantics:
  * direct    — materialized scores; smoke tests, short seqs, decode.
  * chunked   — lax.scan over q- and k-chunks with an online softmax
                (flash-attention at the XLA level); bounds activation
                memory for 32k prefill.  The Pallas flash kernel
                (repro.kernels.flash_attention) is the TPU-optimized
                drop-in with identical semantics.
  * decode    — one query token against a (possibly ring-buffered,
                possibly sequence-sharded) KV cache.

GQA is expressed by reshaping q to (B, T, KV, G, hd) and broadcasting k/v;
KV heads stay replicated across the model axis (they are almost always
fewer than the axis size), q heads or q sequence shard instead — see
distributed/sharding.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .common import Params, apply_rope, dense_init, matmul_lowp, split_keys

NEG_INF = -2.0e38


def attn_init(key: jax.Array, cfg: ModelConfig, d_model: Optional[int] = None,
              num_heads: Optional[int] = None, num_kv: Optional[int] = None,
              dtype=jnp.float32) -> Params:
    d = d_model or cfg.d_model
    h = num_heads or cfg.num_heads
    kv = num_kv or cfg.num_kv_heads
    hd = cfg.head_dim
    ks = split_keys(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig,
                 num_heads: int, num_kv: int):
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, t = x.shape[:2]
    tk = xkv.shape[1]
    q = q.reshape(b, t, num_heads, hd)
    k = k.reshape(b, tk, num_kv, hd)
    v = v.reshape(b, tk, num_kv, hd)
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Tq,KV,G,hd) x k (B,Tk,KV,hd) -> (B,KV,G,Tq,Tk)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k)


def _gqa_out(w: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """w (B,KV,G,Tq,Tk) x v (B,Tk,KV,hd) -> (B,Tq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v)


def _causal_mask(tq: int, tk: int, q_offset, window: int = 0) -> jnp.ndarray:
    """(tq, tk) additive mask. q position = q_offset + row index."""
    qi = q_offset + jnp.arange(tq)[:, None]
    ki = jnp.arange(tk)[None, :]
    ok = ki <= qi
    if window > 0:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def direct_attention(q, k, v, mask) -> jnp.ndarray:
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd), mask (Tq,Tk) or (B,1,1,Tq,Tk)."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, hd) * (hd ** -0.5)
    s = _gqa_scores(qg, k).astype(jnp.float32)
    s = s + (mask if mask.ndim > 2 else mask[None, None, None])
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = _gqa_out(w, v)
    return o.reshape(b, tq, h * hd)


def chunked_attention(q, k, v, q_offset: int, window: int = 0,
                      q_chunk: int = 512, k_chunk: int = 1024) -> jnp.ndarray:
    """Flash-style online-softmax attention via nested lax.scan.

    Memory is O(q_chunk * k_chunk) per head instead of O(Tq * Tk); this is
    the XLA-level equivalent of the Pallas flash kernel and its oracle.
    v may have a different head dim than q/k (MLA).
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    kvh = k.shape[2]
    hv = v.shape[3]
    g = h // kvh
    nq = -(-tq // q_chunk)
    nk = -(-tk // k_chunk)
    pq = nq * q_chunk - tq
    pk = nk * k_chunk - tk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qs = qp.reshape(b, nq, q_chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(b, nk, k_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, k_chunk, kvh, hv).transpose(1, 0, 2, 3, 4)
    scale = hd ** -0.5

    def outer(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def inner(carry, ki_vi_idx):
            m, l, acc = carry
            ki, vi, ik = ki_vi_idx
            k_pos = ik * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi * scale, ki).astype(jnp.float32)
            ok = k_pos[None, :] <= q_pos[:, None]
            ok &= k_pos[None, :] < tk                     # k padding
            if window > 0:
                ok &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(qi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, hv), jnp.float32)
        # checkpoint the k-chunk body: backward recomputes the (bq x bk)
        # score tile instead of saving one per chunk pair — this is what
        # makes the scan-based formulation actually flash-like in memory.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(inner,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), (ks, vs, jnp.arange(nk)))
        o = acc / jnp.maximum(l[..., None], 1e-37)
        # (b,kv,g,qc,hd) -> (b,qc,h*hd)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h * hv)
        return None, o.astype(qi.dtype)

    _, outs = jax.lax.scan(outer, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3).reshape(b, nq * q_chunk, h * hv)
    return out[:, :tq]


def banded_attention(q, k, v, q_offset: int, window: int) -> jnp.ndarray:
    """Sliding-window attention computed as a band: each q chunk attends to
    its own and the previous k chunk only (chunk >= window), so compute is
    O(T * window) instead of the O(T^2) full scan — the reason local
    attention layers are sub-quadratic at 32k+ prefill."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    chunk = max(512, window)
    n = -(-tq // chunk)
    pq = n * chunk - tq
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pq), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pq), (0, 0), (0, 0)))
    qs = qp.reshape(b, n, chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(b, n, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, n, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    k_prev = jnp.concatenate([jnp.zeros_like(ks[:1]), ks[:-1]], axis=0)
    v_prev = jnp.concatenate([jnp.zeros_like(vs[:1]), vs[:-1]], axis=0)
    scale = hd ** -0.5

    def one(carry, xs):
        qi, kb, kpv, vb, vpv, i = xs
        kk = jnp.concatenate([kpv, kb], axis=1)       # (b, 2*chunk, kvh, hd)
        vv = jnp.concatenate([vpv, vb], axis=1)
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        k_pos = q_offset + (i - 1) * chunk + jnp.arange(2 * chunk)
        s = jnp.einsum("bqkgh,bskh->bkgqs",
                       qi.reshape(b, chunk, kvh, g, hd) * scale,
                       kk).astype(jnp.float32)
        ok = (k_pos[None, :] <= q_pos[:, None]) & \
             (k_pos[None, :] > q_pos[:, None] - window) & \
             (k_pos[None, :] >= q_offset)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(qi.dtype)
        o = jnp.einsum("bkgqs,bskh->bqkgh", w, vv)
        return carry, o.reshape(b, chunk, h * hd)

    one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(
        one, None,
        (qs.reshape(n, b, chunk, h, hd), ks, k_prev, vs, v_prev,
         jnp.arange(n)))
    out = outs.transpose(1, 0, 2, 3).reshape(b, n * chunk, h * hd)
    return out[:, :tq]


def _windowed_seq_local(q_local, k, v, offset, window: int) -> jnp.ndarray:
    """Local-window attention for one sequence shard: q_local (B,Tl,H,hd)
    holds global positions [offset, offset+Tl); k/v are the full (replicated)
    sequence.  Only rows [offset-window, offset+Tl) of k/v can contribute,
    so slice exactly those (front-padded by `window` to keep the slice
    in-bounds) — compute is O(Tl * (Tl + window)), not O(Tl * S)."""
    b, tl, h, hd = q_local.shape
    kvh = k.shape[2]
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    ks = jax.lax.dynamic_slice_in_dim(kp, offset, tl + window, axis=1)
    vs = jax.lax.dynamic_slice_in_dim(vp, offset, tl + window, axis=1)
    q_pos = offset + jnp.arange(tl)[:, None]
    k_pos = offset - window + jnp.arange(tl + window)[None, :]
    ok = (k_pos <= q_pos) & (k_pos > q_pos - window) & (k_pos >= 0)
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    return direct_attention(q_local, ks, vs, mask)


def seq_parallel_attention(q, k, v, *, pos_offset, window: int,
                           rules) -> Optional[jnp.ndarray]:
    """Sequence-parallel (context-parallel) attention over the model axis.

    Used when q heads cannot shard across the model axis: q's sequence is
    sharded instead, k/v stay replicated (they are small — kv_heads ≤ 2 for
    these archs), and each device computes attention only for its own
    sequence shard inside a shard_map.  This is the piece plain
    jit+constraints cannot express: ``lax.scan`` cannot iterate a sharded
    axis, so without shard_map XLA gathers q and every model-rank computes
    every chunk (16x redundant flops + full-size score traffic — see
    EXPERIMENTS.md §Perf-1/3).
    Returns None when not applicable (caller falls back).
    """
    if rules is None:
        return None
    axis = rules.rules.get("seq_q")
    if axis is None or not isinstance(axis, str):
        return None
    n = rules.mesh.shape[axis]
    b, tq, h, hd = q.shape
    if n <= 1 or tq % n or (tq // n) % 128:
        return None
    from jax.sharding import PartitionSpec as P
    q_spec = rules.spec("batch", "seq_q", None, None)
    kv_spec = rules.spec("batch", None, "kv_heads", None)
    out_spec = rules.spec("batch", "seq_q", None)

    def local(qk, kk, vv):
        idx = jax.lax.axis_index(axis)
        t_local = qk.shape[1]
        offset = pos_offset + idx * t_local
        if window > 0:
            return _windowed_seq_local(qk, kk, vv, offset, window)
        # q_chunk never larger than the local shard: avoids padding the
        # flash tiles 2x when T/n < 512 (train_4k at 16-way SP)
        return chunked_attention(qk, kk, vv, q_offset=offset,
                                 q_chunk=min(512, t_local))

    return jax.shard_map(local, mesh=rules.mesh,
                         in_specs=(q_spec, kv_spec, kv_spec),
                         out_specs=out_spec, check_vma=False)(q, k, v)


def decode_attention(q, k_cache, v_cache, cache_len, ring: bool = False,
                     window: int = 0) -> jnp.ndarray:
    """One-token decode: q (B,1,H,hd) vs cache (B,S,KV,hd).

    ``cache_len`` = number of tokens already written (including the one for
    this step).  For ring buffers every slot < window is valid once the ring
    has wrapped.  The KV-cache sequence axis may be sharded over the model
    axis ("kv_seq"); XLA lowers the masked softmax with a partial reduction
    + small all-reduce (flash-decode).
    """
    b, _, h, hd = q.shape
    s = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd) * (hd ** -0.5)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
    slot = jnp.arange(s)[None, None, None, None, :]
    if ring:
        valid = slot < jnp.minimum(cache_len, s)
    else:
        valid = slot < cache_len
    logits = jnp.where(valid, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v_cache)
    return o.reshape(b, 1, h * hd)


def attention_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                    kind: str, pos_offset=0, theta: Optional[float] = None,
                    cache: Optional[Params] = None,
                    cross_x: Optional[jnp.ndarray] = None,
                    causal: bool = True,
                    num_heads: Optional[int] = None,
                    num_kv: Optional[int] = None):
    """Full attention sub-block: project → rope → attend → out-project.

    Returns (out, new_cache).  ``cache=None`` means train/prefill without
    cache retention; a dict cache triggers the decode path when Tq == 1.
    kind: "full" | "local"; cross-attention passes ``cross_x`` (no rope,
    not causal).
    """
    h = num_heads or cfg.num_heads
    kv = num_kv or cfg.num_kv_heads
    hd = cfg.head_dim
    window = cfg.attn_window if kind == "local" else 0
    is_cross = cross_x is not None
    theta = cfg.rope_theta if theta is None else theta

    if is_cross or (cache is not None and "xk" in cache):
        if cross_x is None:
            # decode: cross K/V were cached at prefill
            k, v = cache["xk"], cache["xv"]
            q = x @ p["wq"]
            if "bq" in p:
                q = q + p["bq"]
            q = q.reshape(x.shape[0], x.shape[1], h, hd)
            new_cache = {"xk": k, "xv": v}
        else:
            q, k, v = _project_qkv(p, x, cross_x, cfg, h, kv)
            new_cache = {"xk": k, "xv": v} if cache is not None else None
        b, tq = q.shape[:2]
        if tq == 1:
            out = decode_attention(q, k, v, jnp.asarray(k.shape[1]))
        else:
            mask = jnp.zeros((tq, k.shape[1]), jnp.float32)
            out = direct_attention(q, k, v, mask)
        return matmul_lowp(out, p["wo"]), new_cache

    q, k, v = _project_qkv(p, x, x, cfg, h, kv)
    b, tq = q.shape[:2]
    positions = pos_offset + jnp.arange(tq)
    if theta:
        q = apply_rope(q, jnp.broadcast_to(positions, (b, tq)), theta)
        k = apply_rope(k, jnp.broadcast_to(positions, (b, tq)), theta)

    if cache is not None and tq == 1:
        # decode: append to (ring) cache, attend against it
        s_cache = cache["k"].shape[1]
        ring = window > 0 and s_cache <= window
        slot = (pos_offset % s_cache) if ring else pos_offset
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", None)
        v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", None)
        out = decode_attention(q, k_cache, v_cache, pos_offset + 1,
                               ring=ring, window=window)
        new_cache = {"k": k_cache, "v": v_cache}
        return out @ p["wo"], new_cache

    # train / prefill
    q = shard(q, "batch", "seq_q", "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    if tq <= 2048:
        mask = _causal_mask(tq, tq, pos_offset, window) if causal else \
            jnp.zeros((tq, tq), jnp.float32)
        out = direct_attention(q, k, v, mask)
    else:
        out = None
        if causal:
            from ..distributed.sharding import current_rules
            out = seq_parallel_attention(q, k, v, pos_offset=pos_offset,
                                         window=window, rules=current_rules())
        if out is None:
            if window > 0 and window <= tq // 2:
                out = banded_attention(q, k, v, pos_offset, window)
            else:
                out = chunked_attention(q, k, v, pos_offset, window)

    new_cache = None
    if cache is not None:
        s_cache = cache["k"].shape[1]
        ring = window > 0 and s_cache <= window
        if ring:
            # place the last s_cache tokens at their ring slots (slot of
            # position p is p % s_cache)
            take = min(tq, s_cache)
            kk = k[:, -take:].astype(cache["k"].dtype)
            vv = v[:, -take:].astype(cache["v"].dtype)
            p0 = pos_offset + tq - take
            kbuf = jnp.zeros_like(cache["k"]).at[:, :take].set(kk)
            vbuf = jnp.zeros_like(cache["v"]).at[:, :take].set(vv)
            shift = p0 % s_cache
            new_cache = {
                "k": jnp.roll(kbuf, shift, axis=1),
                "v": jnp.roll(vbuf, shift, axis=1),
            }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos_offset, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos_offset, 0, 0)),
            }
    return matmul_lowp(out, p["wo"]), new_cache
