"""Pattern-scanned decoder stack covering every assigned family.

Layers are grouped by the config's repeating ``pattern`` (e.g. gemma3's
5 local + 1 global, recurrentgemma's rglru/rglru/local, llama-vision's
4 self + 1 cross); parameters of each pattern position are stacked over
repeats and the stack is applied with ``lax.scan``, so HLO size (and
compile time) is independent of depth.  The non-divisible remainder is
unrolled.  ``jax.checkpoint`` (full remat) wraps the scanned body for
training.

Caches mirror the parameter structure: one stacked pytree per pattern
position plus per-remainder-layer entries.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .attention import attn_init, attention_block
from .common import (Params, dense_init, layer_norm, layer_norm_init,
                     rms_norm, rms_norm_init, split_keys)
from .mla import mla_block, mla_init
from .mlp import mlp, mlp_init
from .moe import moe_block, moe_init
from .rglru import rglru_block, rglru_init
from .rwkv import rwkv_channel_mix, rwkv_init, rwkv_time_mix


def _norm_init(cfg: ModelConfig, d: int, dtype):
    return rms_norm_init(d, dtype) if cfg.norm == "rms" else layer_norm_init(d, dtype)


def _norm(cfg: ModelConfig, p: Params, x):
    return rms_norm(p, x) if cfg.norm == "rms" else layer_norm(p, x)


# ---------------------------------------------------------------------------
# one residual block per kind
# ---------------------------------------------------------------------------

def block_init(key: jax.Array, cfg: ModelConfig, kind: str,
               dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = split_keys(key, 4)
    p: Params = {"ln1": _norm_init(cfg, d, dtype)}
    if kind in ("full", "local", "cross"):
        if cfg.mla is not None:
            p["attn"] = mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_init(ks[0], cfg, dtype=dtype)
        p["ln2"] = _norm_init(cfg, d, dtype)
        if cfg.moe is not None:
            p["moe"] = moe_init(ks[1], cfg, dtype)
        else:
            gated = cfg.act in ("silu", "gelu")
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, gated=gated, dtype=dtype)
        if kind == "cross":
            p["ln_x"] = _norm_init(cfg, d, dtype)
            p["xattn"] = attn_init(ks[2], cfg, dtype=dtype)
            if cfg.family == "vlm":        # llama-vision gates cross layers
                p["gate_x"] = jnp.zeros((), dtype)
                p["gate_m"] = jnp.zeros((), dtype)
    elif kind == "rglru":
        p["rec"] = rglru_init(ks[0], cfg, dtype)
        p["ln2"] = _norm_init(cfg, d, dtype)
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, gated=True, dtype=dtype)
    elif kind == "rwkv":
        p["tmix"] = rwkv_init(ks[0], cfg, dtype)
        p["ln2"] = _norm_init(cfg, d, dtype)
        # channel-mix params live inside tmix dict (shared init fn)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype) -> Optional[dict[str, Any]]:
    """ShapeDtypeStructs for one layer's decode cache."""
    d = cfg.d_model
    if kind in ("full", "local", "cross"):
        if cfg.mla is not None:
            m = cfg.mla
            spec = {"ckv": jax.ShapeDtypeStruct((batch, max_seq, m.kv_lora_rank), dtype),
                    "kr": jax.ShapeDtypeStruct((batch, max_seq, m.qk_rope_head_dim), dtype)}
        else:
            s = min(cfg.attn_window, max_seq) if (kind == "local" and cfg.attn_window) else max_seq
            kvd = (batch, s, cfg.num_kv_heads, cfg.head_dim)
            spec = {"k": jax.ShapeDtypeStruct(kvd, dtype),
                    "v": jax.ShapeDtypeStruct(kvd, dtype)}
        if kind == "cross":
            n_kv = (cfg.vision.num_image_tokens if cfg.vision
                    else cfg.encoder.num_frames)
            kvd = (batch, n_kv, cfg.num_kv_heads, cfg.head_dim)
            spec["xk"] = jax.ShapeDtypeStruct(kvd, dtype)
            spec["xv"] = jax.ShapeDtypeStruct(kvd, dtype)
        return spec
    if kind == "rglru":
        return {"h": jax.ShapeDtypeStruct((batch, d), dtype),
                "conv": jax.ShapeDtypeStruct((batch, 3, d), dtype)}
    if kind == "rwkv":
        h = d // cfg.rwkv_head_dim
        return {"s": jax.ShapeDtypeStruct((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                                          jnp.float32),
                "x_tm": jax.ShapeDtypeStruct((batch, d), dtype),
                "x_cm": jax.ShapeDtypeStruct((batch, d), dtype)}
    raise ValueError(kind)


def _gather_fsdp(params: Params) -> Params:
    """Re-constrain FSDP-sharded weights to their TP-only sharding at the
    point of use: one small per-layer weight all-gather (ZeRO-3) instead of
    letting the partitioner psum (B,T,D)-sized activation partials, which
    it otherwise prefers and which dominates the collective term
    (EXPERIMENTS.md §Perf-2, iteration 4)."""
    from ..distributed.sharding import current_rules
    rules = current_rules()
    if rules is None or rules.rules.get("fsdp") is None:
        return params
    from .model import _leaf_axes

    def fix(path, leaf):
        axes = _leaf_axes(path, leaf)
        axes = tuple(None if a == "fsdp" else a for a in axes)
        return jax.lax.with_sharding_constraint(leaf, rules.sharding(*axes))

    return jax.tree_util.tree_map_with_path(fix, params)


def apply_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                pos_offset, cache: Optional[Params] = None,
                cross_x: Optional[jnp.ndarray] = None, causal: bool = True):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = shard(x, "batch", None, None)
    # NOTE: _gather_fsdp (explicit per-layer ZeRO-3 weight gathering) was
    # tried here and REMOVED: it never improved the collective term (the
    # partitioner already schedules the equivalent exchange), regressed
    # B=1 decode 48x, and ballooned vision-90B multi-pod train memory by
    # forcing whole-stack gathers — full log in EXPERIMENTS.md §Perf-2.

    if kind == "rwkv":
        sub_cache = None if cache is None else \
            {"s": cache["s"], "x_tm": cache["x_tm"]}
        h, c1 = rwkv_time_mix(p["tmix"], _norm(cfg, p["ln1"], x), cfg,
                              cache=sub_cache)
        x = x + h
        sub_cache2 = None if cache is None else {"x_cm": cache["x_cm"]}
        h, c2 = rwkv_channel_mix(p["tmix"], _norm(cfg, p["ln2"], x), cfg,
                                 cache=sub_cache2)
        x = x + h
        new_cache = None if cache is None else {**c1, **c2}
        return x, new_cache, aux

    if kind == "rglru":
        h, c1 = rglru_block(p["rec"], _norm(cfg, p["ln1"], x), cfg,
                            cache=None if cache is None else
                            {"h": cache["h"], "conv": cache["conv"]})
        x = x + h
        x = x + mlp(p["mlp"], _norm(cfg, p["ln2"], x), cfg.act)
        return x, c1, aux

    # attention kinds
    attn_cache = None
    if cache is not None:
        attn_cache = {k: v for k, v in cache.items() if k in ("k", "v", "ckv", "kr")}
    if cfg.mla is not None:
        h, c_attn = mla_block(p["attn"], _norm(cfg, p["ln1"], x), cfg,
                              pos_offset=pos_offset, cache=attn_cache or None)
    else:
        h, c_attn = attention_block(
            p["attn"], _norm(cfg, p["ln1"], x), cfg, kind="local" if kind == "local" else "full",
            pos_offset=pos_offset, cache=attn_cache, causal=causal)
    x = x + h

    new_cache: Optional[dict[str, Any]] = None
    if cache is not None:
        new_cache = dict(c_attn or {})

    if kind == "cross":
        xc = _norm(cfg, p["ln_x"], x)
        x_cache = None
        if cache is not None:
            x_cache = {k: v for k, v in cache.items() if k in ("xk", "xv")}
            if not x_cache:
                x_cache = None
        h, c_x = attention_block(p["xattn"], xc, cfg, kind="full",
                                 cross_x=cross_x,
                                 cache=x_cache if x_cache else (
                                     {} if cache is not None else None))
        if "gate_x" in p:
            h = jnp.tanh(p["gate_x"]) * h
        x = x + h
        if cache is not None and c_x:
            new_cache.update(c_x)

    h2 = _norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        h2, aux = moe_block(p["moe"], h2, cfg)
    else:
        h2 = mlp(p["mlp"], h2, cfg.act)
    if kind == "cross" and "gate_m" in p:
        h2 = jnp.tanh(p["gate_m"]) * h2
    x = x + h2
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# the scanned stack
# ---------------------------------------------------------------------------

def stack_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    pattern = cfg.pattern
    reps = cfg.num_layers // len(pattern)
    rem_kinds = cfg.layer_kinds()[reps * len(pattern):]
    keys = split_keys(key, len(pattern) + len(rem_kinds))

    groups = []
    for i, kind in enumerate(pattern):
        rep_keys = jnp.stack(split_keys(keys[i], reps))
        stacked = jax.vmap(lambda k, kd=kind: block_init(k, cfg, kd, dtype))(rep_keys)
        groups.append(stacked)
    remainder = [block_init(keys[len(pattern) + j], cfg, kind, dtype)
                 for j, kind in enumerate(rem_kinds)]
    return {"groups": groups, "remainder": remainder}


def stack_cache_specs(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    pattern = cfg.pattern
    reps = cfg.num_layers // len(pattern)
    rem_kinds = cfg.layer_kinds()[reps * len(pattern):]

    def stacked_spec(kind):
        spec = block_cache_spec(cfg, kind, batch, max_seq, dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), spec)

    return {"groups": [stacked_spec(k) for k in pattern],
            "remainder": [block_cache_spec(cfg, k, batch, max_seq, dtype)
                          for k in rem_kinds]}


def apply_stack(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                pos_offset, caches: Optional[Params] = None,
                cross_x: Optional[jnp.ndarray] = None, causal: bool = True):
    """Returns (x, new_caches, total_aux)."""
    pattern = cfg.pattern
    reps = cfg.num_layers // len(pattern)
    rem_kinds = cfg.layer_kinds()[reps * len(pattern):]
    aux_total = jnp.zeros((), jnp.float32)

    def super_block(x, group_params, group_caches):
        """One pass through all pattern positions (one 'super layer')."""
        new_caches = []
        aux_sum = jnp.zeros((), jnp.float32)
        for pos, kind in enumerate(pattern):
            c = None if group_caches is None else group_caches[pos]
            x, nc, aux = apply_block(group_params[pos], x, cfg, kind,
                                     pos_offset=pos_offset, cache=c,
                                     cross_x=cross_x, causal=causal)
            new_caches.append(nc)
            aux_sum = aux_sum + aux
        return x, new_caches, aux_sum

    if reps > 0:
        def scan_body(carry, xs):
            x, aux = carry
            if caches is None:
                gp = xs
                x, _, a = super_block(x, gp, None)
                return (x, aux + a), None
            gp, gc = xs
            x, ncs, a = super_block(x, gp, gc)
            return (x, aux + a), ncs

        body = scan_body
        if cfg.remat and caches is None:
            body = jax.checkpoint(scan_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)

        xs = tuple(params["groups"]) if caches is None else \
            (tuple(params["groups"]), tuple(caches["groups"]))
        (x, aux_total), new_group_caches = jax.lax.scan(
            body, (x, aux_total), xs)
    else:
        new_group_caches = None
        if caches is not None:
            new_group_caches = caches["groups"]

    new_rem = []
    for j, kind in enumerate(rem_kinds):
        c = None if caches is None else caches["remainder"][j]
        x, nc, aux = apply_block(params["remainder"][j], x, cfg, kind,
                                 pos_offset=pos_offset, cache=c,
                                 cross_x=cross_x, causal=causal)
        new_rem.append(nc)
        aux_total = aux_total + aux

    new_caches = None
    if caches is not None:
        new_caches = {"groups": list(new_group_caches), "remainder": new_rem}
    return x, new_caches, aux_total
