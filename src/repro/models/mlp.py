"""MLP blocks: gated (SwiGLU/GeGLU) and plain (whisper's GELU MLP)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .common import Params, act_fn, dense_init, matmul_lowp, split_keys


def mlp_init(key: jax.Array, d: int, f: int, gated: bool = True,
             dtype=jnp.float32) -> Params:
    ks = split_keys(key, 3)
    p = {"w_up": dense_init(ks[0], d, f, dtype),
         "w_down": dense_init(ks[1], f, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = act_fn(act)(x @ p["w_gate"]) * h
    else:
        h = act_fn(act)(h)
    h = shard(h, "batch", None, "ffn")
    return matmul_lowp(h, p["w_down"])
