"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Time-mix recurrence per head (state S ∈ R^{hd x hd}):
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = (S_{t-1} + diag(u) k_t v_tᵀ)ᵀ r_t
with per-channel, data-dependent decay w_t = exp(-exp(ŵ_t)) (the paper's
"Finch" innovation over RWKV-5's static decay).  Token-shift interpolation
(lerp between x_t and x_{t-1}) feeds every projection; the data-dependent
shift uses a small LoRA as in the reference implementation.

Training/prefill runs a chunked lax.scan (state carried between chunks —
sub-quadratic, O(T·hd²) work); the Pallas kernel (repro.kernels.rwkv6)
implements the same chunk recurrence for TPU.  Decode is an O(1) update.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import Params, dense_init, split_keys


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = _heads(cfg)
    lora = 64
    ks = split_keys(key, 12)
    return {
        # time-mix
        "mix_base": (jax.random.uniform(ks[0], (5, d)) * 0.5).astype(dtype),
        "mix_lora_a": dense_init(ks[1], d, 32, dtype),
        "mix_lora_b": (jnp.zeros((32, 5 * d), dtype)),
        "w_r": dense_init(ks[2], d, d, dtype),
        "w_k": dense_init(ks[3], d, d, dtype),
        "w_v": dense_init(ks[4], d, d, dtype),
        "w_g": dense_init(ks[5], d, d, dtype),
        "decay_base": (jnp.full((d,), -6.0, dtype)),
        "decay_lora_a": dense_init(ks[6], d, lora, dtype),
        "decay_lora_b": jnp.zeros((lora, d), dtype),
        "u": (jax.random.uniform(ks[7], (h, hd)) * 0.5).astype(dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
        "w_o": dense_init(ks[8], d, d, dtype),
        # channel-mix
        "cmix_k": (jax.random.uniform(ks[9], (d,)) * 0.5).astype(dtype),
        "cmix_r": (jax.random.uniform(ks[10], (d,)) * 0.5).astype(dtype),
        "w_ck": dense_init(ks[11], d, cfg.d_ff, dtype),
        "w_cv": dense_init(ks[0], cfg.d_ff, d, dtype),
        "w_cr": dense_init(ks[1], d, d, dtype),
    }


def _token_shift(x: jnp.ndarray, x_prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x_{t-1} sequence (first step uses carried state or zeros)."""
    first = x_prev[:, None] if x_prev is not None else \
        jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _wkv_chunk_scan(r, k, v, w, u, s0, chunk: int = 128):
    """Chunked WKV recurrence: scan over time chunks with the inner chunk
    rematerialized, so the backward pass stores only T/chunk boundary
    states (B,H,hd,hd) instead of one per step — the same blocking the
    Pallas kernel (repro.kernels.rwkv6) keeps in VMEM.

    r,k,v: (B,T,H,hd); w: (B,T,H,hd) decay in (0,1); u: (H,hd) bonus.
    s0: (B,H,hd,hd) initial state. Returns (o: (B,T,H,hd), sT).
    """
    b, t, h, hd = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp          # (B,H,hd) each
        rt, kt, vt = (a.astype(jnp.float32) for a in (rt, kt, vt))
        kv = kt[..., :, None] * vt[..., None, :]         # (B,H,hd,hd)
        # o_t uses S_{t-1} plus the u-weighted current pair
        s_eff = s + u[None, :, :, None] * kv
        ot = jnp.einsum("bhij,bhi->bhj", s_eff, rt)
        s_new = wt[..., :, None] * s + kv
        return s_new, ot

    if t % chunk or t <= chunk:
        xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
        sT, o = jax.lax.scan(step, s0, xs)
        return o.transpose(1, 0, 2, 3), sT

    nc = t // chunk
    # (nc, chunk, b, h, hd)
    xs = tuple(x.reshape(b, nc, chunk, h, hd).transpose(1, 2, 0, 3, 4)
               for x in (r, k, v, w))

    def chunk_fn(s, inp):
        s, o = jax.lax.scan(step, s, inp)
        return s, o

    # default checkpoint: saves only chunk inputs; the backward pass
    # recomputes the chunk forward once with transient residuals (NOT
    # nothing_saveable, which would force O(chunk^2) re-recomputation
    # inside the inner scan's backward)
    chunk_fn = jax.checkpoint(chunk_fn)
    sT, o = jax.lax.scan(chunk_fn, s0, xs)       # o: (nc, chunk, b, h, hd)
    o = o.reshape(nc * chunk, b, h, hd).transpose(1, 0, 2, 3)
    return o, sT


def rwkv_time_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  cache: Optional[Params] = None):
    """Returns (out, new_cache). cache = {"s": (B,H,hd,hd), "x_tm": (B,D)}."""
    b, t, d = x.shape
    h, hd = _heads(cfg), cfg.rwkv_head_dim

    x_last = _token_shift(x, cache["x_tm"] if cache is not None else None)
    dx = x_last - x
    # data-dependent lerp amounts (5 projections share a LoRA)
    lora = jnp.tanh(x @ p["mix_lora_a"]) @ p["mix_lora_b"]
    mix = p["mix_base"][:, None, None] + lora.reshape(b, t, 5, d).transpose(2, 0, 1, 3)
    xr, xk, xv, xw, xg = [x + dx * mix[i] for i in range(5)]

    r = (xr @ p["w_r"]).reshape(b, t, h, hd)
    k = (xk @ p["w_k"]).reshape(b, t, h, hd)
    v = (xv @ p["w_v"]).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ p["w_g"])

    decay = p["decay_base"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, hd).astype(jnp.float32)

    s0 = cache["s"].astype(jnp.float32) if cache is not None else \
        jnp.zeros((b, h, hd, hd), jnp.float32)

    if cache is not None and t == 1:
        # match the prefill path's bf16 r/k/v streaming precision exactly
        rt, kt, vt = (a[:, 0].astype(jnp.bfloat16).astype(jnp.float32)
                      for a in (r, k, v))
        wt = w[:, 0]
        kv = kt[..., :, None] * vt[..., None, :]
        s_eff = s0 + p["u"].astype(jnp.float32)[None, :, :, None] * kv
        o = jnp.einsum("bhij,bhi->bhj", s_eff, rt)[:, None]
        sT = wt[..., :, None] * s0 + kv
    else:
        # stream r/k/v in bf16 (state and decay stay f32): halves the
        # dominant scan-xs traffic and the rematerialized-chunk footprint
        o, sT = _wkv_chunk_scan(r.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                                v.astype(jnp.bfloat16), w,
                                p["u"].astype(jnp.float32), s0)

    o = o.reshape(b, t, d)
    # group norm over heads
    og = o.reshape(b, t, h, hd)
    mu = og.mean(-1, keepdims=True)
    var = og.var(-1, keepdims=True)
    og = (og - mu) * jax.lax.rsqrt(var + 1e-5)
    o = og.reshape(b, t, d) * p["gn_scale"].astype(jnp.float32) + \
        p["gn_bias"].astype(jnp.float32)
    out = (o.astype(x.dtype) * g) @ p["w_o"]

    new_cache = None
    if cache is not None:
        new_cache = {"s": sT.astype(cache["s"].dtype), "x_tm": x[:, -1]}
    return out, new_cache


def rwkv_channel_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                     cache: Optional[Params] = None):
    """relu² channel mix with token shift. cache = {"x_cm": (B,D)}."""
    x_last = _token_shift(x, cache["x_cm"] if cache is not None else None)
    dx = x_last - x
    xk = x + dx * p["cmix_k"]
    xr = x + dx * p["cmix_r"]
    v = jnp.square(jax.nn.relu(xk @ p["w_ck"])) @ p["w_cv"]
    out = jax.nn.sigmoid(xr @ p["w_cr"]) * v
    new_cache = {"x_cm": x[:, -1]} if cache is not None else None
    return out, new_cache
