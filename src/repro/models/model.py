"""Model: the public API over the pattern-scanned stack.

One class serves all 10 assigned architectures; family differences
(whisper's encoder, the VLM's vision tokens, tied heads, learned vs rotary
positions) are handled here so that launch/dryrun, train, serving, tests
and benchmarks all speak one interface:

    model = build_model(cfg)
    params = model.init_params(key)                  # or eval_shape'd
    loss, metrics = model.loss_fn(params, batch)
    logits, caches = model.prefill(params, batch, caches)
    logits, caches = model.decode_step(params, tokens, pos, caches)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import shard
from .common import (Params, cross_entropy, embed_init, layer_norm,
                     layer_norm_init, rms_norm, rms_norm_init,
                     sinusoidal_positions, split_keys)
from .transformer import apply_stack, stack_cache_specs, stack_init

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    e = cfg.encoder
    return dataclasses.replace(
        cfg, num_layers=e.num_layers, d_model=e.d_model,
        num_heads=e.num_heads, num_kv_heads=e.num_heads,
        head_dim=e.d_model // e.num_heads, d_ff=e.d_ff,
        pattern=("full",), moe=None, mla=None, vision=None,
        qkv_bias=False, rope_theta=0.0)


class Model:
    def __init__(self, cfg: ModelConfig, max_pos: int = 4096):
        self.cfg = cfg
        self.max_pos = max_pos
        self.dtype = _DTYPES[cfg.dtype]

    # -- parameters ---------------------------------------------------------
    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dt = self.dtype
        ks = split_keys(key, 5)
        p: Params = {
            "tok": embed_init(ks[0], cfg.vocab_padded(), cfg.d_model, dt),
            "final_norm": (rms_norm_init(cfg.d_model, dt) if cfg.norm == "rms"
                           else layer_norm_init(cfg.d_model, dt)),
            "stack": stack_init(ks[1], cfg, dt),
        }
        if not cfg.tie_embeddings:
            p["head"] = embed_init(ks[2], cfg.vocab_padded(), cfg.d_model, dt).T
        if cfg.encoder is not None:
            ecfg = _enc_cfg(cfg)
            p["encoder"] = {
                "stack": stack_init(ks[3], ecfg, dt),
                "final_norm": layer_norm_init(ecfg.d_model, dt)
                if cfg.norm == "layer" else rms_norm_init(ecfg.d_model, dt),
            }
            # whisper decoder uses learned absolute positions
            p["dec_pos"] = (jax.random.normal(ks[4], (self.max_pos, cfg.d_model)) * 0.01).astype(dt)
        return p

    def abstract_params(self) -> Params:
        return jax.eval_shape(lambda k: self.init_params(k),
                              jax.random.key(0))

    # -- encoder (whisper) ----------------------------------------------------
    def _encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        ecfg = _enc_cfg(cfg)
        pos = sinusoidal_positions(frames.shape[1], ecfg.d_model).astype(frames.dtype)
        x = frames + pos[None]
        x, _, _ = apply_stack(params["encoder"]["stack"], x, ecfg,
                              pos_offset=0, causal=False)
        if cfg.norm == "layer":
            return layer_norm(params["encoder"]["final_norm"], x)
        return rms_norm(params["encoder"]["final_norm"], x)

    # -- forward --------------------------------------------------------------
    def forward(self, params: Params, tokens: jnp.ndarray, *,
                extras: Optional[dict[str, jnp.ndarray]] = None,
                pos_offset=0, caches: Optional[Params] = None,
                last_only: bool = False):
        """Returns (logits, new_caches, aux)."""
        cfg = self.cfg
        x = jnp.take(params["tok"], tokens, axis=0)
        if cfg.family in ("dense",) and cfg.name.startswith("gemma") or \
                cfg.family == "hybrid":
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = shard(x, "batch", None, None)

        cross_x = None
        if cfg.encoder is not None:
            if extras is not None and "frames" in extras:
                cross_x = self._encode(params, extras["frames"])
            t = tokens.shape[1]
            pos_ids = pos_offset + jnp.arange(t)
            x = x + jnp.take(params["dec_pos"], pos_ids, axis=0)[None]
        elif cfg.vision is not None and extras is not None and "vision" in extras:
            cross_x = extras["vision"]

        x, new_caches, aux = apply_stack(
            params["stack"], x, cfg, pos_offset=pos_offset, caches=caches,
            cross_x=cross_x)

        if cfg.norm == "rms":
            x = rms_norm(params["final_norm"], x)
        else:
            x = layer_norm(params["final_norm"], x)
        if last_only:
            x = x[:, -1:]
        head = params["head"] if not cfg.tie_embeddings else params["tok"].T
        logits = x @ head.astype(x.dtype)
        logits = shard(logits, "batch", None, "vocab")
        return logits, new_caches, aux

    # -- train ---------------------------------------------------------------
    def loss_fn(self, params: Params, batch: dict[str, jnp.ndarray]):
        logits, _, aux = self.forward(params, batch["tokens"],
                                      extras=batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}

    # -- serve ---------------------------------------------------------------
    def prefill(self, params: Params, batch: dict[str, jnp.ndarray],
                caches: Params):
        logits, caches, _ = self.forward(params, batch["tokens"],
                                         extras=batch, pos_offset=0,
                                         caches=caches, last_only=True)
        return logits, caches

    def decode_step(self, params: Params, tokens: jnp.ndarray,
                    pos, caches: Params):
        """tokens (B, 1); pos = number of tokens already in the cache."""
        logits, caches, _ = self.forward(params, tokens, pos_offset=pos,
                                         caches=caches)
        return logits, caches

    # -- specs (abstract inputs for dry-run / compile) -------------------------
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        dt = self.dtype
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:  # decode
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.encoder is not None and shape.kind != "decode":
            e = cfg.encoder
            specs["frames"] = jax.ShapeDtypeStruct((b, e.num_frames, e.d_model), dt)
        if cfg.vision is not None and shape.kind != "decode":
            specs["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.num_image_tokens, cfg.d_model), dt)
        return specs

    def cache_specs(self, shape: ShapeConfig):
        return stack_cache_specs(self.cfg, shape.global_batch, shape.seq_len,
                                 self.dtype)

    def init_cache(self, batch: int, max_seq: int) -> Params:
        shp = ShapeConfig("adhoc", max_seq, batch, "decode")
        specs = stack_cache_specs(self.cfg, batch, max_seq, self.dtype)
        return jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype), specs)


def build_model(cfg: ModelConfig, max_pos: int = 4096) -> Model:
    return Model(cfg, max_pos=max_pos)


# ---------------------------------------------------------------------------
# parameter logical axes (for NamedSharding via distributed.sharding rules)
# ---------------------------------------------------------------------------

_LEAF_AXES: dict[str, tuple] = {
    "tok": ("vocab", None),
    "head": (None, "vocab"),
    "dec_pos": (None, None),
    "wq": ("fsdp", "qheads"),
    "wk": ("fsdp", None),
    "wv": ("fsdp", None),
    "wo": ("qheads", "fsdp"),
    "bq": ("qheads",), "bk": (None,), "bv": (None,),
    "w_up": ("fsdp", "ffn"), "w_gate": ("fsdp", "ffn"),
    "w_down": ("ffn", "fsdp"),
    "router": (None, None),
    # MLA
    "w_dq": ("fsdp", None), "w_uq": (None, "qheads"),
    "w_dkv": ("fsdp", None), "w_uk": (None, "qheads"),
    "w_uv": (None, "qheads"), "w_kr": (None, None),
    # RG-LRU
    "w_gate_branch": ("fsdp", "lru"), "w_x_branch": ("fsdp", "lru"),
    "conv_w": (None, "lru"), "conv_b": ("lru",),
    # block-diagonal gates: block dim sharded like the lru channels, so the
    # per-block matmuls contract entirely within a shard (no collective)
    "w_a": ("lru_blocks", None, None), "b_a": ("lru",),
    "w_i": ("lru_blocks", None, None), "b_i": ("lru",),
    "lam": ("lru",), "w_out": ("lru", "fsdp"),
    # RWKV
    "w_r": ("fsdp", None), "w_k": ("fsdp", None), "w_v": ("fsdp", None),
    "w_g": ("fsdp", None), "w_o": ("fsdp", None),
    "decay_lora_a": ("fsdp", None), "decay_lora_b": (None, None),
    "mix_lora_a": ("fsdp", None), "mix_lora_b": (None, None),
    "mix_base": (None, None), "decay_base": (None,),
    "u": (None, None), "gn_scale": (None,), "gn_bias": (None,),
    "w_ck": ("fsdp", "rwkv_ffn"), "w_cv": ("rwkv_ffn", "fsdp"),
    "w_cr": ("fsdp", None),
    "cmix_k": (None,), "cmix_r": (None,),
}

_MOE_LEAF_AXES = {
    "w_gate": ("experts", "fsdp", None),
    "w_up": ("experts", "fsdp", None),
    "w_down": ("experts", None, "fsdp"),
}


def _leaf_axes(path, leaf) -> tuple:
    names = [getattr(k, "key", None) for k in path]
    name = names[-1]
    in_moe = "moe" in names
    table = _MOE_LEAF_AXES if (in_moe and name in _MOE_LEAF_AXES) else _LEAF_AXES
    axes = table.get(name)
    nd = len(leaf.shape)
    if axes is None:
        return (None,) * nd
    if len(axes) < nd:                 # stacked (scan) leading axes
        return (None,) * (nd - len(axes)) + tuple(axes)
    return tuple(axes[:nd])


def param_logical_axes(cfg: ModelConfig, params: Params):
    """Tree of logical-axis tuples matching the params tree."""
    return jax.tree_util.tree_map_with_path(_leaf_axes, params)


def param_shardings(cfg: ModelConfig, params: Params, rules):
    """NamedShardings for every param leaf under the given rules."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rules.sharding(*_leaf_axes(path, leaf)), params)
