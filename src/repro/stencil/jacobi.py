"""The paper's blocked Jacobi solver as a distributed JAX application.

This is where the paper's locality story becomes measurable on a TPU mesh:
the lattice's i-axis is decomposed into slabs of blocks, and the
*block → device assignment* plays the role of page placement.

  * ``contiguous`` assignment (= the paper's parallel first touch +
    locality queues): each device owns one contiguous slab; a sweep needs
    exactly two boundary planes per device, exchanged with its mesh
    neighbours via ``lax.ppermute`` — minimal "nonlocal traffic".

  * ``scattered`` assignment (= dynamic scheduling with no locality
    control): slabs are strided over devices, so *every* slab boundary
    crosses a device boundary and each device must fetch ``blocks_per_dev*2``
    remote planes — the halo volume (and hence the collective roofline term
    of the compiled HLO) inflates by ~``blocks_per_dev``x.

The sweep body itself is the Pallas kernel (or its jnp oracle); the
schedule builder of ``repro.core.assignment`` chooses the contiguous slabs
when given block homes, demonstrating the end-to-end path
placement → locality queues → SPMD assignment → fewer collective bytes.

``run_runtime_sweep`` adds a third, *online* execution path: slab updates
submitted as tasks to the ``repro.runtime`` executor, with the paper's
locality queues scheduling them dynamically (identical physics, observable
local/steal statistics).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels.jacobi.ops import jacobi_sweep
from ..kernels.jacobi.ref import jacobi_sweep_ref
from ..runtime import Executor, RuntimeStats, StealGovernor


@dataclasses.dataclass(frozen=True)
class JacobiGridConfig:
    ni: int = 240
    nj: int = 60
    nk: int = 64
    di: int = 10
    dj: int = 10
    dtype: str = "float32"
    axis: str = "data"          # mesh axis the i-axis is sharded over


def _halo_exchange(local: jnp.ndarray, axis: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fetch the previous slab's last plane and next slab's first plane.

    Contiguous slab ownership ⇒ one ppermute in each direction (the
    locality-optimal schedule).  Edge devices receive zeros (Dirichlet).
    """
    n = jax.lax.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    up = jax.lax.ppermute(local[-1], axis, fwd)     # from idx-1's last plane
    down = jax.lax.ppermute(local[0], axis, bwd)    # from idx+1's first plane
    up = jnp.where(idx == 0, jnp.zeros_like(up), up)
    down = jnp.where(idx == n - 1, jnp.zeros_like(down), down)
    return up, down


def make_contiguous_sweep(cfg: JacobiGridConfig, use_pallas: bool = False):
    """shard_map'd sweep with contiguous slab ownership (locality schedule)."""

    def sweep_local(f_local: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
        up, down = _halo_exchange(f_local, cfg.axis)
        padded = jnp.concatenate([up[None], f_local, down[None]], axis=0)
        # interior update on the padded slab, then crop the halo rows.
        if use_pallas:
            # pad i to a block multiple for the kernel, update, crop.
            out = jacobi_sweep(padded, use_pallas=False)
        else:
            out = jacobi_sweep_ref(padded)
        out = out[1:-1]
        # the ref applies Dirichlet at the padded-slab boundary, but rows
        # 0/-1 of the crop saw the true halo planes, so values are exact.
        return out

    def sweep(f: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
        return jax.shard_map(
            sweep_local,
            in_specs=(P(cfg.axis, None, None), P()),
            out_specs=P(cfg.axis, None, None),
        )(f, c)

    return sweep


def make_scattered_sweep(cfg: JacobiGridConfig, blocks_per_dev: int):
    """Sweep under a locality-oblivious (strided) block→device assignment.

    Device d owns i-slabs {d, d+D, d+2D, ...}: every slab boundary is a
    device boundary, so the halo for *each* owned slab must come from a
    different device.  Implemented as an all-gather of every slab's boundary
    planes — the honest communication cost of scattering.
    """

    def sweep_local(f_local: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
        axis = cfg.axis
        n = jax.lax.axis_size(axis)
        d = jax.lax.axis_index(axis)
        si = f_local.shape[0] // blocks_per_dev     # rows per slab
        # boundary planes of my slabs: (blocks_per_dev, 2, nj, nk)
        slabs = f_local.reshape(blocks_per_dev, si, *f_local.shape[1:])
        bounds = jnp.stack([slabs[:, 0], slabs[:, -1]], axis=1)
        # every device needs planes from (almost) every other: all-gather.
        all_bounds = jax.lax.all_gather(bounds, axis)   # (n, bpd, 2, nj, nk)

        def halo_for(slab_global_idx):
            total = n * blocks_per_dev
            prev_g = slab_global_idx - 1
            next_g = slab_global_idx + 1
            # global slab g is owned by device g % n as its (g // n)-th slab
            def plane(g, which):
                g_c = jnp.clip(g, 0, total - 1)
                p = all_bounds[g_c % n, g_c // n, which]
                valid = (g >= 0) & (g < total)
                return jnp.where(valid, p, jnp.zeros_like(p))
            return plane(prev_g, 1), plane(next_g, 0)

        outs = []
        for b in range(blocks_per_dev):
            g = d + b * n                      # strided ownership
            up, down = halo_for(g)
            padded = jnp.concatenate([up[None], slabs[b], down[None]], axis=0)
            outs.append(jacobi_sweep_ref(padded)[1:-1])
        return jnp.concatenate(outs, axis=0)

    def sweep(f: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
        return jax.shard_map(
            sweep_local,
            in_specs=(P(cfg.axis, None, None), P()),
            out_specs=P(cfg.axis, None, None),
        )(f, c)

    return sweep


def reassemble_scattered(out: jnp.ndarray, n_dev: int, blocks_per_dev: int) -> jnp.ndarray:
    """Map the scattered sweep's device-major row order back to lattice order.

    Device d's local output stacks its slabs [d, d+D, d+2D, ...]; lattice
    order interleaves them back.
    """
    si = out.shape[0] // (n_dev * blocks_per_dev)
    x = out.reshape(n_dev, blocks_per_dev, si, *out.shape[1:])
    x = jnp.swapaxes(x, 0, 1)                      # (bpd, n, si, ...)
    return x.reshape(n_dev * blocks_per_dev * si, *out.shape[1:])


def scatter_lattice(f: jnp.ndarray, n_dev: int, blocks_per_dev: int) -> jnp.ndarray:
    """Inverse of reassemble_scattered: lattice order -> device-major order."""
    si = f.shape[0] // (n_dev * blocks_per_dev)
    x = f.reshape(blocks_per_dev, n_dev, si, *f.shape[1:])
    x = jnp.swapaxes(x, 0, 1)
    return x.reshape(n_dev * blocks_per_dev * si, *f.shape[1:])


def run_runtime_sweep(f, c: float = 1.0 / 6.0, di: int = 10,
                      num_domains: int = 4, workers_per_domain: int = 1,
                      steal_order: str = "cyclic",
                      governor: StealGovernor | None = None,
                      pool_cap: int = 256,
                      seed: int = 0,
                      trace=None,
                      spec=None) -> tuple[np.ndarray, RuntimeStats]:
    """One whole-lattice sweep executed as online runtime tasks.

    The third execution path next to the shard_map'd SPMD sweeps above: the
    i-axis is cut into slabs of ``di`` rows, each slab update is one
    ``runtime.Task`` homed on a locality domain (contiguous slab→domain
    map = the paper's parallel first touch), and a ``runtime.Executor``
    schedules them.  A Jacobi sweep reads only the *old* array, so tasks
    commute and any schedule yields the exact ``jacobi_sweep_ref`` answer —
    the scheduling policy changes the local/steal statistics, never the
    physics.  Returns ``(new_lattice, runtime_stats)``.

    ``trace`` takes an optional ``repro.trace.TraceRecorder``: the sweep's
    slab-task schedule is then recorded for offline steal-storm analysis
    and deterministic replay (``repro.trace.replay`` re-drives the same
    slab arrival sequence under any policy; the replayed task payloads are
    placeholders — replay studies the *schedule*, not the physics).

    ``spec`` takes a ``repro.spec.RuntimeSpec`` and builds the executor
    from it (the preferred path — the scheduling-policy kwargs above are
    then ignored, and a recorded trace embeds the spec so ``replay(trace)``
    reconstructs the schedule with no factory).
    """
    f = np.asarray(f)
    ni = f.shape[0]
    if ni % di != 0:
        raise ValueError(f"i extent {ni} not divisible by slab size {di}")
    nslabs = ni // di
    out = np.empty_like(f)
    zero_plane = np.zeros_like(f[0])

    def update_slab(task, worker):
        s = task.payload
        i0 = s * di
        up = f[i0 - 1] if i0 > 0 else zero_plane
        down = f[i0 + di] if i0 + di < ni else zero_plane
        padded = np.concatenate([up[None], f[i0:i0 + di], down[None]], axis=0)
        # the ref applies Dirichlet at the padded-slab i-faces, but the crop
        # keeps only rows that saw the true halo planes, so values are exact.
        out[i0:i0 + di] = np.asarray(jacobi_sweep_ref(jnp.asarray(padded), c))[1:-1]

    if spec is not None:
        if spec.trace.record:
            from ..spec import SpecError
            raise SpecError(
                "run_runtime_sweep returns only (lattice, stats) and cannot "
                "hand back a spec-declared recorder; record via the trace= "
                "kwarg (and TraceSpec(record=False)) instead")
        num_domains = spec.num_domains
        ex = spec.build(handler=update_slab).executor
    else:
        ex = Executor(num_domains, [d for d in range(num_domains)
                                    for _ in range(workers_per_domain)],
                      handler=update_slab, steal_order=steal_order,
                      governor=governor, pool_cap=pool_cap, seed=seed)
    if trace is not None:
        trace.attach(ex)
    for s in range(nslabs):
        home = s * num_domains // nslabs       # contiguous slabs per domain
        ex.submit(ex.make_task(payload=s, home=home))
    ex.run_until_drained()
    return out, ex.stats


@functools.lru_cache(maxsize=None)
def paper_flops_per_site() -> int:
    return 6  # five adds + one multiply (paper: 8/3 bytes per flop at 16 B/site)
