"""repro.topology — hierarchical locality domains as distance trees.

The runtime-facing topology model: a ``DistanceMatrix`` of inter-domain
access costs with levels derived by ranking the distinct distances, plus
builders for the repo's layouts (``flat``, ``grouped`` sockets, TPU
``pods``).  Declared in a ``repro.spec.TopologySpec`` and consumed by
``runtime.DomainQueues`` (nearest-first steal scans), ``runtime.Executor``
(distance-scaled penalties), ``runtime.AdaptiveSteal`` (per-level θ), and
the ``repro.control`` plane (level-aware spilling and storm breaking).
"""
from .distance import DistanceMatrix, TopologyError, flat, grouped, pods

__all__ = ["DistanceMatrix", "TopologyError", "flat", "grouped", "pods"]
