"""Hierarchical locality-domain distances: the runtime's topology model.

The paper treats locality domains as flat peers — every nonlocal access
costs the same bounded penalty, so the steal scan may visit victims in any
order (§2.2).  Real ccNUMA machines are trees: cores share a socket, sockets
share a host, hosts share a pod.  The hierarchical-runtime line of work
(Thibault et al., arxiv 0706.2073; Tahan, arxiv 1411.7131) shows that a
scheduler which knows the tree steals from siblings before cousins and pays
the deep-link penalty only when the near tiers are truly dry.

``DistanceMatrix`` is the runtime-facing form of that tree: an n×n symmetric
matrix of relative access costs (diagonal 0), from which *levels* are
derived by ranking the distinct off-diagonal distances — level 1 is the
nearest tier (e.g. same socket), level 2 the next (cross socket), and so on.
The runtime consumes only the derived structure:

  * ``peers(domain, level)``        — foreign domains at exactly that level,
                                      in ascending domain order (the
                                      deterministic scan universe);
  * ``cyclic_peers(domain, level)`` — the same set rotated to start just
                                      after the caller, so the paper's
                                      cyclic scan keeps its §2.2 shape
                                      *within* a level;
  * ``distance(a, b)``              — the penalty scale factor a steal
                                      across that link pays.

Three builders cover the repo's layouts: ``flat`` (the paper's machines as
PR 1 modelled them — one level, distance 1 everywhere, byte-compatible with
no topology at all), ``grouped`` (two-level socket/domain trees), and
``pods`` (the TPU tier: domains grouped into pods, with the cross-pod
distance priced from ``core.topology.tpu_topology``'s ICI-vs-DCN bandwidth
ratio — crossing a pod boundary costs what the DCN link's relative slowdown
says it costs).

A ``DistanceMatrix`` is pure data (``to_dict``/``from_dict`` round-trip
exactly), so trace headers can embed it and a recorded hierarchical run
replays from its header alone.
"""
from __future__ import annotations

from typing import Any, Sequence

from ..core.topology import tpu_topology


class TopologyError(ValueError):
    """Raised for malformed distance matrices or builder arguments."""


class DistanceMatrix:
    """Symmetric inter-domain distances plus the derived level structure.

    ``distances[a][b]`` is the relative cost scale of domain ``a`` accessing
    domain ``b``'s memory: 0 on the diagonal, positive elsewhere, symmetric
    (the runtime's links are bidirectional buses, not routes).  Levels rank
    the distinct off-diagonal values ascending: ``level(a, b)`` is 1 for the
    nearest tier, ``num_levels`` for the farthest, 0 only for ``a == b``.
    """

    def __init__(self, distances: Sequence[Sequence[float]]):
        rows = [tuple(float(x) for x in row) for row in distances]
        n = len(rows)
        if n < 1:
            raise TopologyError("distance matrix needs at least one domain")
        for a, row in enumerate(rows):
            if len(row) != n:
                raise TopologyError(
                    f"distance matrix is not square: row {a} has {len(row)} "
                    f"entries for {n} domains")
            if row[a] != 0.0:
                raise TopologyError(
                    f"distance({a},{a}) must be 0, got {row[a]}")
            for b, d in enumerate(row):
                if b != a and d <= 0.0:
                    raise TopologyError(
                        f"distance({a},{b}) must be positive, got {d}")
                if rows[b][a] != d:
                    raise TopologyError(
                        f"distance matrix is asymmetric at ({a},{b}): "
                        f"{d} != {rows[b][a]}")
        self._d = tuple(rows)
        self.num_domains = n
        tiers = sorted({d for row in rows for d in row if d > 0.0})
        self.num_levels = len(tiers)
        rank = {d: i + 1 for i, d in enumerate(tiers)}
        self._level = tuple(
            tuple(0 if b == a else rank[rows[a][b]] for b in range(n))
            for a in range(n))
        # per-domain scan universes: peers grouped by level, ascending domain
        # order, plus the cyclic rotation (domains after the caller first) so
        # the paper's (domain + off) % n scan survives inside each level.
        self._peers = tuple(
            tuple(tuple(b for b in range(n) if self._level[a][b] == lv)
                  for lv in range(1, self.num_levels + 1))
            for a in range(n))
        self._cyclic = tuple(
            tuple(tuple(b for b in ps if b > a) + tuple(b for b in ps if b < a)
                  for ps in self._peers[a])
            for a in range(n))

    # -- structure reads -----------------------------------------------------
    def distance(self, a: int, b: int) -> float:
        return self._d[a][b]

    def level(self, a: int, b: int) -> int:
        """Tier of the ``a``→``b`` link: 0 for self, 1 = nearest tier, up to
        ``num_levels`` = farthest."""
        return self._level[a][b]

    def peers(self, domain: int, level: int) -> tuple[int, ...]:
        """Foreign domains exactly ``level`` away, ascending domain order."""
        if not 1 <= level <= self.num_levels:
            raise TopologyError(f"level {level} outside 1..{self.num_levels}")
        return self._peers[domain][level - 1]

    def cyclic_peers(self, domain: int, level: int) -> tuple[int, ...]:
        """``peers`` rotated to start just after ``domain`` — the §2.2 cyclic
        visiting order restricted to one level."""
        if not 1 <= level <= self.num_levels:
            raise TopologyError(f"level {level} outside 1..{self.num_levels}")
        return self._cyclic[domain][level - 1]

    @property
    def hierarchical(self) -> bool:
        """True when there is more than one steal tier — the runtime's
        nearest-first scan only engages then (a single tier is scan-identical
        to the flat PR-1 behaviour by construction)."""
        return self.num_levels > 1

    def remote_level(self) -> int:
        """The first *cross* tier (2), the boundary the storm detectors and
        the breaker treat as "remote"; equals ``num_levels`` + 1 when the
        matrix is flat (i.e. nothing is remote)."""
        return 2

    # -- value semantics -----------------------------------------------------
    def __eq__(self, other: object) -> bool:
        return isinstance(other, DistanceMatrix) and self._d == other._d

    def __hash__(self) -> int:
        return hash(self._d)

    def __repr__(self) -> str:
        return (f"DistanceMatrix(num_domains={self.num_domains}, "
                f"num_levels={self.num_levels})")

    # -- serialization (trace headers embed this) ----------------------------
    def to_dict(self) -> dict[str, Any]:
        return {"num_domains": self.num_domains,
                "distances": [list(row) for row in self._d]}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DistanceMatrix":
        if not isinstance(d, dict) or "distances" not in d:
            raise TopologyError(
                f"expected a distance-matrix object with 'distances', "
                f"got {d!r}")
        m = cls(d["distances"])
        n = d.get("num_domains")
        if n is not None and int(n) != m.num_domains:
            raise TopologyError(
                f"num_domains {n} does not match a "
                f"{m.num_domains}x{m.num_domains} matrix")
        return m


# -- builders ----------------------------------------------------------------

def flat(num_domains: int, distance: float = 1.0) -> DistanceMatrix:
    """The paper's flat machine: every foreign domain one uniform hop away.

    With ``distance=1.0`` (the default) this is behaviour-identical to no
    topology at all — one steal level, penalty scale 1 — which is the
    back-compat anchor the replay goldens pin.
    """
    if num_domains < 1:
        raise TopologyError("need at least one domain")
    if distance <= 0:
        raise TopologyError("distance must be positive")
    return DistanceMatrix(
        [[0.0 if a == b else float(distance) for b in range(num_domains)]
         for a in range(num_domains)])


def grouped(groups: Sequence[int], near: float = 1.0,
            far: float = 4.0) -> DistanceMatrix:
    """A two-level socket/domain tree: ``groups[i]`` domains share socket
    ``i`` at distance ``near``; crossing sockets costs ``far``.

    ``far == near`` degenerates to a flat matrix (one level) — useful for
    A/B arms that differ only in the tree, not the link costs.
    """
    gs = [int(g) for g in groups]
    if not gs or any(g < 1 for g in gs):
        raise TopologyError(f"groups must be positive ints, got {groups!r}")
    if near <= 0 or far < near:
        raise TopologyError(f"need far >= near > 0, got near={near} far={far}")
    socket = []
    for i, g in enumerate(gs):
        socket += [i] * g
    n = len(socket)
    return DistanceMatrix(
        [[0.0 if a == b else (near if socket[a] == socket[b] else far)
          for b in range(n)] for a in range(n)])


def pods(num_pods: int, domains_per_pod: int, near: float = 1.0,
         chips_per_pod: int = 256) -> DistanceMatrix:
    """The TPU tier as a distance tree: ``domains_per_pod`` domains share a
    pod (ICI, distance ``near``); crossing pods rides the DCN.

    The cross-pod distance is priced from ``core.topology.tpu_topology``'s
    calibrated ``remote_factor`` (DCN effective bandwidth relative to ICI):
    a link that delivers ``remote_factor`` of the local bandwidth costs
    ``near / remote_factor`` to cross — the same bandwidth→cost inversion
    the ccNUMA simulator applies to the paper's Table 1 machines.
    """
    if num_pods < 1 or domains_per_pod < 1:
        raise TopologyError("need num_pods >= 1 and domains_per_pod >= 1")
    machine = tpu_topology(num_pods, chips_per_pod)
    far = near / machine.remote_factor
    return grouped([domains_per_pod] * num_pods, near=near, far=far)
