"""Self-profiling of the scheduler's own hot paths.

The ROADMAP's production-scale question is not "how fast do tasks run" but
"how much does each *scheduling decision* cost" (cf. Wang et al. on
fine-grained parallelism overheads).  ``HotPathProfiler`` answers it with
opt-in ``perf_counter_ns`` timers around the four decision sites the
``Executor`` exposes:

  ``submit_route``  — choosing a queue for a routed submission
                      (router / home / round-robin, in ``submit``)
  ``steal_scan``    — one dequeue attempt: the local check plus the
                      governed victim scan (``DomainQueues.dequeue``)
  ``batch_grab``    — draining batch-mates from the chosen queue
                      (``DomainQueues.drain``; only fires when the batch
                      limit exceeds 1)
  ``event_append``  — appending one event to the ring-buffer ``EventLog``

The profiler is *passive state plus integer adds*: the executor calls
``add(path, ns)`` with an elapsed time it measured itself, so an attached
profiler perturbs nothing but wall clock (scheduling decisions, stats, and
replay remain bit-identical — the obs invariant the tests gate).  With no
profiler attached (the default) the executor skips the timers entirely.

``benchmarks/scheduler_overhead.py`` aggregates these into
``BENCH_overhead.json``: ns/decision per hot path as task and domain count
scale.
"""
from __future__ import annotations

PATHS = ("submit_route", "steal_scan", "batch_grab", "event_append")


class HotPathProfiler:
    """Accumulates total elapsed ns and call counts per hot path."""

    def __init__(self) -> None:
        self.ns = dict.fromkeys(PATHS, 0)
        self.calls = dict.fromkeys(PATHS, 0)

    def add(self, path: str, ns: int) -> None:
        self.ns[path] += ns
        self.calls[path] += 1

    def ns_per_call(self) -> dict[str, float]:
        """Mean ns per decision for every path (0.0 where a path never
        fired — e.g. ``batch_grab`` under single-task grabs)."""
        return {p: (self.ns[p] / self.calls[p] if self.calls[p] else 0.0)
                for p in PATHS}

    @property
    def total_ns(self) -> int:
        return sum(self.ns.values())

    def merge(self, other: "HotPathProfiler") -> None:
        for p in PATHS:
            self.ns[p] += other.ns[p]
            self.calls[p] += other.calls[p]

    def snapshot(self) -> dict:
        return {"ns": dict(self.ns), "calls": dict(self.calls),
                "ns_per_call": self.ns_per_call()}

    def __repr__(self) -> str:
        per = ", ".join(f"{p}={v:.0f}ns" for p, v in self.ns_per_call().items())
        return f"HotPathProfiler({per})"
