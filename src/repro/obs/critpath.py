"""Critical-path blame: where each task's sojourn actually went.

The paper's argument is an *attribution* argument — locality queues win
because remote access and steal churn are charged to the decisions that
caused them, not guessed at from aggregates.  ``observe`` (PR 7) reports
the sojourn distribution; this module explains it, decomposing every
observed task's sojourn into the three phases the runtime can actually
spend time in::

    sojourn  =  queue_wait  +  steal_transfer  +  exec

  queue_wait      scheduling rounds between submission and execution —
                  time spent sitting in the routed queue (charged to the
                  queue's domain);
  steal_transfer  the nonlocal penalty actually paid when the task was
                  taken from a foreign queue (charged to the thief's
                  domain and to the topology level of the link crossed —
                  level 0 means the task ran local and paid nothing);
  exec            the task's own execution cost (charged to the executing
                  domain).

The decomposition is *exact by construction*: it is computed from the very
fields (``wait``, ``Event.cost``, ``Event.penalty``) whose sum defines the
recorded sojourn (``trace.replay.TaskTiming.sojourn = wait + (cost +
penalty)``), in the same operation order, so per task the phases sum
bit-identically to the recorded sojourn — the invariant
``tests/test_analytics.py`` gates over the whole policy × workload matrix.
Aggregation (per-domain and per-level blame tables, top-K dominant
contributors) iterates tasks in ascending uid order, so two decompositions
of the same trace are identical — the same schedule-passivity contract the
rest of ``repro.obs`` keeps.

Works on any v1–v4 trace: steal levels are priced by the header-embedded
``DistanceMatrix`` when one exists (schema v3+), else every steal is the
flat machine's level 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from ..trace.schema import event_stolen
from .spans import EXEC_KINDS

PHASES = ("queue_wait", "steal_transfer", "exec")


@dataclasses.dataclass(frozen=True)
class TaskBlame:
    """One task's exact sojourn decomposition (see module docstring).

    ``level`` is the topology tier the steal crossed (1 = nearest, 2+ =
    remote), or 0 when the task executed without being stolen.
    """

    uid: int
    home: int
    routed: int          # the queue the submission was routed to
    exec_domain: int     # the domain whose worker executed it
    worker: int
    level: int
    queue_wait: float
    steal_transfer: float
    exec: float

    @property
    def sojourn(self) -> float:
        """Exactly the recorded sojourn: ``wait + (cost + penalty)`` in the
        same float-operation order ``TaskTiming.sojourn`` uses."""
        return self.queue_wait + (self.exec + self.steal_transfer)

    @property
    def phases(self) -> dict[str, float]:
        return {"queue_wait": self.queue_wait,
                "steal_transfer": self.steal_transfer, "exec": self.exec}

    @property
    def dominant(self) -> str:
        """The phase holding the largest share of this task's sojourn (ties
        break by the fixed ``PHASES`` order, so the answer is deterministic).
        """
        ph = self.phases
        return max(PHASES, key=lambda p: (ph[p], -PHASES.index(p)))


def _zero_row() -> dict[str, float]:
    return {"queue_wait": 0.0, "steal_transfer": 0.0, "exec": 0.0,
            "total": 0.0, "tasks": 0}


@dataclasses.dataclass
class BlameReport:
    """The full critical-path attribution of one trace.

    ``by_domain`` charges each phase to the domain that owns it:
    queue-wait to the *routed* queue's domain, steal-transfer and exec to
    the *executing* domain.  ``by_level`` splits steal-transfer blame by
    the topology tier crossed (level 0 rows aggregate local executions:
    zero transfer, all exec).  Both tables carry a ``total`` column and a
    task count; summing any table's ``total`` column reproduces
    ``total_sojourn`` (same floats, fixed iteration order).
    """

    tasks: dict[int, TaskBlame]
    missing: tuple[int, ...]
    by_domain: dict[int, dict[str, float]]
    by_level: dict[int, dict[str, float]]
    totals: dict[str, float]

    def __len__(self) -> int:
        return len(self.tasks)

    @property
    def total_sojourn(self) -> float:
        return self.totals["total"]

    def top(self, k: int = 10) -> list[TaskBlame]:
        """The ``k`` worst tasks by sojourn (ties broken by ascending uid —
        deterministic), each carrying its own phase split."""
        return sorted(self.tasks.values(),
                      key=lambda b: (-b.sojourn, b.uid))[:k]

    def dominant_contributors(self, k: int = 5) -> list[dict[str, Any]]:
        """The top-K (phase, domain) blame cells: which phase on which
        domain holds the largest share of total sojourn.  Each row carries
        the absolute blame and its share of ``total_sojourn``; ordering is
        blame-descending with (phase, domain) tie-breaks."""
        cells = []
        for domain in sorted(self.by_domain):
            row = self.by_domain[domain]
            for phase in PHASES:
                if row[phase] > 0.0:
                    cells.append({"phase": phase, "domain": domain,
                                  "blame": row[phase],
                                  "share": row[phase]
                                  / max(self.total_sojourn, 1e-12)})
        cells.sort(key=lambda c: (-c["blame"], c["phase"], c["domain"]))
        return cells[:k]

    def snapshot(self) -> dict[str, Any]:
        """JSON-ready summary: totals, per-domain and per-level tables, and
        the dominant-contributor ranking (not the per-task detail)."""
        return {
            "tasks": len(self.tasks),
            "missing": len(self.missing),
            "totals": dict(self.totals),
            "by_domain": {str(d): dict(r)
                          for d, r in sorted(self.by_domain.items())},
            "by_level": {str(lv): dict(r)
                         for lv, r in sorted(self.by_level.items())},
            "dominant": self.dominant_contributors(),
        }


def decompose(trace, topology: Optional[Any] = None) -> BlameReport:
    """Decompose every observed task of ``trace`` (v1–v4) into exact
    queue-wait / steal-transfer / exec blame.

    ``topology`` overrides the header-embedded ``DistanceMatrix`` for
    steal-level pricing; without either, every steal is level 1 (the flat
    machine), matching the executor's own flat accounting.  Tasks whose
    execution event fell out of the ring-buffer window are listed in
    ``missing``, never silently skipped.
    """
    if topology is None and trace.topology_dict is not None:
        from ..topology import DistanceMatrix   # lazy: keep import light
        topology = DistanceMatrix.from_dict(trace.topology_dict)

    submitted = {s.uid: s for s in trace.submissions}
    execs = {}
    for e in trace.events:
        if e.kind in EXEC_KINDS and e.task_uid in submitted:
            execs[e.task_uid] = e

    tasks: dict[int, TaskBlame] = {}
    by_domain: dict[int, dict[str, float]] = {}
    by_level: dict[int, dict[str, float]] = {}
    totals = _zero_row()
    for uid in sorted(execs):
        e, sub = execs[uid], submitted[uid]
        wait = e.step - sub.step            # ints, exact
        stolen = event_stolen(e)
        if stolen:
            level = (topology.level(e.domain, e.src_domain)
                     if topology is not None else 1)
        else:
            level = 0
        blame = TaskBlame(uid=uid, home=sub.home, routed=sub.domain,
                          exec_domain=e.domain, worker=e.worker, level=level,
                          queue_wait=wait, steal_transfer=e.penalty,
                          exec=e.cost)
        tasks[uid] = blame
        dr = by_domain.setdefault(sub.domain, _zero_row())
        dr["queue_wait"] += wait
        de = by_domain.setdefault(e.domain, _zero_row())
        de["steal_transfer"] += e.penalty
        de["exec"] += e.cost
        lr = by_level.setdefault(level, _zero_row())
        lr["queue_wait"] += wait
        lr["steal_transfer"] += e.penalty
        lr["exec"] += e.cost
        lr["total"] += blame.sojourn
        lr["tasks"] += 1
        totals["queue_wait"] += wait
        totals["steal_transfer"] += e.penalty
        totals["exec"] += e.cost
        totals["total"] += blame.sojourn
        totals["tasks"] += 1
    # per-domain totals: the three phase columns that domain was blamed for
    for row in by_domain.values():
        row["total"] = row["queue_wait"] + row["steal_transfer"] + row["exec"]
    for uid in sorted(tasks):
        by_domain[tasks[uid].exec_domain]["tasks"] += 1
    missing = tuple(uid for uid in submitted if uid not in execs)
    return BlameReport(tasks=tasks, missing=missing, by_domain=by_domain,
                       by_level=by_level, totals=totals)
