"""Tying it together: observe a recorded run, or attach to a live build.

``observe(trace)`` is the one-call post-hoc pipeline: span assembly +
metrics registry over any recorded ``repro.trace.Trace`` (v1–v4), producing
an ``ObsReport`` with

  * counters   — tasks submitted/observed/unobserved, steals, remote
                 steals, events dropped by the ring buffer;
  * histograms — wait / sojourn / service / steal-distance, on the
                 registry's fixed log-scale buckets;
  * exact percentiles — nearest-rank p50/p95/p99 of wait, sojourn, and
                 service over the *full* per-task sample (not bucket
                 estimates), the numbers ``BENCH_experiments.json`` exports;
  * the span forest itself, for drill-down and the Perfetto export.

``Observation`` is the live counterpart a spec-built system carries
(``RuntimeSpec.obs.enabled`` → ``Built.obs``): it owns the registry, the
opt-in ``HotPathProfiler`` (``obs.profile``), and a ``report(trace)``
convenience that folds the profiler snapshot into the post-hoc report.
Observation is deliberately *passive* — it changes no scheduling decision,
which is why obs-on and obs-off runs produce bit-identical ``RuntimeStats``
and replays (the invariant ``tests/test_obs.py`` gates per policy).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .metrics import Registry, percentiles
from .profile import HotPathProfiler
from .spans import SpanForest, assemble_spans

PERCENTILE_QS = (50, 95, 99)


@dataclasses.dataclass
class ObsReport:
    """Everything one observation of one run produced (see module doc)."""

    registry: Registry
    spans: SpanForest
    percentiles: dict[str, dict[str, float]]
    profile: Optional[dict] = None

    def snapshot(self) -> dict:
        """One JSON-ready dict: registry metrics + exact percentiles (+ the
        profiler snapshot when the run was profiled)."""
        out = {"metrics": self.registry.snapshot(),
               "percentiles": self.percentiles,
               "tasks_observed": len(self.spans),
               "tasks_unobserved": len(self.spans.missing)}
        if self.profile is not None:
            out["profile"] = self.profile
        return out


def _events_dropped(trace) -> int:
    dropped = getattr(trace, "events_dropped", None)
    if dropped is not None:
        return int(dropped)
    total = sum(trace.event_counts.values()) if trace.event_counts else 0
    return max(total - trace.events_retained, 0)


def observe(trace, *, registry: Optional[Registry] = None,
            topology=None) -> ObsReport:
    """Run the full post-hoc observation pipeline over ``trace``.

    Pass a ``registry`` to accumulate into an existing one (a live
    ``Observation`` does); by default a fresh registry with the standard
    bucket ladder is used.  ``topology`` overrides the header-embedded
    distance matrix for steal level/distance pricing.
    """
    reg = registry if registry is not None else Registry()
    forest = assemble_spans(trace, topology=topology)

    reg.counter("tasks_submitted").inc(len(trace.submissions))
    reg.counter("tasks_observed").inc(len(forest))
    reg.counter("tasks_unobserved").inc(len(forest.missing))
    reg.counter("events_dropped").inc(_events_dropped(trace))

    waits, sojourns, services = [], [], []
    h_wait = reg.histogram("wait")
    h_sojourn = reg.histogram("sojourn")
    h_service = reg.histogram("service")
    h_dist = reg.histogram("steal_distance")
    steals = reg.counter("steals")
    remote = reg.counter("remote_steals")
    for span in forest:
        exec_span = span.children[-1]
        queued = span.children[0]
        wait = queued.duration
        service = exec_span.duration
        waits.append(wait)
        services.append(service)
        sojourns.append(span.duration)
        h_wait.record(wait)
        h_service.record(service)
        h_sojourn.record(span.duration)
        for c in span.children:
            if c.name == "steal":
                steals.inc()
                h_dist.record(c.attrs["distance"])
                if c.attrs["level"] >= 2:
                    remote.inc()

    pct = {}
    if sojourns:
        pct = {"wait": percentiles(waits, PERCENTILE_QS),
               "sojourn": percentiles(sojourns, PERCENTILE_QS),
               "service": percentiles(services, PERCENTILE_QS)}
    return ObsReport(registry=reg, spans=forest, percentiles=pct)


class Observation:
    """The live observation a spec-built system carries (``Built.obs``).

    ``spec`` is the declaring ``repro.spec.ObsSpec`` (any object with
    ``enabled`` / ``profile`` / ``hist_lo`` / ``hist_growth`` /
    ``hist_buckets`` attributes works — the obs package stays import-free
    of the spec layer).  The registry is created up front; the profiler
    only when ``spec.profile`` asks for the timers.
    """

    def __init__(self, spec) -> None:
        self.spec = spec
        self.registry = Registry(hist_lo=spec.hist_lo,
                                 hist_growth=spec.hist_growth,
                                 hist_buckets=spec.hist_buckets)
        self.profiler = HotPathProfiler() if spec.profile else None

    def report(self, trace) -> ObsReport:
        """Post-hoc observation of ``trace`` into this observation's
        registry, with the profiler snapshot attached when profiling."""
        rep = observe(trace, registry=self.registry)
        if self.profiler is not None:
            rep.profile = self.profiler.snapshot()
        return rep
