"""Perfetto / Chrome trace-event export of a recorded run.

``export_chrome_trace(trace, path)`` renders any v1–v4 ``repro.trace.Trace``
as a Chrome trace-event JSON file — open it at https://ui.perfetto.dev (or
``chrome://tracing``) for the interactive form of the paper's Fig. 4
timelines:

  * one *process* track per locality domain, holding
      - one *thread* lane per worker pinned to that domain, carrying the
        execution slices (``run`` / ``steal`` / ``inline``), one slice per
        task, sized by its measured service (cost + penalty) and labelled
        with uid, cost, penalty, and batch grouping;
      - one ``queue`` lane marking steal hand-offs out of this domain's
        queue;
      - a ``queue depth`` counter series (submissions in, executions out)
        — the depth-imbalance picture behind the storm detectors.
  * a *flow arrow* per steal, drawn from the victim domain's queue lane to
    the thief worker's execution slice — cross-domain (and with schema v3+
    topology headers, cross-socket) traffic is visible as arrows crossing
    process tracks.

The step clock maps to trace time as 1 scheduling round = ``step_us``
microseconds (default 1000, so Perfetto's "ms" readout counts rounds).
Within one batch grab the member slices are laid out back-to-back from the
grab's step so they stay individually visible; the step clock, not the
laid-out offset, remains the analytical truth (spans/metrics use it).

Everything is derived from the recorded trace, deterministically: the same
trace always exports byte-identical JSON.
"""
from __future__ import annotations

import json

from .spans import EXEC_KINDS
from ..trace.schema import Trace, event_stolen

_QUEUE_TID_BASE = 1_000_000   # queue lanes sit far above real worker tids


def _worker_domains(trace: Trace) -> list[int]:
    return [int(d) for d in trace.meta.get("worker_domains", [])]


def chrome_trace_events(trace: Trace, *, step_us: int = 1000) -> list[dict]:
    """The trace-event list (see module docstring); ``export_chrome_trace``
    wraps it in the JSON envelope."""
    if step_us < 1:
        raise ValueError("step_us must be >= 1")
    wd = _worker_domains(trace)
    out: list[dict] = []

    # -- metadata: name/sort the domain processes and their lanes ------------
    for d in range(trace.num_domains):
        out.append({"ph": "M", "name": "process_name", "pid": d, "tid": 0,
                    "args": {"name": f"domain {d}"}})
        out.append({"ph": "M", "name": "process_sort_index", "pid": d,
                    "tid": 0, "args": {"sort_index": d}})
        out.append({"ph": "M", "name": "thread_name", "pid": d,
                    "tid": _QUEUE_TID_BASE + d, "args": {"name": "queue"}})
    for wid, d in enumerate(wd):
        out.append({"ph": "M", "name": "thread_name", "pid": d, "tid": wid,
                    "args": {"name": f"worker {wid}"}})

    # -- execution slices, steal flows, queue-depth counters -----------------
    depth = [0] * trace.num_domains
    batch_off: dict[tuple[int, int], float] = {}   # (step, worker) -> offset
    flow_id = 0
    for e in trace.events:
        ts = e.step * step_us
        if e.kind == "submit":
            if 0 <= e.domain < len(depth):
                depth[e.domain] += 1
                out.append({"ph": "C", "name": "queue depth", "pid": e.domain,
                            "tid": 0, "ts": ts,
                            "args": {"tasks": depth[e.domain]}})
            continue
        if e.kind not in EXEC_KINDS:
            continue
        src = e.src_domain if e.src_domain >= 0 else e.domain
        if 0 <= src < len(depth) and depth[src] > 0:
            depth[src] -= 1
            out.append({"ph": "C", "name": "queue depth", "pid": src,
                        "tid": 0, "ts": ts, "args": {"tasks": depth[src]}})
        pid = wd[e.worker] if 0 <= e.worker < len(wd) else e.domain
        key = (e.step, e.worker)
        start = ts + batch_off.get(key, 0.0)
        dur = max(e.service * step_us, 1.0)
        batch_off[key] = batch_off.get(key, 0.0) + dur
        out.append({"ph": "X", "name": f"{e.kind} t{e.task_uid}",
                    "cat": e.kind, "pid": pid, "tid": e.worker,
                    "ts": start, "dur": dur,
                    "args": {"uid": e.task_uid, "cost": e.cost,
                             "penalty": e.penalty, "src_domain": e.src_domain}})
        if event_stolen(e):
            flow_id += 1
            qtid = _QUEUE_TID_BASE + e.src_domain
            out.append({"ph": "i", "name": f"stolen t{e.task_uid}",
                        "cat": "steal", "s": "t", "pid": e.src_domain,
                        "tid": qtid, "ts": ts})
            out.append({"ph": "s", "name": "steal", "cat": "steal",
                        "id": flow_id, "pid": e.src_domain, "tid": qtid,
                        "ts": ts})
            out.append({"ph": "f", "bp": "e", "name": "steal", "cat": "steal",
                        "id": flow_id, "pid": pid, "tid": e.worker,
                        "ts": start})
    return out


def export_chrome_trace(trace: Trace, path, *, step_us: int = 1000):
    """Write ``trace`` as a Chrome trace-event JSON file; returns ``path``.

    The output is a complete Perfetto-loadable artifact: drag it into
    https://ui.perfetto.dev.  Conventionally named ``*.perfetto-trace`` or
    ``*.json``.
    """
    envelope = {
        "traceEvents": chrome_trace_events(trace, step_us=step_us),
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.obs.export_chrome_trace",
            "governor": trace.meta.get("governor", ""),
            "num_domains": trace.num_domains,
            "total_steps": trace.total_steps,
            "step_us": step_us,
        },
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(envelope, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path
