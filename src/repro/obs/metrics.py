"""Deterministic metrics: counters, gauges, fixed-bucket histograms.

Everything here is exact and reproducible — no randomized sketches, no
sampling, no wall-clock dependence — so two observations of the same run
produce byte-identical snapshots (the same property the replay layer
guarantees for ``RuntimeStats``).  Two complementary tools:

  ``Histogram``            — a *fixed-bucket log-scale* histogram: bucket
                             upper bounds form a geometric ladder declared
                             up front (``lo * growth**i``), so memory is
                             bounded (``buckets + 1`` ints) no matter how
                             many values stream in, and the same values
                             always land in the same buckets.  Quantiles
                             from a histogram are *bucket-resolution*
                             estimates: the reported pNN is the upper bound
                             of the bucket holding the nearest-rank sample
                             (conservative — never under-reports), with the
                             observed min/max tightening the first and last
                             buckets.
  ``percentile(s)``        — *exact* nearest-rank percentiles over a full
                             sample list, for the places that retain every
                             value anyway (per-task sojourns in a replay,
                             the simulator's per-trial MLUP/s samples).
                             ``BENCH_experiments.json``'s p50/p95/p99 come
                             from here, not from bucket estimates.

``Registry`` names and owns a flat set of metrics; ``snapshot()`` renders
them as one plain, sorted, JSON-ready dict — the export surface the
benchmarks and ``ObsReport`` serialize.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile (``q`` in [0, 100]) of ``values``.

    Nearest-rank (the smallest value with at least ``q``% of the sample at
    or below it) is deterministic, order-independent, and always returns an
    *observed* value — no interpolation between samples, so p99 of integer
    waits is an integer wait.  Raises on an empty sample.
    """
    if not values:
        raise ValueError("percentile of an empty sample")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    ordered = sorted(values)
    if q == 0.0:
        return ordered[0]
    rank = math.ceil(q / 100.0 * len(ordered))
    return ordered[rank - 1]


def percentiles(values: Sequence[float],
                qs: Iterable[float] = (50, 95, 99)) -> dict[str, float]:
    """Exact nearest-rank percentiles as a ``{"p50": ..., ...}`` dict.

    The standard latency summary exported into ``BENCH_experiments.json``
    and ``ReplayResult.sojourn_percentiles()``.  Keys are ``p`` + the
    percentile with any trailing ``.0`` dropped (``p99.9`` stays ``p99.9``).
    """
    out = {}
    for q in qs:
        label = f"{float(q):g}"
        out[f"p{label}"] = percentile(values, float(q))
    return out


class Counter:
    """A monotone event count."""

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """A point-in-time level (queue depth, current batch size, ...)."""

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket log-scale histogram (see module docstring).

    ``buckets`` finite buckets with upper bounds ``lo * growth**i`` plus one
    overflow bucket; values ``<= lo`` land in bucket 0.  The default ladder
    (0.5 · 2ⁱ, 24 buckets) spans 0.5 .. ~4·10⁶ — wide enough for step-clock
    waits and cost-unit services at any benchmark scale.
    """

    def __init__(self, lo: float = 0.5, growth: float = 2.0,
                 buckets: int = 24):
        if lo <= 0:
            raise ValueError("histogram lo must be > 0")
        if growth <= 1.0:
            raise ValueError("histogram growth must be > 1")
        if buckets < 1:
            raise ValueError("histogram needs at least one bucket")
        self.bounds = tuple(lo * growth ** i for i in range(buckets))
        self.counts = [0] * (buckets + 1)    # + overflow
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        # linear scan beats bisect for the short ladders used here and is
        # trivially deterministic; values above every bound overflow.
        idx = len(self.bounds)
        for i, ub in enumerate(self.bounds):
            if v <= ub:
                idx = i
                break
        self.counts[idx] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile: the upper bound of the bucket holding
        the nearest-rank sample, clamped to the observed [min, max].  Exact
        when a bucket holds one distinct value; otherwise an upper estimate
        no farther off than one bucket's width."""
        if self.count == 0:
            raise ValueError("quantile of an empty histogram")
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"quantile q={q!r} outside [0, 100]")
        rank = max(math.ceil(q / 100.0 * self.count), 1)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                if i >= len(self.bounds):       # overflow bucket
                    return self.vmax
                return min(max(self.bounds[i], self.vmin), self.vmax)
        return self.vmax                         # unreachable

    def nonzero_buckets(self) -> list[list[float]]:
        """``[upper_bound, count]`` pairs for occupied buckets only (the
        overflow bucket reports the observed max as its bound) — the compact
        JSON form of the distribution."""
        out = []
        for i, c in enumerate(self.counts):
            if c:
                ub = self.bounds[i] if i < len(self.bounds) else self.vmax
                out.append([float(ub), int(c)])
        return out

    def snapshot(self):
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.quantile(50),
            "p95": self.quantile(95),
            "p99": self.quantile(99),
            "buckets": self.nonzero_buckets(),
        }


@dataclasses.dataclass(frozen=True)
class _Slot:
    kind: str
    metric: object


class Registry:
    """A named, flat set of metrics with one JSON-ready snapshot.

    ``counter``/``gauge``/``histogram`` create on first use and return the
    existing instrument afterwards; asking for the same name as a different
    kind is a bug and raises.  Histogram bucket parameters are fixed at
    creation (an ``ObsSpec`` declares them once for the whole registry).
    """

    def __init__(self, *, hist_lo: float = 0.5, hist_growth: float = 2.0,
                 hist_buckets: int = 24):
        self.hist_lo = hist_lo
        self.hist_growth = hist_growth
        self.hist_buckets = hist_buckets
        self._slots: dict[str, _Slot] = {}

    def _get(self, name: str, kind: str, factory):
        slot = self._slots.get(name)
        if slot is None:
            slot = _Slot(kind, factory())
            self._slots[name] = slot
        elif slot.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{slot.kind}, not {kind}")
        return slot.metric

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter", Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge", Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram",
                         lambda: Histogram(self.hist_lo, self.hist_growth,
                                           self.hist_buckets))

    def __contains__(self, name: str) -> bool:
        return name in self._slots

    def names(self) -> list[str]:
        return sorted(self._slots)

    def snapshot(self) -> dict:
        """All metrics, sorted by name: ``{name: value-or-dict}`` (counters
        and gauges flatten to their value; histograms to their stat dict)."""
        return {name: self._slots[name].metric.snapshot()
                for name in self.names()}
