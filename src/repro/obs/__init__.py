"""repro.obs — spans, deterministic metrics, timeline export, self-profiling.

The paper's evidence is observational: Fig. 4-style per-thread timelines
and locality counters that show *where* dynamic scheduling breaks page
locality.  Earlier PRs record everything (submission traces, the event
ring buffer, ``RuntimeStats``) but report only aggregates.  This package
is the observability layer over that record — strictly post hoc (or
passively attached), so observation never perturbs the observed schedule:

  paper / ROADMAP concept                obs object
  -------------------------------------  ---------------------------------
  per-task lifecycle (submit → queue →   ``assemble_spans`` → ``Span`` /
  steal → run), Fig. 4 drill-down        ``SpanForest`` — one well-nested
                                         span path per task, steal spans
                                         priced with topology level/distance
  latency distributions, p50/p95/p99     ``Registry`` (counters, gauges,
  as experiment outputs (ROADMAP 3)      fixed-bucket log-scale
                                         ``Histogram``) + exact nearest-rank
                                         ``percentile``/``percentiles``
  interactive Fig. 4 timelines           ``export_chrome_trace`` — Perfetto/
                                         Chrome trace-event JSON with per-
                                         domain tracks and steal flow-arrows
  scheduler cost at production scale     ``HotPathProfiler`` — opt-in
  (ROADMAP 2, ns/decision)               ``perf_counter_ns`` timers around
                                         submit-route / steal-scan /
                                         batch-grab / event-append, fed by
                                         ``Executor(profiler=...)``
  one-call observation                   ``observe(trace)`` → ``ObsReport``;
                                         ``Observation`` is the live form a
                                         spec-built system carries
                                         (``RuntimeSpec.obs`` → ``Built.obs``)
  *why* did time go there — per-task     ``decompose(trace)`` →
  sojourn attribution                    ``BlameReport`` (queue-wait /
                                         steal-transfer / exec, bit-exact)
  "what changed between these runs?"     ``diff_traces(a, b)`` →
                                         ``TraceDiff`` (stats/histogram/
                                         steal-matrix deltas, percentile
                                         shifts with min-effect threshold)
  human-facing regression reports        ``render_blame`` / ``render_diff``
                                         (deterministic markdown; the CI
                                         sentinel's artifact format)

Usage::

    from repro import obs, spec

    built = spec.named("paper_cyclic").build()
    ...                                    # drive built.executor, record
    report = obs.observe(trace)            # spans + histograms + percentiles
    print(report.snapshot()["percentiles"]["sojourn"])
    obs.export_chrome_trace(trace, "run.perfetto-trace")
"""
from .chrome import chrome_trace_events, export_chrome_trace
from .critpath import PHASES, BlameReport, TaskBlame, decompose
from .diff import HistDelta, Shift, TraceDiff, diff_traces
from .metrics import Counter, Gauge, Histogram, Registry, percentile, \
    percentiles
from .observe import ObsReport, Observation, observe
from .profile import PATHS, HotPathProfiler
from .report import markdown_table, render_blame, render_diff
from .spans import Span, SpanForest, assemble_spans, spans_from

__all__ = [
    "chrome_trace_events", "export_chrome_trace",
    "PHASES", "BlameReport", "TaskBlame", "decompose",
    "HistDelta", "Shift", "TraceDiff", "diff_traces",
    "Counter", "Gauge", "Histogram", "Registry", "percentile", "percentiles",
    "ObsReport", "Observation", "observe",
    "PATHS", "HotPathProfiler",
    "markdown_table", "render_blame", "render_diff",
    "Span", "SpanForest", "assemble_spans", "spans_from",
]
