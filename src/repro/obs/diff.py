"""Structured A/B trace comparison: ``diff_traces(a, b)``.

"Controlled vs. uncontrolled" and "flat vs. pods" were, until now, two
JSON files and a pair of eyeballs.  This module makes the comparison one
deterministic function call over any two recorded traces (v1–v4, same or
different system shapes):

  * **stats deltas** — every numeric ``RuntimeStats`` key the two footers
    share, as exact ``(a, b, b−a)`` triples;
  * **per-phase histogram deltas** — the critical-path phases
    (``queue_wait`` / ``steal_transfer`` / ``exec``) plus ``sojourn``
    itself, each accumulated into the registry's *shared fixed log-scale
    buckets* (same ladder on both sides, so a per-bucket count delta is
    meaningful) — where the distribution moved, not just its mean;
  * **steal-matrix deltas** — steal counts by topology level (each trace
    priced by its own header's distance matrix) and by (victim → thief)
    domain pair, as count triples;
  * **exact percentile shifts** — nearest-rank p50/p95/p99 of wait /
    sojourn / service on each side, with a *deterministic min-effect
    threshold*: a shift is flagged ``significant`` only when it clears
    ``max(min_abs, min_rel · |a|)``, so step-quantization noise does not
    read as a regression.

Everything is pure post-processing of the two traces: no randomness, no
wall clock, and ``diff_traces(t, t)`` is all-zero by construction (the
property ``tests/test_analytics.py`` gates per registry policy).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from ..trace.schema import event_stolen
from .critpath import decompose
from .metrics import Histogram
from .observe import PERCENTILE_QS, observe

# ``Registry``'s standard ladder — both sides of every phase histogram use
# exactly these buckets, which is what makes per-bucket deltas comparable.
HIST_LO, HIST_GROWTH, HIST_BUCKETS = 0.5, 2.0, 24

DIFF_PHASES = ("queue_wait", "steal_transfer", "exec", "sojourn")
PCT_METRICS = ("wait", "sojourn", "service")

# min-effect defaults: half a scheduling round absolute, 2% relative —
# below both, a percentile shift is reported but not significant.
MIN_ABS = 0.5
MIN_REL = 0.02


@dataclasses.dataclass(frozen=True)
class Shift:
    """One exact before/after pair with its delta and significance."""

    a: float
    b: float
    significant: bool = True

    @property
    def delta(self) -> float:
        return self.b - self.a

    def as_tuple(self) -> tuple[float, float, float]:
        return (self.a, self.b, self.delta)


@dataclasses.dataclass(frozen=True)
class HistDelta:
    """Per-bucket count deltas of one phase, on the shared fixed ladder.

    ``buckets`` lists ``[upper_bound, count_a, count_b, count_b - count_a]``
    for every bucket occupied on either side (ascending bound; the overflow
    bucket reports ``inf``).  ``count_a``/``count_b`` are the sample sizes;
    ``mean_a``/``mean_b`` the exact means.
    """

    buckets: tuple[tuple[float, int, int, int], ...]
    count_a: int
    count_b: int
    mean_a: float
    mean_b: float

    @property
    def is_zero(self) -> bool:
        return all(d == 0 for _, _, _, d in self.buckets) \
            and self.count_a == self.count_b

    @property
    def moved(self) -> int:
        """Total per-bucket movement: half the sum of absolute count deltas
        (each relocated sample leaves one bucket and enters another)."""
        return sum(abs(d) for _, _, _, d in self.buckets) // 2


@dataclasses.dataclass
class TraceDiff:
    """The full structured comparison of two traces (B − A everywhere)."""

    stats: dict[str, Shift]
    phases: dict[str, HistDelta]
    steal_levels: dict[int, Shift]
    steal_matrix: dict[tuple[int, int], Shift]
    percentile_shifts: dict[str, dict[str, Shift]]
    tasks: Shift
    min_abs: float
    min_rel: float

    @property
    def is_zero(self) -> bool:
        """True when *every* recorded delta is exactly zero — the
        self-diff invariant (``diff_traces(t, t).is_zero``)."""
        return (all(s.delta == 0 for s in self.stats.values())
                and all(h.is_zero for h in self.phases.values())
                and all(s.delta == 0 for s in self.steal_levels.values())
                and all(s.delta == 0 for s in self.steal_matrix.values())
                and all(s.delta == 0 for d in self.percentile_shifts.values()
                        for s in d.values())
                and self.tasks.delta == 0)

    def significant_shifts(self) -> dict[str, dict[str, Shift]]:
        """Only the percentile shifts that clear the min-effect threshold,
        metric-keyed — the headline of an A/B report."""
        out: dict[str, dict[str, Shift]] = {}
        for metric, qs in self.percentile_shifts.items():
            kept = {q: s for q, s in qs.items() if s.significant}
            if kept:
                out[metric] = kept
        return out

    def snapshot(self) -> dict[str, Any]:
        """One JSON-ready dict of the whole comparison."""
        return {
            "stats": {k: list(s.as_tuple())
                      for k, s in sorted(self.stats.items())},
            "phases": {p: {"count_a": h.count_a, "count_b": h.count_b,
                           "mean_a": h.mean_a, "mean_b": h.mean_b,
                           "moved": h.moved,
                           "buckets": [list(b) for b in h.buckets]}
                       for p, h in self.phases.items()},
            "steal_levels": {str(lv): list(s.as_tuple())
                             for lv, s in sorted(self.steal_levels.items())},
            "steal_matrix": {f"{src}->{dst}": list(s.as_tuple())
                             for (src, dst), s
                             in sorted(self.steal_matrix.items())},
            "percentiles": {m: {q: {"a": s.a, "b": s.b, "delta": s.delta,
                                    "significant": s.significant}
                                for q, s in qs.items()}
                            for m, qs in self.percentile_shifts.items()},
            "tasks": list(self.tasks.as_tuple()),
            "is_zero": self.is_zero,
        }


def _phase_samples(trace, topology=None) -> dict[str, list[float]]:
    """Per-task phase durations in ascending uid order (critpath exactness
    carries over: the sojourn sample is wait + (cost + penalty))."""
    rep = decompose(trace, topology=topology)
    out: dict[str, list[float]] = {p: [] for p in DIFF_PHASES}
    for uid in sorted(rep.tasks):
        b = rep.tasks[uid]
        out["queue_wait"].append(b.queue_wait)
        out["steal_transfer"].append(b.steal_transfer)
        out["exec"].append(b.exec)
        out["sojourn"].append(b.sojourn)
    return out


def _hist(values) -> Histogram:
    h = Histogram(HIST_LO, HIST_GROWTH, HIST_BUCKETS)
    h.record_many(values)
    return h


def _hist_delta(va: list[float], vb: list[float]) -> HistDelta:
    ha, hb = _hist(va), _hist(vb)
    rows = []
    for i in range(len(ha.counts)):
        ca, cb = ha.counts[i], hb.counts[i]
        if ca or cb:
            ub = ha.bounds[i] if i < len(ha.bounds) else float("inf")
            rows.append((ub, ca, cb, cb - ca))
    return HistDelta(buckets=tuple(rows), count_a=ha.count, count_b=hb.count,
                     mean_a=ha.mean, mean_b=hb.mean)


def _steal_counts(trace) -> tuple[dict[int, int], dict[tuple[int, int], int]]:
    """Steals by topology level and by (victim, thief) domain pair, priced
    by the trace's own header topology (flat traces: all level 1)."""
    topology = None
    if trace.topology_dict is not None:
        from ..topology import DistanceMatrix   # lazy: keep import light
        topology = DistanceMatrix.from_dict(trace.topology_dict)
    levels: dict[int, int] = {}
    matrix: dict[tuple[int, int], int] = {}
    for e in trace.events:
        if event_stolen(e):
            lv = (topology.level(e.domain, e.src_domain)
                  if topology is not None else 1)
            levels[lv] = levels.get(lv, 0) + 1
            key = (e.src_domain, e.domain)
            matrix[key] = matrix.get(key, 0) + 1
    return levels, matrix


def _shift(a: float, b: float, min_abs: float, min_rel: float) -> Shift:
    sig = abs(b - a) >= max(min_abs, min_rel * abs(a))
    return Shift(a=a, b=b, significant=sig)


def diff_traces(a, b, *, min_abs: float = MIN_ABS,
                min_rel: float = MIN_REL,
                topology_a: Optional[Any] = None,
                topology_b: Optional[Any] = None) -> TraceDiff:
    """Structured comparison of two recorded traces (B − A).

    The traces may come from different systems (different policies, domain
    counts, topologies): stats keys are intersected, steal levels/pairs are
    unioned, and each side's steals are priced by its own topology.
    ``min_abs``/``min_rel`` set the deterministic min-effect threshold for
    percentile-shift significance (absolute steps / fraction of the A
    value).  ``topology_a``/``topology_b`` override the header matrices.
    """
    # footer stats: exact numeric deltas on the shared keys
    stats: dict[str, Shift] = {}
    for key in sorted(set(a.stats) & set(b.stats)):
        va, vb = a.stats[key], b.stats[key]
        if isinstance(va, (int, float)) and isinstance(vb, (int, float)):
            stats[key] = Shift(a=float(va), b=float(vb))

    # per-phase histogram deltas on the shared fixed ladder
    pa = _phase_samples(a, topology=topology_a)
    pb = _phase_samples(b, topology=topology_b)
    phases = {p: _hist_delta(pa[p], pb[p]) for p in DIFF_PHASES}

    # steal matrices by level and by (victim -> thief) pair
    la, ma = _steal_counts(a)
    lb, mb = _steal_counts(b)
    steal_levels = {lv: Shift(a=float(la.get(lv, 0)), b=float(lb.get(lv, 0)))
                    for lv in sorted(set(la) | set(lb))}
    steal_matrix = {k: Shift(a=float(ma.get(k, 0)), b=float(mb.get(k, 0)))
                    for k in sorted(set(ma) | set(mb))}

    # exact percentile shifts with the min-effect threshold
    obs_a, obs_b = observe(a, topology=topology_a), \
        observe(b, topology=topology_b)
    shifts: dict[str, dict[str, Shift]] = {}
    for metric in PCT_METRICS:
        qa = obs_a.percentiles.get(metric)
        qb = obs_b.percentiles.get(metric)
        if qa is None or qb is None:
            continue
        shifts[metric] = {q: _shift(qa[q], qb[q], min_abs, min_rel)
                          for q in (f"p{p:g}" for p in PERCENTILE_QS)}

    tasks = Shift(a=float(len(pa["sojourn"])), b=float(len(pb["sojourn"])))
    return TraceDiff(stats=stats, phases=phases, steal_levels=steal_levels,
                     steal_matrix=steal_matrix, percentile_shifts=shifts,
                     tasks=tasks, min_abs=min_abs, min_rel=min_rel)
