"""Render blame tables and trace diffs as markdown/text reports.

``critpath`` and ``diff`` produce structured data; this module turns them
into the human-facing artifacts the CI sentinel uploads and ``benchmarks/
run.py --compare`` prints.  Rendering is deliberately dumb — fixed column
orders, ``%g`` number formatting, no wall-clock or environment input — so
the same report input always yields the same bytes (the reports diff
cleanly across CI runs, like every other artifact in this repo).
"""
from __future__ import annotations

from typing import Any, Iterable, Sequence

from .critpath import PHASES, BlameReport
from .diff import TraceDiff


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:g}"
    return str(v)


def markdown_table(headers: Sequence[str],
                   rows: Iterable[Sequence[Any]]) -> str:
    """A GitHub-flavored markdown table (no column padding games — plain
    pipes render everywhere and keep the bytes deterministic)."""
    out = ["| " + " | ".join(str(h) for h in headers) + " |",
           "|" + "|".join(" --- " for _ in headers) + "|"]
    for row in rows:
        out.append("| " + " | ".join(_fmt(c) for c in row) + " |")
    return "\n".join(out)


def render_blame(report: BlameReport, k: int = 10,
                 title: str = "Critical-path blame") -> str:
    """Markdown report of one trace's sojourn attribution: phase totals,
    per-domain and per-level blame tables, dominant contributors, and the
    ``k`` worst tasks with their own phase splits."""
    t = report.totals
    lines = [f"## {title}", "",
             f"{int(t['tasks'])} tasks observed"
             + (f" ({len(report.missing)} outside the event window)"
                if report.missing else "")
             + f", total sojourn {t['total']:g} steps:", "",
             markdown_table(
                 ["phase", "blame (steps)", "share"],
                 [[p, t[p], f"{t[p] / max(t['total'], 1e-12):.1%}"]
                  for p in PHASES]),
             "", "### By domain",
             "(queue-wait charged to the routed queue; transfer/exec to "
             "the executing domain)", "",
             markdown_table(
                 ["domain", "queue_wait", "steal_transfer", "exec", "total",
                  "tasks"],
                 [[d, r["queue_wait"], r["steal_transfer"], r["exec"],
                   r["total"], int(r["tasks"])]
                  for d, r in sorted(report.by_domain.items())]),
             "", "### By topology level",
             "(level 0 = executed local; level 2+ crossed a socket/pod)",
             "",
             markdown_table(
                 ["level", "queue_wait", "steal_transfer", "exec", "total",
                  "tasks"],
                 [[lv, r["queue_wait"], r["steal_transfer"], r["exec"],
                   r["total"], int(r["tasks"])]
                  for lv, r in sorted(report.by_level.items())]),
             "", "### Dominant contributors", "",
             markdown_table(
                 ["rank", "phase", "domain", "blame", "share"],
                 [[i + 1, c["phase"], c["domain"], c["blame"],
                   f"{c['share']:.1%}"]
                  for i, c in enumerate(report.dominant_contributors(k))]),
             "", f"### Top {k} tasks by sojourn", "",
             markdown_table(
                 ["uid", "sojourn", "dominant", "queue_wait",
                  "steal_transfer", "exec", "routed", "exec_domain",
                  "level"],
                 [[b.uid, b.sojourn, b.dominant, b.queue_wait,
                   b.steal_transfer, b.exec, b.routed, b.exec_domain,
                   b.level]
                  for b in report.top(k)])]
    return "\n".join(lines) + "\n"


def render_diff(diff: TraceDiff, label_a: str = "A",
                label_b: str = "B",
                title: str = "Trace diff") -> str:
    """Markdown report of a ``diff_traces`` comparison: headline verdict,
    significant percentile shifts, stats deltas, per-phase distribution
    movement, and steal-matrix movement by level."""
    lines = [f"## {title}: {label_a} vs {label_b}", ""]
    if diff.is_zero:
        lines += ["**Identical**: every recorded delta is exactly zero.",
                  ""]
    sig = diff.significant_shifts()
    lines += [f"Tasks observed: {diff.tasks.a:g} -> {diff.tasks.b:g}.",
              "", "### Percentile shifts (exact nearest-rank; significant "
              f"at >= max({diff.min_abs:g} steps, {diff.min_rel:.0%}))", "",
              markdown_table(
                  ["metric", "q", label_a, label_b, "delta", "significant"],
                  [[m, q, s.a, s.b, f"{s.delta:+g}",
                    "yes" if s.significant else "no"]
                   for m, qs in diff.percentile_shifts.items()
                   for q, s in qs.items()])]
    if not diff.percentile_shifts:
        lines.append("(no observed tasks on one side — no percentiles)")
    lines += ["",
              f"{sum(len(v) for v in sig.values())} significant shift(s).",
              "", "### RuntimeStats deltas", "",
              markdown_table(
                  ["stat", label_a, label_b, "delta"],
                  [[k, s.a, s.b, f"{s.delta:+g}"]
                   for k, s in sorted(diff.stats.items())
                   if s.delta != 0] or [["(all equal)", "", "", ""]]),
              "", "### Phase distribution movement (shared fixed buckets)",
              "",
              markdown_table(
                  ["phase", f"n {label_a}", f"n {label_b}",
                   f"mean {label_a}", f"mean {label_b}", "samples moved"],
                  [[p, h.count_a, h.count_b, h.mean_a, h.mean_b, h.moved]
                   for p, h in diff.phases.items()]),
              "", "### Steals by topology level", "",
              markdown_table(
                  ["level", label_a, label_b, "delta"],
                  [[lv, int(s.a), int(s.b), f"{s.delta:+g}"]
                   for lv, s in sorted(diff.steal_levels.items())]
                  or [["(no steals)", "", "", ""]])]
    moved = [((src, dst), s) for (src, dst), s
             in sorted(diff.steal_matrix.items()) if s.delta != 0]
    if moved:
        lines += ["", "### Steal matrix movement (victim -> thief)", "",
                  markdown_table(
                      ["link", label_a, label_b, "delta"],
                      [[f"{src}->{dst}", int(s.a), int(s.b),
                        f"{s.delta:+g}"] for (src, dst), s in moved])]
    return "\n".join(lines) + "\n"
