"""Per-task lifecycle spans, reconstructed from the event stream.

The runtime already records everything a per-task timeline needs — the
submission trace carries each arrival (uid, step, home, cost, routed
domain) and the event log carries each execution decision (step, worker,
kind, victim queue, cost, penalty).  This module folds the two into one
*span tree per task*, purely post hoc: nothing here touches the hot path,
and observing a recorded run twice yields identical trees.

Each task's root span covers its whole sojourn and nests a well-ordered
child path::

    task #uid  [submit_step .. exec_step + service]
      queued   [submit_step .. exec_step]        the wait in its routed queue
      steal    [exec_step]                       only when taken from a
                                                 foreign queue: victim,
                                                 thief, topology level,
                                                 link distance, penalty paid
      exec     [exec_step .. exec_step + service] the execution itself
                                                 (``kind`` attr: run /
                                                 steal / inline), with batch
                                                 grouping attached (grab
                                                 size + index within the
                                                 grab)

Well-nestedness (children ordered, non-overlapping, inside the parent) and
one-path-per-task are load-bearing invariants — the hypothesis property
tests in ``tests/test_obs.py`` gate them.

Only tasks whose execution event is still inside the (ring-buffered) event
window get a span; ``assemble_spans`` also returns the uids it could not
reconstruct so a truncated window is never mistaken for an idle scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Optional, Sequence

from ..runtime import Event
from ..trace.schema import event_stolen

EXEC_KINDS = ("run", "steal", "inline")


@dataclasses.dataclass(frozen=True)
class Span:
    """One named interval on the step clock, with attributes and children.

    ``start``/``end`` are in scheduling rounds (the run's only clock);
    instantaneous markers (a steal hand-off) have ``start == end``.
    """

    name: str
    start: float
    end: float
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    children: tuple["Span", ...] = ()

    @property
    def duration(self) -> float:
        return self.end - self.start

    def well_nested(self) -> bool:
        """True when every child lies inside this span, children are
        ordered by start and do not overlap, and each child is itself
        well-nested."""
        prev_end = self.start
        for c in self.children:
            if c.start < prev_end or c.end > self.end or c.end < c.start:
                return False
            if not c.well_nested():
                return False
            prev_end = max(prev_end, c.start)
        return True

    def walk(self) -> Iterable["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()


@dataclasses.dataclass(frozen=True)
class SpanForest:
    """All reconstructed task spans of one run.

    ``spans`` maps uid -> root span; ``missing`` lists submitted uids whose
    execution event was not in the event window (dropped by the ring buffer
    or simply never executed before the trace was cut).
    """

    spans: dict[int, Span]
    missing: tuple[int, ...]

    def __len__(self) -> int:
        return len(self.spans)

    def __getitem__(self, uid: int) -> Span:
        return self.spans[uid]

    def __iter__(self) -> Iterable[Span]:
        return iter(self.spans.values())


def _batch_positions(events: Sequence[Event]) -> dict[int, tuple[int, int]]:
    """uid -> (batch_index, batch_size) from execution-event adjacency.

    A batch grab executes its tasks back-to-back on one worker within one
    step, so consecutive execution events sharing ``(step, worker)`` in
    stream order are one grab.  Single-task grabs get (0, 1).
    """
    groups: dict[tuple[int, int], list[int]] = {}
    for e in events:
        if e.kind in EXEC_KINDS and e.task_uid >= 0:
            groups.setdefault((e.step, e.worker), []).append(e.task_uid)
    out: dict[int, tuple[int, int]] = {}
    for uids in groups.values():
        for i, uid in enumerate(uids):
            out[uid] = (i, len(uids))
    return out


def spans_from(submissions, events: Sequence[Event],
               topology=None) -> SpanForest:
    """Assemble the span forest from raw submissions + events.

    ``submissions`` is any iterable of submission records (``uid``,
    ``step``, ``home``, ``cost``, ``domain`` attributes — the trace's
    ``SubmissionRecord``).  ``topology`` (a ``repro.topology
    .DistanceMatrix``) prices each steal's level/distance; without one the
    flat machine's level 1 / distance 1.0 is reported, matching the
    executor's own flat accounting.
    """
    events = list(events)
    submitted = {s.uid: s for s in submissions}
    batch_pos = _batch_positions(events)
    spans: dict[int, Span] = {}
    for e in events:
        if e.kind not in EXEC_KINDS or e.task_uid not in submitted:
            continue
        sub = submitted[e.task_uid]
        start, exec_step = float(sub.step), float(e.step)
        end = exec_step + e.service
        children = [Span("queued", start, exec_step,
                         attrs={"domain": sub.domain})]
        if event_stolen(e):
            if topology is not None:
                level = topology.level(e.domain, e.src_domain)
                distance = topology.distance(e.domain, e.src_domain)
            else:
                level, distance = 1, 1.0
            children.append(Span("steal", exec_step, exec_step, attrs={
                "src_domain": e.src_domain, "domain": e.domain,
                "level": level, "distance": distance,
                "penalty": e.penalty}))
        bi, bs = batch_pos.get(e.task_uid, (0, 1))
        children.append(Span("exec", exec_step, end, attrs={
            "kind": e.kind, "worker": e.worker, "domain": e.domain,
            "cost": e.cost, "penalty": e.penalty, "batch_index": bi,
            "batch_size": bs}))
        spans[e.task_uid] = Span("task", start, end, attrs={
            "uid": e.task_uid, "home": sub.home, "cost": sub.cost,
            "routed": sub.domain}, children=tuple(children))
    missing = tuple(uid for uid in submitted if uid not in spans)
    return SpanForest(spans=spans, missing=missing)


def assemble_spans(trace, topology: Optional[Any] = None) -> SpanForest:
    """Assemble per-task spans from a recorded ``repro.trace.Trace``.

    Uses the distance matrix embedded in a schema-v3+ header (so steal
    spans carry the exact level/distance the executor charged) unless an
    explicit ``topology`` is passed; v1/v2 and flat traces report the flat
    level-1 accounting.
    """
    if topology is None and trace.topology_dict is not None:
        from ..topology import DistanceMatrix     # lazy: keep import light
        topology = DistanceMatrix.from_dict(trace.topology_dict)
    return spans_from(trace.submissions, trace.events, topology=topology)
