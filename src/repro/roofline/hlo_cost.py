"""Loop-aware cost analysis of compiled (partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers model (i.e. every model here) is undercounted by the trip
count — flops, bytes AND collectives.  This module re-derives the three
roofline inputs by walking the HLO computation graph:

  * builds a per-computation symbol table (name -> shape),
  * costs each op (dot flops from contracting dims; memory bytes at fusion
    boundaries: operands + results; collective wire bytes by kind),
  * recurses into called computations: ``while`` multiplies its body cost by
    the trip count parsed from the loop condition's ``compare(%iv, const)``,
    fusions contribute their root dots but only boundary bytes, and
    ``conditional`` takes the max across branches.

Validated against hand-counted matmul/scan cases in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^=]*\)|[\w\[\],\{\} ]+?))\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|^%?([\w\.\-]+)\s*\{\s*$")

_COLLECTIVES = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
    "ragged-all-to-all": ("operand", 1.0),
}

_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "logistic", "cosine", "sine", "exponential-minus-one",
                   "log-plus-one"}

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done", "opt-barrier"}


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), (tuple(int(d) for d in m.group(2).split(",") if d)
                        if m.group(2) else ())


def _all_shapes_bytes(text: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape) -> int:
    n = 1
    for d in shape[1]:
        n *= d
    return n


def _shape_bytes(shape) -> float:
    return _shape_elems(shape) * _DTYPE_BYTES.get(shape[0], 0)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult


@dataclasses.dataclass
class _Instr:
    name: str
    shape_text: str          # result shape text (may be a tuple)
    op: str
    args_text: str           # everything after the opening paren
    operands: list[str]


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[str, Cost] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: list[_Instr] | None = None
        cur_name = None
        for raw in text.splitlines():
            line = re.sub(r"/\*.*?\*/", "", raw).rstrip()   # strip /*index=N*/
            stripped = line.strip()
            if not stripped or stripped.startswith("//"):
                continue
            if stripped.startswith("HloModule"):
                continue
            # computation header: "%name (args) -> type {" or "ENTRY %name ..."
            is_def = re.match(r"^(ROOT\s+)?%[\w\.\-]+\s*=\s*", stripped)
            if stripped.endswith("{") and not is_def:
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", stripped)
                if m:
                    cur_name = m.group(2)
                    cur = []
                    self.computations[cur_name] = cur
                    if m.group(1):
                        self.entry = cur_name
                continue
            if stripped == "}" or stripped.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            dm = _DEF_RE.match(stripped)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            om = _OP_RE.match(rhs)
            if not om:
                continue
            shape_text, op, rest = om.group(1), om.group(2), om.group(3)
            # operands: %names up to closing paren at depth 0
            depth = 1
            args_end = len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args_end = i
                        break
            args = rest[:args_end]
            operands = re.findall(r"%([\w\.\-]+)", args)
            cur.append(_Instr(name=name, shape_text=shape_text.strip(), op=op,
                              args_text=rest, operands=operands))

    # -- costing ---------------------------------------------------------------
    def _symtab(self, comp: str) -> dict[str, str]:
        return {i.name: i.shape_text for i in self.computations.get(comp, [])}

    def _trip_count(self, cond_comp: str) -> float:
        """Parse `compare(%iv, %bound), direction=LT` with const bound."""
        instrs = self.computations.get(cond_comp, [])
        consts: dict[str, float] = {}
        for i in instrs:
            if i.op == "constant":
                m = re.search(r"constant\((-?[\d\.e\+]+)\)", "constant(" + i.args_text)
                if m:
                    try:
                        consts[i.name] = float(m.group(1))
                    except ValueError:
                        pass
        for i in instrs:
            if i.op == "compare" and "direction=LT" in i.args_text:
                for opnd in i.operands:
                    if opnd in consts:
                        return max(consts[opnd], 1.0)
        return 1.0

    def cost_of(self, comp: str, count_bytes: bool = True) -> Cost:
        key = f"{comp}|{count_bytes}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symtab = self._symtab(comp)
        for ins in self.computations.get(comp, []):
            total.add(self._instr_cost(ins, symtab, count_bytes))
        self._memo[key] = total
        return total

    def _called(self, ins: _Instr, attr: str) -> str | None:
        m = re.search(attr + r"=%?([\w\.\-]+)", ins.args_text)
        return m.group(1) if m else None

    def _fusion_result_bytes(self, called: str | None, ins: _Instr) -> float:
        """Write bytes of a fusion: full result, EXCEPT dynamic-update-slice
        roots, which write only the update region (the buffer is aliased —
        the scan-accumulator pattern)."""
        full = _all_shapes_bytes(ins.shape_text)
        if called is None or called not in self.computations:
            return full
        comp = self.computations[called]
        if not comp:
            return full
        sym = {i.name: i for i in comp}
        root = comp[-1]
        roots = [root]
        if root.op == "tuple":
            roots = [sym[o] for o in root.operands if o in sym]
        total = 0.0
        for r in roots:
            if r.op == "dynamic-update-slice" and len(r.operands) > 1:
                upd = sym.get(r.operands[1])
                total += _all_shapes_bytes(upd.shape_text) if upd else 0.0
            else:
                total += _all_shapes_bytes(r.shape_text)
        return min(total, full)

    def _fusion_operand_bytes(self, called: str | None, ins: _Instr,
                              symtab: dict[str, str]) -> float:
        """Bytes read by a fusion: operands charged in full, EXCEPT operands
        whose every in-fusion use is a (dynamic-)slice — those read only the
        sliced region (the scan-over-layers weight/activation slices)."""
        operands = list(dict.fromkeys(ins.operands))   # unique, ordered-ish
        if called is None or called not in self.computations:
            return sum(_all_shapes_bytes(symtab.get(o, "")) for o in operands)
        comp = self.computations[called]
        # param index -> param instr name
        param_by_idx: dict[int, str] = {}
        for i in comp:
            if i.op == "parameter":
                m = re.match(r"\s*(\d+)", i.args_text)
                if m:
                    param_by_idx[int(m.group(1))] = i.name
        # users map (following bitcasts)
        users: dict[str, list[_Instr]] = defaultdict(list)
        for i in comp:
            for o in i.operands:
                users[o].append(i)

        def sliced_bytes(pname: str) -> float | None:
            """Total read bytes if every use of pname is a slice; else None."""
            total = 0.0
            stack = [pname]
            seen = set()
            while stack:
                n = stack.pop()
                if n in seen:
                    continue
                seen.add(n)
                for u in users.get(n, []):
                    if u.op in ("bitcast", "reshape", "copy", "transpose",
                                "convert"):
                        stack.append(u.name)
                    elif u.op in ("dynamic-slice", "slice", "gather"):
                        total += _all_shapes_bytes(u.shape_text)
                    elif u.op == "dynamic-update-slice" and u.operands and \
                            u.operands[0] == n:
                        pass     # aliased in-place target: no read traffic
                    else:
                        return None
            return total

        # fusion operand order == parameter index order
        total = 0.0
        for idx, opnd in enumerate(ins.operands):
            pname = param_by_idx.get(idx)
            full = _all_shapes_bytes(symtab.get(opnd, ""))
            if pname is None:
                total += full
                continue
            sb = sliced_bytes(pname)
            total += full if sb is None else min(sb, full)
        return total

    def _instr_cost(self, ins: _Instr, symtab: dict[str, str],
                    count_bytes: bool) -> Cost:
        c = Cost()
        op = ins.op
        base = op[:-6] if op.endswith("-start") else op
        if op in _FREE_OPS or op.endswith("-done"):
            return c

        # collectives
        if base in _COLLECTIVES:
            side, weight = _COLLECTIVES[base]
            if side == "result":
                nbytes = _all_shapes_bytes(ins.shape_text)
            else:
                nbytes = sum(_all_shapes_bytes(symtab.get(o, ""))
                             for o in ins.operands)
            c.coll[base] += nbytes * weight
            if count_bytes:
                c.bytes += _all_shapes_bytes(ins.shape_text)
            return c

        if op == "while":
            body = self._called(ins, "body")
            cond = self._called(ins, "condition")
            m = re.search(r'known_trip_count[^\d]*(\d+)', ins.args_text)
            if m:
                trips = float(m.group(1))
            else:
                trips = self._trip_count(cond) if cond else 1.0
            if body:
                c.add(self.cost_of(body, count_bytes=count_bytes), trips)
            if cond:
                c.add(self.cost_of(cond, count_bytes=False), trips)
            return c

        if op == "fusion":
            called = self._called(ins, "calls")
            if called:
                inner = self.cost_of(called, count_bytes=False)  # bytes at boundary
                c.add(inner)
            if count_bytes:
                c.bytes += self._fusion_result_bytes(called, ins)
                c.bytes += self._fusion_operand_bytes(called, ins, symtab)
            return c

        if op in ("call", "async-start"):
            called = self._called(ins, "calls") or self._called(ins, "to_apply")
            if called:
                c.add(self.cost_of(called, count_bytes=count_bytes))
            return c

        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.args_text)
            names = []
            if branches:
                names = re.findall(r"%?([\w\.\-]+)", branches[0])
            else:
                for attr in ("true_computation", "false_computation"):
                    n = self._called(ins, attr)
                    if n:
                        names.append(n)
            if names:
                worst = Cost()
                for n in names:
                    bc = self.cost_of(n, count_bytes=count_bytes)
                    if bc.flops + bc.bytes >= worst.flops + worst.bytes:
                        worst = bc
                c.add(worst)
            return c

        # dot: flops = 2 * prod(result) * prod(lhs contracting dims)
        if op == "dot":
            res = _first_shape(ins.shape_text)
            lhs_shape = _first_shape(symtab.get(ins.operands[0], "")) if ins.operands else None
            k = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.args_text)
            if m and lhs_shape:
                for d in m.group(1).split(","):
                    if d:
                        k *= lhs_shape[1][int(d)]
            if res:
                c.flops += 2.0 * _shape_elems(res) * k
        elif op == "convolution":
            # not used by the zoo's jnp paths; approximate via output*1
            res = _first_shape(ins.shape_text)
            if res:
                c.flops += 2.0 * _shape_elems(res)
        elif op in ("reduce", "reduce-window", "add", "multiply", "subtract",
                    "divide", "maximum", "minimum", "select", "compare",
                    "convert", "negate", "abs", "and", "or", "xor", "clamp"):
            res = _first_shape(ins.shape_text)
            if res:
                c.flops += float(_shape_elems(res))
        elif op in _TRANSCENDENTAL:
            res = _first_shape(ins.shape_text)
            if res:
                c.flops += 4.0 * _shape_elems(res)

        if count_bytes and op not in ("tuple",):
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, writes the result
                c.bytes += 2.0 * _all_shapes_bytes(ins.shape_text)
            elif op == "dynamic-update-slice":
                # reads the update, writes the region (buffer is aliased)
                upd = symtab.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
                c.bytes += 2.0 * _all_shapes_bytes(upd)
            elif op in ("scatter",):
                upd = symtab.get(ins.operands[-1], "") if ins.operands else ""
                c.bytes += 2.0 * _all_shapes_bytes(upd)
            else:
                c.bytes += _all_shapes_bytes(ins.shape_text)
                c.bytes += sum(_all_shapes_bytes(symtab.get(o, ""))
                               for o in set(ins.operands))
        return c

    def total(self) -> Cost:
        entry = self.entry
        if entry is None:
            # fall back: the computation named main-ish or the largest
            entry = max(self.computations, key=lambda k: len(self.computations[k]))
        return self.cost_of(entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloModule(hlo_text).total()


def top_instructions(mod: HloModule, n: int = 20):
    """Debug: (bytes*mult, flops*mult, mult, comp, op, name) heaviest ops."""
    rows = []

    def walk(comp: str, mult: float, depth: int):
        if depth > 12:
            return
        symtab = mod._symtab(comp)
        for ins in mod.computations.get(comp, []):
            if ins.op == "while":
                body = mod._called(ins, "body")
                m = re.search(r"known_trip_count[^\d]*(\d+)", ins.args_text)
                trips = float(m.group(1)) if m else 1.0
                if body:
                    walk(body, mult * trips, depth + 1)
            elif ins.op in ("call", "async-start"):
                callee = mod._called(ins, "calls") or mod._called(ins, "to_apply")
                if callee:
                    walk(callee, mult, depth + 1)
            else:
                c = mod._instr_cost(ins, symtab, True)
                rows.append((c.bytes * mult, c.flops * mult, mult, comp,
                             ins.op, ins.name))

    entry = mod.entry or max(mod.computations,
                             key=lambda k: len(mod.computations[k]))
    walk(entry, 1.0, 0)
    rows.sort(reverse=True)
    return rows[:n]
