"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

``compiled.cost_analysis()`` reports the *partitioned* (per-device) module,
so its flops/bytes are already per-chip (verified against a hand-counted
matmul in tests/test_roofline.py).  Collective bytes are not in
cost_analysis; they are parsed from the partitioned HLO text — per
collective kind the wire volume per device is approximately:

    all-gather          result bytes          (receive volume)
    reduce-scatter      operand bytes         (send volume)
    all-reduce          2 x operand bytes     (reduce-scatter + all-gather)
    all-to-all          operand bytes
    collective-permute  operand bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_WEIGHTS = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
    "ragged-all-to-all": ("operand", 1.0),
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes by collective kind, from partitioned HLO text."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVE_WEIGHTS}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition(" = ")
        m = re.match(r"(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)(?:-start)?\(", rhs)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[:-6]
        if op not in _COLLECTIVE_WEIGHTS:
            continue
        side, weight = _COLLECTIVE_WEIGHTS[op]
        if side == "result":
            result_part = rhs.split(op)[0]
            nbytes = _shape_bytes(result_part)
        else:
            args_part = rhs[rhs.index("("):]
            nbytes = _shape_bytes(args_part)
        out[op] += nbytes * weight
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per chip
    hbm_bytes: float              # per chip
    coll_bytes: float             # per chip (weighted wire volume)
    coll_by_kind: dict[str, float]

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_by_kind": self.coll_by_kind,
        }


def analyze(compiled) -> Roofline:
    """Loop-aware analysis of the partitioned module.

    ``compiled.cost_analysis()`` counts while-loop bodies once; the HLO
    walker in ``hlo_cost`` multiplies by trip counts (scan-over-layers,
    microbatch accumulation, chunked attention), which is essential for
    honest roofline terms — see tests/test_roofline.py.
    """
    from .hlo_cost import analyze_text
    cost = analyze_text(compiled.as_text())
    coll = dict(cost.coll)
    return Roofline(flops=cost.flops, hbm_bytes=cost.bytes,
                    coll_bytes=sum(coll.values()), coll_by_kind=coll)


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6·N·D for a train step (fwd+bwd) over `tokens` tokens."""
    return 6.0 * n_active_params * tokens


def model_flops_infer(n_active_params: int, tokens: int) -> float:
    """2·N·D for inference."""
    return 2.0 * n_active_params * tokens
